"""Chaos bench: the hostile-storage hardening gate.

Runs the same TQL query + streaming-loader workload twice over one dataset:
once against a clean SimulatedS3 provider, once against the same provider
with a seeded :class:`~repro.core.FaultPolicy` injecting timeouts, 5xx
transients, slow-range straggles and torn reads.  The smoke gate (run by
``scripts/check.sh``) asserts, BEFORE recording anything:

* **zero corruption** — selected rows, stream order and payload bytes are
  byte-identical between the clean and the faulted run (the retry/hedge
  machinery absorbs every injected fault);
* **visible absorption** — ``faults_injected`` > 0 on the provider and
  ``engine_errors_transient`` > 0 on the fetch engine (faults actually
  fired and were retried, not silently skipped);
* **bounded amplification** — the faulted run issues at most
  ``AMPLIFICATION_BUDGET``x the clean run's charged requests (retries +
  hedges may not stampede the store; S3 SlowDown must not beget SlowDown).

The datapoint lands in ``BENCH_io.json`` under ``chaos_hostile_storage``
with full provider + ``engine_*`` counter snapshots (retries, hedges,
hedge_wins, errors_transient, ...), so retry/hedge behaviour is tracked
across PRs next to the request counts.

A second section exercises the **write plane** (ISSUE 7): N concurrent
committers on a shared store with injected put/cas faults.  Its gates:

* **zero lost appends** — every committer lands (or raises a typed
  error; none may here), and each branch reads back byte-identical to a
  serial clean-provider run of the same workload;
* **visible write faults** — ``faults_put_*``/``faults_cas_5xx`` > 0 and
  at least one commit rebased (contention actually happened);
* **no stranded chunks** — after all commits, a GC mark pass finds zero
  orphaned chunk-payload bytes (rebases graft uploads, never abandon
  them);
* **wasted uploads ≈ 0 under non-overlapping contention** — a clean
  (fault-free) same-branch disjoint-tensor contention run re-publishes
  metadata only: ``wasted_upload_bytes`` stays exactly 0.

That datapoint lands under ``chaos_write_path``, including a
``registry`` section (``commit_rebases``, ``commit_adoptions``,
``commit_relocations``, ``commit_grafted_chunks``,
``storage_wasted_upload_bytes``) taken as a delta of the process-wide
:func:`repro.core.telemetry.registry` snapshot around the concurrent run.

Both chaos passes run under the span tracer: the hostile read pass must
contain ``fetch.retry`` and ``fetch.hedge`` spans and the contended write
pass ``commit.rebase`` spans — the injected-fault recovery machinery is
visible in the exported timeline, not just in counters.
"""

from __future__ import annotations

import threading
from typing import List, Tuple

import numpy as np

import repro.core as dl

from . import io_report
from .common import Timer, row

SEED = 20260807
QUERY = "SELECT * FROM dataset WHERE MIN(val) > 580"

#: charged-request ratio (faulted / clean) the smoke gate tolerates; the
#: default fault rates total ~15% so geometric retry amplification sits
#: near 1.2x — 1.5x leaves room for hedged duplicates without letting a
#: retry storm pass unnoticed.
AMPLIFICATION_BUDGET = 1.5

FAULT_RATES = dict(timeout_rate=0.04, error_rate=0.04,
                   straggle_rate=0.05, torn_rate=0.03)

WRITE_FAULT_RATES = dict(put_error_rate=0.08, put_torn_rate=0.06,
                         cas_error_rate=0.06)

#: concurrent committers in the write-chaos section (ISSUE 7 floor: >= 4)
N_WRITERS = 4


def _clustered_dataset(base: dl.StorageProvider, bands: int,
                       per_band: int) -> None:
    """Value-clustered fixture: tiny chunks so the query prunes most of
    them via manifest stats and the stream touches many objects (more
    reads = more injected faults per run)."""
    ds = dl.Dataset(base)
    ds.create_tensor("val", dtype="float32", min_chunk_size=1 << 11,
                     max_chunk_size=1 << 12)
    ds.create_tensor("lab", htype="class_label")
    rng = np.random.default_rng(11)
    for band in range(bands):
        lo = band * 100.0
        vals = rng.uniform(lo, lo + 90.0,
                           size=(per_band, 64)).astype(np.float32)
        for i in range(per_band):
            ds.append({"val": vals[i], "lab": np.int64(band * per_band + i)})
    ds.commit("chaos fixture")


def _stream(storage: dl.StorageProvider) -> Tuple[list, list, bytes]:
    """Query + ordered stream; returns everything the parity gate compares
    (selected indices, label order, concatenated payload bytes)."""
    ds = dl.Dataset(storage)
    view = ds.query(QUERY, engine="numpy")
    idx = view.indices.tolist()
    loader = ds.dataloader(batch_size=32, shuffle=False, num_workers=2,
                           seed=0)
    labs, vals = [], []
    for batch in loader:
        labs.extend(int(v) for v in batch["lab"])
        vals.append(np.asarray(batch["val"]))
    payload = np.concatenate(vals).tobytes() if vals else b""
    return idx, labs, payload


def _writer_rows(i: int, commits: int, rows_each: int) -> List[List[np.ndarray]]:
    """Deterministic per-writer workload: ``commits`` batches of
    ``rows_each`` rows for writer ``i``."""
    return [[np.full(32, i * 10_000 + c * 100 + r, np.float32)
             for r in range(rows_each)]
            for c in range(commits)]


def _branch_fixture(storage: dl.StorageProvider, n: int) -> None:
    """Serial setup: one tensor, one init commit, one branch per writer
    (branch creation republishes the whole tree, so it stays serial)."""
    ds = dl.Dataset(storage)
    ds.create_tensor("t", dtype="float32", min_chunk_size=1 << 11,
                     max_chunk_size=1 << 12)
    ds.commit("init")
    for i in range(n):
        ds.checkout(f"w{i}", create=True)


def _branch_payloads(storage: dl.StorageProvider, n: int) -> List[bytes]:
    """Concatenated row bytes per branch, via fresh cold opens."""
    out = []
    for i in range(n):
        r = dl.Dataset(storage)
        r.checkout(f"w{i}")
        t = r["t"]
        out.append(b"".join(np.ascontiguousarray(t[j]).tobytes()
                            for j in range(len(t))))
    return out


def _concurrent_commit_run(storage: dl.StorageProvider, commits: int,
                           rows_each: int) -> Tuple[list, dict]:
    """N_WRITERS threads, one branch each, barrier-released, appending and
    committing against one shared provider.  Returns (errors, summed
    commit_stats)."""
    handles = []
    for i in range(N_WRITERS):
        h = dl.Dataset(storage)
        h.checkout(f"w{i}")
        handles.append(h)
    barrier = threading.Barrier(N_WRITERS)
    errors: list = []

    def run(i: int, h: dl.Dataset) -> None:
        try:
            barrier.wait()
            for batch in _writer_rows(i, commits, rows_each):
                for arr in batch:
                    h["t"].append(arr)
                h.commit(f"writer {i}")
        except Exception as e:  # noqa: BLE001 - surfaced by the gate
            errors.append((i, repr(e)))

    threads = [threading.Thread(target=run, args=(i, h))
               for i, h in enumerate(handles)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    agg: dict = {}
    for h in handles:
        for k, v in h.vc.commit_stats.items():
            agg[k] = agg.get(k, 0) + v
    return errors, agg


def main(smoke: bool = False) -> List[str]:
    bands, per_band = (8, 100) if smoke else (12, 200)
    base = dl.MemoryProvider()
    _clustered_dataset(base, bands, per_band)

    # ---------------- clean pass (reference results + request baseline)
    clean_s3 = dl.SimulatedS3Provider(base, time_scale=0)
    with Timer() as t_clean:
        clean = _stream(clean_s3)
    clean_stats = io_report.provider_snapshot(clean_s3)

    # ---------------- hostile pass: seeded faults on the same objects
    policy = dl.FaultPolicy(seed=SEED, straggle_sleep_s=0.06, **FAULT_RATES)
    chaos_s3 = dl.SimulatedS3Provider(base, time_scale=0,
                                      fault_policy=policy)
    with dl.telemetry.tracing() as tr_read, Timer() as t_chaos:
        faulted = _stream(chaos_s3)
    chaos_stats = io_report.provider_snapshot(chaos_s3)

    # ---------------- gates (run BEFORE record(): a failing run must never
    # become part of the tracked history)
    assert faulted[0] == clean[0], "faulted run selected different rows"
    assert faulted[1] == clean[1], "faulted run changed the stream order"
    assert faulted[2] == clean[2], "faulted run corrupted payload bytes"
    assert chaos_stats["faults_injected"] > 0, \
        "fault policy injected nothing — the bench is not exercising chaos"
    assert chaos_stats.get("engine_errors_transient", 0) > 0, \
        "no transient was retried by the fetch engine"
    for k in ("engine_retries", "engine_hedges", "engine_hedge_wins",
              "engine_errors_permanent", "engine_stragglers"):
        assert k in chaos_stats, f"engine counter {k} missing from snapshot"
    amplification = chaos_stats["requests"] / max(clean_stats["requests"], 1)
    assert amplification <= AMPLIFICATION_BUDGET, (
        f"request amplification {amplification:.2f}x exceeds "
        f"{AMPLIFICATION_BUDGET}x budget (clean {clean_stats['requests']}, "
        f"chaos {chaos_stats['requests']})")
    # the recovery events must appear in the traced timeline too: a retry
    # or hedge that only bumps a counter is invisible in a stall
    # post-mortem
    retry_spans = tr_read.count("fetch.retry")
    hedge_spans = tr_read.count("fetch.hedge")
    assert retry_spans > 0, "hostile run recorded no fetch.retry spans"
    assert hedge_spans > 0, "hostile run recorded no fetch.hedge spans"

    io_report.record("chaos_hostile_storage", {
        "clean": clean_stats,
        "chaos": chaos_stats,
        "gate": {"amplification_x": amplification,
                 "budget_x": AMPLIFICATION_BUDGET,
                 "parity_ok": 1,
                 "rows_streamed": len(clean[1]),
                 "retry_spans": retry_spans,
                 "hedge_spans": hedge_spans,
                 "smoke": int(smoke)},
    })

    # ================= write plane: concurrent committers under chaos
    commits_each, rows_each = (2, 6) if smoke else (3, 12)

    # serial clean reference: same workload, one writer at a time
    ref_store = dl.MemoryProvider()
    _branch_fixture(ref_store, N_WRITERS)
    for i in range(N_WRITERS):
        h = dl.Dataset(ref_store)
        h.checkout(f"w{i}")
        for batch in _writer_rows(i, commits_each, rows_each):
            for arr in batch:
                h["t"].append(arr)
            h.commit(f"writer {i}")
    ref_payloads = _branch_payloads(ref_store, N_WRITERS)

    # chaos run: shared faulted provider, N_WRITERS concurrent committers
    wpolicy = dl.FaultPolicy(seed=SEED + 1, **WRITE_FAULT_RATES)
    ws3 = dl.SimulatedS3Provider(dl.MemoryProvider(), time_scale=0,
                                 fault_policy=wpolicy)
    _branch_fixture(ws3, N_WRITERS)
    # bracket the concurrent run with process-wide registry snapshots: the
    # delta isolates this run's commit/waste counters from everything the
    # process did before (fixtures, the read section, other benches)
    reg0 = dl.telemetry.registry().snapshot()
    with dl.telemetry.tracing() as tr_commit, Timer() as t_write:
        errors, cstats = _concurrent_commit_run(ws3, commits_each, rows_each)
    regd = dl.telemetry.registry().delta(reg0)
    wstats = io_report.provider_snapshot(ws3)

    # ---- gates
    assert not errors, f"committers failed under write chaos: {errors}"
    chaos_payloads = _branch_payloads(ws3, N_WRITERS)
    assert chaos_payloads == ref_payloads, \
        "concurrent chaos run is not byte-identical to the serial run"
    write_faults = (wstats["faults_put_5xx"] + wstats["faults_put_torn"]
                    + wstats["faults_cas_5xx"])
    assert write_faults > 0, "no write fault was injected"
    assert wstats["put_requests"] > 0, "put_requests counter never charged"
    assert cstats["rebases"] > 0, \
        "no commit rebased — the run never actually contended"
    rebase_spans = tr_commit.count("commit.rebase")
    assert rebase_spans > 0, \
        "contended run recorded no commit.rebase spans"
    # the registry mirrors VersionControl.commit_stats one-for-one
    assert regd.get("commit_rebases", 0) == cstats["rebases"], (
        f"registry commit_rebases {regd.get('commit_rebases', 0)} != "
        f"summed commit_stats rebases {cstats['rebases']}")
    gc_ds = dl.Dataset(ws3)
    gc_rep = gc_ds.maintenance().gc_orphans(dry_run=True)
    assert gc_rep.details["orphan_chunk_bytes"] == 0, (
        f"{gc_rep.details['orphan_chunk_bytes']} chunk bytes stranded — "
        f"a rebase abandoned uploads instead of grafting them")

    # non-overlapping same-branch contention on a CLEAN provider: the
    # loser relocates + grafts, so zero upload bytes are ever wasted
    cs3 = dl.SimulatedS3Provider(dl.MemoryProvider(), time_scale=0)
    ds0 = dl.Dataset(cs3)
    for t in ("a", "b"):
        ds0.create_tensor(t, dtype="float32", min_chunk_size=1 << 11,
                          max_chunk_size=1 << 12)
    ds0.commit("init")
    wa, wb = dl.Dataset(cs3), dl.Dataset(cs3)
    for i in range(rows_each):
        wa["a"].append(np.full(32, i, np.float32))
        wb["b"].append(np.full(32, 100 + i, np.float32))
    wa.commit("writer a")
    wb.commit("writer b")  # loses the CAS -> relocation + graft
    assert wb.vc.commit_stats["relocations"] >= 1
    assert wb.vc.commit_stats["grafted_chunks"] >= 1
    assert cs3.stats["wasted_upload_bytes"] == 0, (
        f"{cs3.stats['wasted_upload_bytes']} upload bytes wasted on "
        f"non-overlapping contention (expected 0: graft, don't re-upload)")

    io_report.record("chaos_write_path", {
        "chaos": wstats,
        "commit_stats": cstats,
        "registry": {k: regd.get(k, 0)
                     for k in ("commit_commits", "commit_rebases",
                               "commit_adoptions", "commit_relocations",
                               "commit_grafted_chunks", "commit_contended",
                               "storage_wasted_upload_bytes")},
        "gate": {"writers": N_WRITERS,
                 "rebase_spans": rebase_spans,
                 "commits_per_writer": commits_each,
                 "rows_per_commit": rows_each,
                 "parity_ok": 1,
                 "write_faults": write_faults,
                 "orphan_chunk_bytes": gc_rep.details["orphan_chunk_bytes"],
                 "clean_contention_wasted_upload_bytes":
                     cs3.stats["wasted_upload_bytes"],
                 "clean_contention_grafted_chunks":
                     wb.vc.commit_stats["grafted_chunks"],
                 "smoke": int(smoke)},
    })

    n = max(len(clean[1]), 1)
    return [
        row("chaos_clean_stream", t_clean.elapsed / n * 1e6,
            f"reqs{clean_stats['requests']}_rows{len(clean[1])}"),
        row("chaos_hostile_stream", t_chaos.elapsed / n * 1e6,
            f"reqs{chaos_stats['requests']}_"
            f"faults{chaos_stats['faults_injected']}_"
            f"retries{chaos_stats.get('engine_retries', 0)}_"
            f"hedges{chaos_stats.get('engine_hedges', 0)}_"
            f"hedgewins{chaos_stats.get('engine_hedge_wins', 0)}_"
            f"amp{amplification:.2f}x"),
        row("chaos_write_commits",
            t_write.elapsed / max(cstats["commits"], 1) * 1e6,
            f"writers{N_WRITERS}_commits{cstats['commits']}_"
            f"rebases{cstats['rebases']}_"
            f"relocations{cstats['relocations']}_"
            f"grafts{cstats['grafted_chunks']}_"
            f"wfaults{write_faults}"),
    ]


if __name__ == "__main__":
    import sys

    print("\n".join(main(smoke="--smoke" in sys.argv[1:])))
