"""Chaos bench: the hostile-storage hardening gate.

Runs the same TQL query + streaming-loader workload twice over one dataset:
once against a clean SimulatedS3 provider, once against the same provider
with a seeded :class:`~repro.core.FaultPolicy` injecting timeouts, 5xx
transients, slow-range straggles and torn reads.  The smoke gate (run by
``scripts/check.sh``) asserts, BEFORE recording anything:

* **zero corruption** — selected rows, stream order and payload bytes are
  byte-identical between the clean and the faulted run (the retry/hedge
  machinery absorbs every injected fault);
* **visible absorption** — ``faults_injected`` > 0 on the provider and
  ``engine_errors_transient`` > 0 on the fetch engine (faults actually
  fired and were retried, not silently skipped);
* **bounded amplification** — the faulted run issues at most
  ``AMPLIFICATION_BUDGET``x the clean run's charged requests (retries +
  hedges may not stampede the store; S3 SlowDown must not beget SlowDown).

The datapoint lands in ``BENCH_io.json`` under ``chaos_hostile_storage``
with full provider + ``engine_*`` counter snapshots (retries, hedges,
hedge_wins, errors_transient, ...), so retry/hedge behaviour is tracked
across PRs next to the request counts.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

import repro.core as dl

from . import io_report
from .common import Timer, row

SEED = 20260807
QUERY = "SELECT * FROM dataset WHERE MIN(val) > 580"

#: charged-request ratio (faulted / clean) the smoke gate tolerates; the
#: default fault rates total ~15% so geometric retry amplification sits
#: near 1.2x — 1.5x leaves room for hedged duplicates without letting a
#: retry storm pass unnoticed.
AMPLIFICATION_BUDGET = 1.5

FAULT_RATES = dict(timeout_rate=0.04, error_rate=0.04,
                   straggle_rate=0.05, torn_rate=0.03)


def _clustered_dataset(base: dl.StorageProvider, bands: int,
                       per_band: int) -> None:
    """Value-clustered fixture: tiny chunks so the query prunes most of
    them via manifest stats and the stream touches many objects (more
    reads = more injected faults per run)."""
    ds = dl.Dataset(base)
    ds.create_tensor("val", dtype="float32", min_chunk_size=1 << 11,
                     max_chunk_size=1 << 12)
    ds.create_tensor("lab", htype="class_label")
    rng = np.random.default_rng(11)
    for band in range(bands):
        lo = band * 100.0
        vals = rng.uniform(lo, lo + 90.0,
                           size=(per_band, 64)).astype(np.float32)
        for i in range(per_band):
            ds.append({"val": vals[i], "lab": np.int64(band * per_band + i)})
    ds.commit("chaos fixture")


def _stream(storage: dl.StorageProvider) -> Tuple[list, list, bytes]:
    """Query + ordered stream; returns everything the parity gate compares
    (selected indices, label order, concatenated payload bytes)."""
    ds = dl.Dataset(storage)
    view = ds.query(QUERY, engine="numpy")
    idx = view.indices.tolist()
    loader = ds.dataloader(batch_size=32, shuffle=False, num_workers=2,
                           seed=0)
    labs, vals = [], []
    for batch in loader:
        labs.extend(int(v) for v in batch["lab"])
        vals.append(np.asarray(batch["val"]))
    payload = np.concatenate(vals).tobytes() if vals else b""
    return idx, labs, payload


def main(smoke: bool = False) -> List[str]:
    bands, per_band = (8, 100) if smoke else (12, 200)
    base = dl.MemoryProvider()
    _clustered_dataset(base, bands, per_band)

    # ---------------- clean pass (reference results + request baseline)
    clean_s3 = dl.SimulatedS3Provider(base, time_scale=0)
    with Timer() as t_clean:
        clean = _stream(clean_s3)
    clean_stats = io_report.provider_snapshot(clean_s3)

    # ---------------- hostile pass: seeded faults on the same objects
    policy = dl.FaultPolicy(seed=SEED, straggle_sleep_s=0.06, **FAULT_RATES)
    chaos_s3 = dl.SimulatedS3Provider(base, time_scale=0,
                                      fault_policy=policy)
    with Timer() as t_chaos:
        faulted = _stream(chaos_s3)
    chaos_stats = io_report.provider_snapshot(chaos_s3)

    # ---------------- gates (run BEFORE record(): a failing run must never
    # become part of the tracked history)
    assert faulted[0] == clean[0], "faulted run selected different rows"
    assert faulted[1] == clean[1], "faulted run changed the stream order"
    assert faulted[2] == clean[2], "faulted run corrupted payload bytes"
    assert chaos_stats["faults_injected"] > 0, \
        "fault policy injected nothing — the bench is not exercising chaos"
    assert chaos_stats.get("engine_errors_transient", 0) > 0, \
        "no transient was retried by the fetch engine"
    for k in ("engine_retries", "engine_hedges", "engine_hedge_wins",
              "engine_errors_permanent", "engine_stragglers"):
        assert k in chaos_stats, f"engine counter {k} missing from snapshot"
    amplification = chaos_stats["requests"] / max(clean_stats["requests"], 1)
    assert amplification <= AMPLIFICATION_BUDGET, (
        f"request amplification {amplification:.2f}x exceeds "
        f"{AMPLIFICATION_BUDGET}x budget (clean {clean_stats['requests']}, "
        f"chaos {chaos_stats['requests']})")

    io_report.record("chaos_hostile_storage", {
        "clean": clean_stats,
        "chaos": chaos_stats,
        "gate": {"amplification_x": amplification,
                 "budget_x": AMPLIFICATION_BUDGET,
                 "parity_ok": 1,
                 "rows_streamed": len(clean[1]),
                 "smoke": int(smoke)},
    })

    n = max(len(clean[1]), 1)
    return [
        row("chaos_clean_stream", t_clean.elapsed / n * 1e6,
            f"reqs{clean_stats['requests']}_rows{len(clean[1])}"),
        row("chaos_hostile_stream", t_chaos.elapsed / n * 1e6,
            f"reqs{chaos_stats['requests']}_"
            f"faults{chaos_stats['faults_injected']}_"
            f"retries{chaos_stats.get('engine_retries', 0)}_"
            f"hedges{chaos_stats.get('engine_hedges', 0)}_"
            f"hedgewins{chaos_stats.get('engine_hedge_wins', 0)}_"
            f"amp{amplification:.2f}x"),
    ]


if __name__ == "__main__":
    import sys

    print("\n".join(main(smoke="--smoke" in sys.argv[1:])))
