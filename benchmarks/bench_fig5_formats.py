"""Fig 5 reproduction: (a) ingestion/conversion throughput, (b) local
iteration on small images, (c) local iteration on large images, (d) remote
streaming iteration — Deep Lake chunked format vs file-per-sample baseline.

The paper's comparison libraries (FFCV/WebDataset/Petastorm) are offline;
the structural contrast they represent is format-level and IS reproduced:
  file-per-sample (raw S3/file mode)   vs   chunked columnar + sample codecs.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

import repro.core as dl
from repro.core.views import DatasetView

from .common import (Timer, build_lake, file_store_read, file_store_write,
                     make_images, row)


def bench_ingest(images, label: str) -> List[str]:
    out = []
    nbytes = sum(i.nbytes for i in images)
    base = dl.MemoryProvider()
    with Timer() as t:
        file_store_write(base, images)
    out.append(row(f"fig5a_ingest_files_{label}",
                   t.elapsed / len(images) * 1e6,
                   f"{nbytes / t.elapsed / 1e6:.0f}MBps"))
    for codec in ("raw", "zlib", "quant8"):
        with Timer() as t:
            ds = build_lake(images, codec=codec)
        stored = ds.storage.total_bytes()
        out.append(row(f"fig5a_ingest_lake_{codec}_{label}",
                       t.elapsed / len(images) * 1e6,
                       f"{nbytes / t.elapsed / 1e6:.0f}MBps_ratio"
                       f"{nbytes / stored:.1f}x"))
    return out


def bench_iterate_local(images, label: str, epochs: int = 2) -> List[str]:
    out = []
    n = len(images)
    base = dl.MemoryProvider()
    file_store_write(base, images)
    with Timer() as t:
        for _ in range(epochs):
            for i in range(n):
                _ = file_store_read(base, i)
    out.append(row(f"fig5bc_iter_files_{label}", t.elapsed / (n * epochs) * 1e6,
                   f"{n * epochs / t.elapsed:.0f}sps"))
    for codec in ("raw", "zlib", "quant8"):
        ds = build_lake(images, codec=codec)
        loader = ds.dataloader(batch_size=32, shuffle=True, num_workers=8,
                               tensors=["images", "labels"])
        with Timer() as t:
            for _ in range(epochs):
                for _b in loader:
                    pass
        out.append(row(f"fig5bc_iter_lake_{codec}_{label}",
                       t.elapsed / (n * epochs) * 1e6,
                       f"{n * epochs / t.elapsed:.0f}sps"))
    return out


def bench_iterate_remote(images, label: str, time_scale: float = 0.05
                         ) -> List[str]:
    """Fig 5d: iterate from simulated object storage (latency+bandwidth model,
    sim time compressed by `time_scale` and reported at full scale)."""
    out = []
    n = len(images)

    # file mode: one GET per sample, sequential
    s3 = dl.SimulatedS3Provider(time_scale=time_scale)
    file_store_write(s3.base, images)
    s3.reset_stats()
    with Timer() as t:
        for i in range(n):
            _ = file_store_read(s3, i)
    sim = s3.stats["sim_seconds"]
    out.append(row(f"fig5d_remote_files_{label}", sim / n * 1e6,
                   f"{n / sim:.0f}sps_sim"))

    # deep lake: chunked + parallel workers + LRU (cold-cache read path:
    # the lake is written straight to S3, then re-opened behind a FRESH
    # cache so iteration actually streams)
    s3b = dl.SimulatedS3Provider(time_scale=time_scale)
    build_lake(images, codec="quant8", storage=s3b)
    s3b.reset_stats()
    ds = dl.Dataset(dl.chain(dl.MemoryProvider(), s3b,
                             capacity_bytes=32 << 20))
    loader = ds.dataloader(batch_size=32, shuffle=True, num_workers=8)
    with Timer() as t:
        for _b in loader:
            pass
    # effective time: overlapped IO -> max(cpu wall, per-connection sim time)
    sim_io = s3b.stats["sim_seconds"] / max(loader.num_workers, 1)
    eff = max(t.elapsed - s3b.stats["sim_seconds"] * time_scale + sim_io, sim_io)
    out.append(row(f"fig5d_remote_lake_{label}", eff / n * 1e6,
                   f"{n / eff:.0f}sps_sim_reqs{s3b.stats['requests']}"))
    return out


def main() -> List[str]:
    lines = []
    small = make_images(1200, (30, 30))     # CIFAR-class
    large = make_images(120, (250, 250))    # the paper's 'random dataset'
    lines += bench_ingest(small, "30px")
    lines += bench_ingest(large, "250px")
    lines += bench_iterate_local(small, "30px")
    lines += bench_iterate_local(large, "250px")
    lines += bench_iterate_remote(small, "30px")
    lines += bench_iterate_remote(large, "250px")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
