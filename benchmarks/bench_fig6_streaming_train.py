"""Fig 6 reproduction: identical training, three data paths —

  (a) local            — data already on the machine
  (b) AWS File Mode    — one synchronous GET per sample from object storage
  (c) Fast File Mode   — threaded per-sample GETs (starts fast, no chunking)
  (d) Deep Lake stream — chunked columnar + parallel fetch + prefetch overlap

Workload mirrors the paper's: an image model (MLP classifier stands in for
the conv net; per-step compute ~tens of ms like a real accelerator step)
over 64x64 images.  Remote timing uses the SimulatedS3 cost model
(cross-region: 30ms TTFB, 50MB/s per connection); sim seconds are reported
at full scale.  Paper's claim to match: (d) ~= (a); (b) is several x slower.
"""

from __future__ import annotations

import concurrent.futures as cf
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as dl

from .common import (Timer, build_lake, file_store_read, file_store_write,
                     make_images, row)

N_IMAGES = 600
BATCH = 32
STEPS = 36
LAT, BW = 0.030, 50e6     # cross-region object store
TIME_SCALE = 0.0          # pure accounting; wall = compute, sim = IO


def _train_step_fn():
    key = jax.random.PRNGKey(0)
    d, h, classes = 64 * 64 * 3, 1024, 10
    w1 = jax.random.normal(key, (d, h), jnp.float32) * 0.01
    w2 = jax.random.normal(key, (h, classes), jnp.float32) * 0.01
    params = {"w1": w1, "w2": w2}

    @jax.jit
    def step(params, x, y):
        def loss_fn(p):
            z = jnp.tanh(x.reshape(x.shape[0], -1) @ p["w1"]) @ p["w2"]
            lse = jax.nn.logsumexp(z, -1)
            return (lse - jnp.take_along_axis(z, y[:, None], 1)[:, 0]).mean()

        loss, g = jax.value_and_grad(loss_fn)(params)
        return {k: params[k] - 0.01 * g[k] for k in params}, loss

    return params, step


def _consume(params, step, batch_iter, steps=STEPS):
    compute = 0.0
    it = iter(batch_iter)
    for _ in range(steps):
        x, y = next(it)
        t0 = time.perf_counter()
        params, loss = step(params, jnp.asarray(x, jnp.float32) / 255.0,
                            jnp.asarray(y))
        jax.block_until_ready(loss)
        compute += time.perf_counter() - t0
    return compute


def main() -> List[str]:
    lines = []
    images = make_images(N_IMAGES, (64, 64))
    labels = [i % 10 for i in range(N_IMAGES)]
    rng = np.random.default_rng(0)
    order = lambda: rng.permutation(N_IMAGES)

    # ---------------- (a) local
    params, step = _train_step_fn()
    imgs_arr = np.stack(images)
    labs_arr = np.asarray(labels)

    def local_batches():
        while True:
            idx = order()
            for i in range(0, N_IMAGES - BATCH, BATCH):
                sel = idx[i:i + BATCH]
                yield imgs_arr[sel], labs_arr[sel]

    compute = _consume(params, step, local_batches())
    local_wall = compute
    lines.append(row("fig6_local", local_wall / STEPS * 1e6, "baseline"))

    # ---------------- (b) file mode: sequential GET per sample
    s3 = dl.SimulatedS3Provider(time_scale=TIME_SCALE, latency_s=LAT,
                                bandwidth_bps=BW)
    file_store_write(s3.base, images, labels)

    def filemode_batches():
        while True:
            idx = order()
            for i in range(0, N_IMAGES - BATCH, BATCH):
                sel = idx[i:i + BATCH]
                xs = np.stack([file_store_read(s3, int(j)) for j in sel])
                yield xs, labs_arr[sel]

    from . import io_report

    s3.reset_stats()
    params, step = _train_step_fn()
    compute = _consume(params, step, filemode_batches())
    wall_b = compute + s3.stats["sim_seconds"]   # sequential: IO adds up
    # snapshot BEFORE the fast-file section resets the shared provider
    filemode_stats = io_report.provider_snapshot(s3)
    lines.append(row("fig6_s3_filemode", wall_b / STEPS * 1e6,
                     f"slowdown{wall_b / local_wall:.1f}x"))

    # ---------------- (c) fast file mode: threaded GETs, still per-sample
    s3.reset_stats()
    pool = cf.ThreadPoolExecutor(8)

    def fastfile_batches():
        while True:
            idx = order()
            for i in range(0, N_IMAGES - BATCH, BATCH):
                sel = idx[i:i + BATCH]
                xs = np.stack(list(pool.map(
                    lambda j: file_store_read(s3, int(j)), sel)))
                yield xs, labs_arr[sel]

    params, step = _train_step_fn()
    compute = _consume(params, step, fastfile_batches())
    wall_c = compute + s3.stats["sim_seconds"] / 8   # 8-way overlapped IO
    # snapshot too (earlier revisions dropped this section's stats)
    fastfile_stats = io_report.provider_snapshot(s3)
    lines.append(row("fig6_s3_fastfile", wall_c / STEPS * 1e6,
                     f"slowdown{wall_c / local_wall:.1f}x"))

    # ---------------- (d) deep lake streaming
    s3b = dl.SimulatedS3Provider(time_scale=TIME_SCALE, latency_s=LAT,
                                 bandwidth_bps=BW)
    build_lake(images, codec="quant8", storage=s3b, chunk_mb=2)
    s3b.reset_stats()
    dsr = dl.Dataset(dl.chain(dl.MemoryProvider(), s3b,
                              capacity_bytes=64 << 20))
    loader = dsr.dataloader(batch_size=BATCH, shuffle=True, num_workers=8,
                            drop_last=True)

    def lake_batches():
        while True:
            for b in loader:
                yield b["images"], b["labels"]

    params, step = _train_step_fn()
    compute = _consume(params, step, lake_batches())
    # chunked fetch overlaps compute through the prefetch queue: the critical
    # path is max(compute, per-connection IO), plus residual handoff
    wall_d = max(compute, s3b.stats["sim_seconds"] / 8) \
        + 0.1 * min(compute, s3b.stats["sim_seconds"] / 8)
    lines.append(row("fig6_deeplake_stream", wall_d / STEPS * 1e6,
                     f"slowdown{wall_d / local_wall:.2f}x_"
                     f"reqs{s3b.stats['requests']}_"
                     f"coal{s3b.stats['coalesced_requests']}_"
                     f"down{s3b.stats['bytes_down']}_"
                     f"sim{s3b.stats['sim_seconds']:.3f}"))

    io_report.record("fig6_streaming_train", {
        "s3_filemode": filemode_stats,
        "s3_fastfile": fastfile_stats,
        "deeplake_stream": io_report.provider_snapshot(s3b),
        "walls": {"local_s": local_wall, "filemode_s": wall_b,
                  "fastfile_s": wall_c, "deeplake_s": wall_d},
        "loader": {"io_requests": loader.stats.io_requests,
                   "bytes_fetched": loader.stats.bytes_fetched,
                   "samples": loader.stats.samples},
    })
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
