"""Fig 6 reproduction: identical training, three data paths —

  (a) local            — data already on the machine
  (b) AWS File Mode    — one synchronous GET per sample from object storage
  (c) Fast File Mode   — threaded per-sample GETs (starts fast, no chunking)
  (d) Deep Lake stream — chunked columnar + parallel fetch + prefetch overlap

Workload mirrors the paper's: an image model (MLP classifier stands in for
the conv net; per-step compute ~tens of ms like a real accelerator step)
over 64x64 images.  Remote timing uses the SimulatedS3 cost model
(cross-region: 30ms TTFB, 50MB/s per connection); sim seconds are reported
at full scale.  Paper's claim to match: (d) ~= (a); (b) is several x slower.
"""

from __future__ import annotations

import concurrent.futures as cf
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as dl

from .common import (Timer, build_lake, file_store_read, file_store_write,
                     make_images, row)

N_IMAGES = 600
BATCH = 32
STEPS = 36
LAT, BW = 0.030, 50e6     # cross-region object store
TIME_SCALE = 0.0          # pure accounting; wall = compute, sim = IO

#: steady-state stall budget for the deep-lake section (smoke gate):
#: seconds the simulated per-connection IO may exceed compute — the scan
#: pipeline's cross-unit prefetch must keep the training step the
#: bottleneck, so the stall stays ~0 (§4.5, Fig 6's "(d) ~= (a)" claim)
STALL_BUDGET_S = 1.0


#: regression slack over the recorded baseline + an absolute noise floor
#: (compute wall time jitters between machines; stall ~0 makes a bare
#: multiplicative bound meaninglessly tight)
STALL_REGRESSION_SLACK = 1.25
STALL_NOISE_FLOOR_S = 0.25


def _baseline_stall(smoke: bool) -> float:
    """Newest recorded stall_seconds of a run with the SAME workload size
    (smoke vs full — their stalls are not comparable); inf when the
    history has no matching datapoint."""
    import json

    from . import io_report
    try:
        with open(io_report.PATH) as f:
            hist = json.load(f)["benches"]["fig6_streaming_train"]
        for entry in reversed(hist):
            stall = entry.get("stall", {})
            if stall.get("smoke") == int(smoke):
                return float(stall["stall_seconds"])
    except (OSError, KeyError, ValueError, TypeError):
        pass
    return float("inf")


def _train_step_fn():
    key = jax.random.PRNGKey(0)
    d, h, classes = 64 * 64 * 3, 1024, 10
    w1 = jax.random.normal(key, (d, h), jnp.float32) * 0.01
    w2 = jax.random.normal(key, (h, classes), jnp.float32) * 0.01
    params = {"w1": w1, "w2": w2}

    @jax.jit
    def step(params, x, y):
        def loss_fn(p):
            z = jnp.tanh(x.reshape(x.shape[0], -1) @ p["w1"]) @ p["w2"]
            lse = jax.nn.logsumexp(z, -1)
            return (lse - jnp.take_along_axis(z, y[:, None], 1)[:, 0]).mean()

        loss, g = jax.value_and_grad(loss_fn)(params)
        return {k: params[k] - 0.01 * g[k] for k in params}, loss

    return params, step


def _consume(params, step, batch_iter, steps=STEPS):
    compute = 0.0
    it = iter(batch_iter)
    for _ in range(steps):
        x, y = next(it)
        t0 = time.perf_counter()
        params, loss = step(params, jnp.asarray(x, jnp.float32) / 255.0,
                            jnp.asarray(y))
        jax.block_until_ready(loss)
        compute += time.perf_counter() - t0
    return compute


#: tracing-overhead gate (smoke): the traced deep-lake run's simulated IO
#: seconds must match the untraced run within this relative fraction (plus
#: an absolute floor — smoke sims are fractions of a second)
TRACE_OVERHEAD_TOL = 0.05
TRACE_OVERHEAD_FLOOR_S = 0.05


def _deeplake_run(images, steps: int):
    """One deep-lake streaming training run on a fresh simulated store.

    Returns ``(compute_seconds, provider, loader)`` — the provider's stats
    and the loader's stats are both still live snapshots of this run.
    """
    s3b = dl.SimulatedS3Provider(time_scale=TIME_SCALE, latency_s=LAT,
                                 bandwidth_bps=BW)
    build_lake(images, codec="quant8", storage=s3b, chunk_mb=2)
    s3b.reset_stats()
    dsr = dl.Dataset(dl.chain(dl.MemoryProvider(), s3b,
                              capacity_bytes=64 << 20))
    loader = dsr.dataloader(batch_size=BATCH, shuffle=True, num_workers=8,
                            drop_last=True)

    def lake_batches():
        while True:
            for b in loader:
                yield b["images"], b["labels"]

    params, step = _train_step_fn()
    compute = _consume(params, step, lake_batches(), steps=steps)
    return compute, s3b, loader


def main(smoke: bool = False, trace_out: str | None = None) -> List[str]:
    n_images = 240 if smoke else N_IMAGES
    steps = 12 if smoke else STEPS
    lines = []
    images = make_images(n_images, (64, 64))
    labels = [i % 10 for i in range(n_images)]
    rng = np.random.default_rng(0)
    order = lambda: rng.permutation(n_images)

    # ---------------- (a) local
    params, step = _train_step_fn()
    imgs_arr = np.stack(images)
    labs_arr = np.asarray(labels)

    def local_batches():
        while True:
            idx = order()
            for i in range(0, n_images - BATCH, BATCH):
                sel = idx[i:i + BATCH]
                yield imgs_arr[sel], labs_arr[sel]

    compute = _consume(params, step, local_batches(), steps=steps)
    local_wall = compute
    lines.append(row("fig6_local", local_wall / steps * 1e6, "baseline"))

    # ---------------- (b) file mode: sequential GET per sample
    s3 = dl.SimulatedS3Provider(time_scale=TIME_SCALE, latency_s=LAT,
                                bandwidth_bps=BW)
    file_store_write(s3.base, images, labels)

    def filemode_batches():
        while True:
            idx = order()
            for i in range(0, n_images - BATCH, BATCH):
                sel = idx[i:i + BATCH]
                xs = np.stack([file_store_read(s3, int(j)) for j in sel])
                yield xs, labs_arr[sel]

    from . import io_report

    s3.reset_stats()
    params, step = _train_step_fn()
    compute = _consume(params, step, filemode_batches(), steps=steps)
    wall_b = compute + s3.stats["sim_seconds"]   # sequential: IO adds up
    # snapshot BEFORE the fast-file section resets the shared provider
    filemode_stats = io_report.provider_snapshot(s3)
    lines.append(row("fig6_s3_filemode", wall_b / steps * 1e6,
                     f"slowdown{wall_b / local_wall:.1f}x"))

    # ---------------- (c) fast file mode: threaded GETs, still per-sample
    s3.reset_stats()
    pool = cf.ThreadPoolExecutor(8)

    def fastfile_batches():
        while True:
            idx = order()
            for i in range(0, n_images - BATCH, BATCH):
                sel = idx[i:i + BATCH]
                xs = np.stack(list(pool.map(
                    lambda j: file_store_read(s3, int(j)), sel)))
                yield xs, labs_arr[sel]

    params, step = _train_step_fn()
    compute = _consume(params, step, fastfile_batches(), steps=steps)
    wall_c = compute + s3.stats["sim_seconds"] / 8   # 8-way overlapped IO
    # snapshot too (earlier revisions dropped this section's stats)
    fastfile_stats = io_report.provider_snapshot(s3)
    lines.append(row("fig6_s3_fastfile", wall_c / steps * 1e6,
                     f"slowdown{wall_c / local_wall:.1f}x"))

    # ---------------- (d) deep lake streaming
    compute, s3b, loader = _deeplake_run(images, steps)
    # chunked fetch overlaps compute through the prefetch queue: the critical
    # path is max(compute, per-connection IO), plus residual handoff
    wall_d = max(compute, s3b.stats["sim_seconds"] / 8) \
        + 0.1 * min(compute, s3b.stats["sim_seconds"] / 8)
    lines.append(row("fig6_deeplake_stream", wall_d / steps * 1e6,
                     f"slowdown{wall_d / local_wall:.2f}x_"
                     f"reqs{s3b.stats['requests']}_"
                     f"coal{s3b.stats['coalesced_requests']}_"
                     f"down{s3b.stats['bytes_down']}_"
                     f"sim{s3b.stats['sim_seconds']:.3f}"))

    # steady-state stall: seconds the per-connection simulated IO exceeds
    # compute — with the pipeline's cross-unit prefetch this must stay ~0
    # (training step remains the bottleneck).  The smoke gate enforces the
    # absolute budget AND no regression vs. the recorded same-size
    # baseline (slack + noise floor); it runs BEFORE record() so a failing
    # stall can never become the next run's baseline (no self-ratchet).
    stall_d = max(0.0, s3b.stats["sim_seconds"] / 8 - compute)
    baseline = _baseline_stall(smoke)
    lake_stats = io_report.provider_snapshot(s3b)
    lines.append(row("fig6_stall", stall_d * 1e6,
                     f"budget{STALL_BUDGET_S:.2f}s_prefhits"
                     f"{lake_stats.get('engine_prefetch_hits', 0)}_wasted"
                     f"{lake_stats.get('engine_prefetch_wasted_bytes', 0)}"))
    if smoke:
        limit = STALL_BUDGET_S
        if baseline != float("inf"):
            limit = min(limit, max(STALL_REGRESSION_SLACK * baseline,
                                   STALL_NOISE_FLOOR_S))
        assert stall_d <= limit, (
            f"steady-state stall {stall_d:.3f}s exceeds gate {limit:.3f}s "
            f"(budget {STALL_BUDGET_S}s, baseline {baseline})")

    # stall attribution: decompose the simulated stall into exhaustive,
    # non-overlapping causes from the provider's per-cause sim partition
    # (demand-fetch wait, retry/hedge/fault overhead, decode, prefetch
    # eviction).  The partition invariant and the causes-sum-to-total
    # invariant are both gated here in smoke AND re-checked structurally
    # by `io_report --validate`.
    from repro.core import telemetry

    sim_part = telemetry.sim_cause_partition(s3b.stats)
    part_sum = sum(sim_part.values())
    stall_attr = telemetry.attribute_stall(
        sim_part, compute, parallelism=8,
        decode_s=loader.stats.decode_seconds / 8)
    lines.append(row(
        "fig6_stall_attribution", stall_attr["total_s"] * 1e6,
        "_".join(f"{k[:-2]}{stall_attr[k]:.3f}"
                 for k in telemetry.STALL_CAUSE_KEYS)))
    if smoke:
        assert abs(part_sum - s3b.stats["sim_seconds"]) <= \
            0.01 * s3b.stats["sim_seconds"] + 1e-6, (
            f"sim cause partition {part_sum:.6f}s != "
            f"sim_seconds {s3b.stats['sim_seconds']:.6f}s")
        causes = sum(v for k, v in stall_attr.items() if k != "total_s")
        assert abs(causes - stall_attr["total_s"]) <= \
            0.05 * abs(stall_attr["total_s"]) + 1e-6, (
            f"stall causes sum {causes:.6f}s != total "
            f"{stall_attr['total_s']:.6f}s")

    # traced re-run: the tracing layer must not perturb the measured IO —
    # the traced run's simulated seconds must match the untraced run within
    # 5% (deterministic cost model; only the span bookkeeping differs).
    # Runs in smoke (gate) or when a trace artifact was requested.
    if smoke or trace_out:
        with telemetry.tracing() as tr:
            compute_t, s3t, loader_t = _deeplake_run(images, steps)
        sim_u = s3b.stats["sim_seconds"]
        sim_t = s3t.stats["sim_seconds"]
        lines.append(row("fig6_trace_overhead", abs(sim_t - sim_u) * 1e6,
                         f"untraced{sim_u:.3f}s_traced{sim_t:.3f}s_"
                         f"spans{len(tr.events())}"))
        if smoke:
            assert abs(sim_t - sim_u) <= max(TRACE_OVERHEAD_TOL * sim_u,
                                             TRACE_OVERHEAD_FLOOR_S), (
                f"traced sim {sim_t:.3f}s deviates from untraced "
                f"{sim_u:.3f}s beyond {TRACE_OVERHEAD_TOL:.0%}")
            assert tr.count("scan.group") > 0, \
                "traced run produced no scan.group spans"
        if trace_out:
            tr.write_chrome(trace_out)
            lines.append(row("fig6_trace_artifact", len(tr.events()),
                             trace_out))

    io_report.record("fig6_streaming_train", {
        "stall_attribution": stall_attr,
        "s3_filemode": filemode_stats,
        "s3_fastfile": fastfile_stats,
        "deeplake_stream": lake_stats,
        "walls": {"local_s": local_wall, "filemode_s": wall_b,
                  "fastfile_s": wall_c, "deeplake_s": wall_d},
        "stall": {"stall_seconds": stall_d, "budget_s": STALL_BUDGET_S,
                  "smoke": int(smoke)},
        "loader": {"io_requests": loader.stats.io_requests,
                   "bytes_fetched": loader.stats.bytes_fetched,
                   "samples": loader.stats.samples,
                   "wait_seconds": loader.stats.wait_seconds,
                   # consumer-side wait decomposition (sums to wait_seconds)
                   **{f"stall_{k}_s": v
                      for k, v in loader.stats.stall_by_cause.items()}},
    })
    return lines


if __name__ == "__main__":
    import sys

    argv = sys.argv[1:]
    out = None
    if "--trace-out" in argv:
        out = argv[argv.index("--trace-out") + 1]
    print("\n".join(main(smoke="--smoke" in argv, trace_out=out)))
