"""Fig 7 reproduction: accelerator utilization while streaming.

The paper streams LAION into 16 A100s training CLIP and reports (i) GPU
utilization staying ~100% and (ii) 80k images/s/machine loader-only
throughput.  Structural reproduction: stream images from the simulated
object store through the loader into a consumer with a fixed per-batch
'accelerator' cost, report utilization = busy / (busy + data-wait), plus
loader-only peak throughput.
"""

from __future__ import annotations

import time
from typing import List

import repro.core as dl

from .common import Timer, build_lake, make_images, row


def main() -> List[str]:
    lines = []
    images = make_images(1500, (64, 64))
    s3 = dl.SimulatedS3Provider(time_scale=0.02)
    ds = build_lake(images, codec="quant8",
                    storage=dl.chain(dl.MemoryProvider(), s3,
                                     capacity_bytes=64 << 20), chunk_mb=4)

    # loader-only peak throughput (the paper's 80k img/s per machine figure)
    loader = ds.dataloader(batch_size=64, shuffle=True, num_workers=8)
    with Timer() as t:
        n = sum(len(b["labels"]) for b in loader)
    lines.append(row("fig7_loader_only", t.elapsed / n * 1e6,
                     f"{n / t.elapsed:.0f}imgps"))

    # streaming into a consumer with fixed per-batch compute (a large-model
    # step is 50-200ms; util should approach 1.0 as the paper's Fig 7 shows)
    for step_ms in (50.0, 150.0):
        loader = ds.dataloader(batch_size=64, shuffle=True, num_workers=8,
                               seed=1)
        busy = 0.0
        with Timer() as t:
            for b in loader:
                time.sleep(step_ms / 1e3)          # the 'GPU step'
                busy += step_ms / 1e3
        util = loader.stats.utilization(step_ms / 1e3)
        lines.append(row(f"fig7_stream_util_step{int(step_ms)}ms",
                         t.elapsed * 1e6 / max(loader.stats.batches, 1),
                         f"util{util:.2f}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
