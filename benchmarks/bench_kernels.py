"""Kernel microbenchmarks (interpret mode = correctness-grade timing only;
real perf comes from the §Roofline analysis of the lowered programs)."""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .common import Timer, row


def main() -> List[str]:
    lines = []
    rng = np.random.default_rng(0)

    # flash attention vs jnp oracle (quality + wall)
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import ref_attention
    q = jnp.asarray(rng.standard_normal((1, 512, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    want = ref_attention(q, k, v, causal=True)
    got = flash_attention(q, k, v, True, 0, None, 128, 128, True)
    err = float(jnp.max(jnp.abs(got - want)))
    ref_jit = jax.jit(lambda q_: ref_attention(q_, k, v, causal=True))
    jax.block_until_ready(ref_jit(q))
    with Timer() as t:
        for _ in range(5):
            jax.block_until_ready(ref_jit(q))
    lines.append(row("kern_attn_xla_ref", t.elapsed / 5 * 1e6,
                     f"maxerr{err:.1e}"))

    # ssd chunked (XLA path) vs naive recurrence
    from repro.models.ssm import ssd_chunked, ssd_reference
    x = jnp.asarray(rng.standard_normal((2, 512, 8, 64)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (2, 512, 8)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (8,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((2, 512, 1, 64)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((2, 512, 1, 64)) * 0.3, jnp.float32)
    f_naive = jax.jit(lambda *a: ssd_reference(*a)[0])
    f_chunk = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    y1 = jax.block_until_ready(f_naive(x, dt, A, Bm, Cm))
    y2 = jax.block_until_ready(f_chunk(x, dt, A, Bm, Cm))
    err = float(jnp.max(jnp.abs(y1 - y2)))
    with Timer() as t:
        for _ in range(5):
            jax.block_until_ready(f_naive(x, dt, A, Bm, Cm))
    naive_us = t.elapsed / 5 * 1e6
    with Timer() as t:
        for _ in range(5):
            jax.block_until_ready(f_chunk(x, dt, A, Bm, Cm))
    lines.append(row("kern_ssd_chunked_vs_naive", t.elapsed / 5 * 1e6,
                     f"speedup{naive_us / (t.elapsed / 5 * 1e6):.1f}x_"
                     f"maxerr{err:.1e}"))

    # decode attention kernel allclose (interpret)
    from repro.kernels.decode_attention import decode_attention
    from repro.kernels.decode_attention.ref import ref_decode_attention
    qd = jnp.asarray(rng.standard_normal((2, 8, 64)), jnp.float32)
    ck = jnp.asarray(rng.standard_normal((2, 2048, 2, 64)), jnp.float32)
    cv = jnp.asarray(rng.standard_normal((2, 2048, 2, 64)), jnp.float32)
    with Timer() as t:
        got = decode_attention(qd, ck, cv, pos=jnp.int32(1500), block_t=512,
                               interpret=True)
    err = float(jnp.max(jnp.abs(
        got - ref_decode_attention(qd, ck, cv, pos=1500))))
    lines.append(row("kern_decode_attn_interp", t.elapsed * 1e6,
                     f"maxerr{err:.1e}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
