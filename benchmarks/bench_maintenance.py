"""Cold-open budget + maintenance smoke benchmark (manifest subsystem).

Measures and GATES the manifest's reason to exist:

* **cold_open** — opening a committed multi-tensor dataset over simulated
  S3 must cost at most ``COLD_OPEN_BUDGET`` storage requests with a
  manifest (pointer + consolidated segment = 2), vs ``~2 + 6·n_tensors``
  for the legacy per-file layout.  Both datapoints go to ``BENCH_io.json``
  so the trajectory is tracked across PRs; the budget is a hard assert —
  ``scripts/check.sh`` fails when a regression pushes the manifest open
  over budget or shrinks the legacy/manifest gap below 3x.

* **maintenance_smoke** — the three maintenance jobs run end-to-end on a
  pre-stats copy of the same dataset: backfill must restore the native
  prune verdicts exactly (planner parity, byte-identical rows), the GC
  dry-run must flag a planted orphan without deleting anything, and
  compaction must collapse the manifest back to the 2-request open.

Run: ``python -m benchmarks.bench_maintenance --smoke`` (also the
check.sh gate; the full mode just prints the same rows).
"""

from __future__ import annotations

from typing import List

import numpy as np

import repro.core as dl
from repro.core.manifest import MANIFEST_KEY, SEGMENT_PREFIX

from . import io_report
from .common import Timer, row

N_TENSORS = 4
N_ROWS = 400
COLD_OPEN_BUDGET = 3        # requests; acceptance criterion from ISSUE 3
QUERY = "SELECT * FROM dataset WHERE MIN(t0) > 1200"


def _build(storage):
    rng = np.random.default_rng(23)
    ds = dl.Dataset(storage)
    for j in range(N_TENSORS):
        ds.create_tensor(f"t{j}", dtype="float32", min_chunk_size=1 << 11,
                         max_chunk_size=1 << 12)
    for i in range(N_ROWS):
        band = i // 25
        ds.append({f"t{j}": (rng.standard_normal(8).astype(np.float32)
                             + np.float32(100 * band + j))
                   for j in range(N_TENSORS)})
    ds.commit("bench fixture")
    return ds


def _cold_open_stats(base):
    s3 = dl.SimulatedS3Provider(base, time_scale=0.0)
    with Timer() as t:
        ds = dl.Dataset(s3)
        for name in ds.tensor_names:
            assert len(ds[name]) == N_ROWS
    return io_report.provider_snapshot(s3), t.elapsed


def _strip_manifest(base):
    base.delete(MANIFEST_KEY)
    for key in list(base.list_keys(SEGMENT_PREFIX)):
        base.delete(key)


def _strip_stats(base):
    for key in list(base.list_keys()):
        if key.endswith("chunk_stats.json"):
            base.delete(key)


def main() -> List[str]:
    lines = []
    base = dl.MemoryProvider()
    native = _build(base)
    native_view = native.query(QUERY, use_stats=True)
    native_plan = native_view.scan_plan
    native_rows = native_view.indices.tolist()

    # ---- cold-open budget: manifest vs legacy ---------------------------
    manifest_stats, wall_m = _cold_open_stats(base)
    legacy_base = dl.MemoryProvider()
    _build(legacy_base)
    _strip_manifest(legacy_base)
    legacy_stats, wall_l = _cold_open_stats(legacy_base)
    lines.append(row("cold_open_manifest", wall_m * 1e6,
                     f"req{manifest_stats['requests']}"
                     f"_meta{manifest_stats['meta_requests']}"
                     f"_sim{manifest_stats['sim_seconds']:.3f}"))
    lines.append(row("cold_open_legacy", wall_l * 1e6,
                     f"req{legacy_stats['requests']}"
                     f"_meta{legacy_stats['meta_requests']}"
                     f"_sim{legacy_stats['sim_seconds']:.3f}"))
    assert manifest_stats["requests"] <= COLD_OPEN_BUDGET, (
        f"cold open with manifest took {manifest_stats['requests']} requests "
        f"(budget {COLD_OPEN_BUDGET})")
    assert legacy_stats["requests"] >= 3 * manifest_stats["requests"], (
        f"manifest gain fell under 3x: legacy {legacy_stats['requests']} vs "
        f"manifest {manifest_stats['requests']}")
    io_report.record("cold_open", {
        "manifest": manifest_stats, "legacy": legacy_stats,
        "budget": {"requests_budget": COLD_OPEN_BUDGET,
                   "n_tensors": N_TENSORS}})

    # ---- maintenance smoke: backfill -> prune parity --------------------
    pre_base = dl.MemoryProvider()
    _build(pre_base)
    _strip_manifest(pre_base)
    _strip_stats(pre_base)
    pre = dl.Dataset(pre_base)
    unpruned = pre.query(QUERY, use_stats=True)
    assert unpruned.scan_plan["rows_pruned"] == 0, "pre-stats ds pruned?!"
    with Timer() as t:
        backfill = pre.maintenance().backfill_stats()
    pruned_view = pre.query(QUERY, use_stats=True)
    assert pruned_view.indices.tolist() == native_rows, \
        "backfill changed query results"
    for k in ("rows_pruned", "rows_sure", "rows_verify", "chunks_pruned"):
        assert pruned_view.scan_plan[k] == native_plan[k], (
            f"backfill prune verdict mismatch on {k}: "
            f"{pruned_view.scan_plan[k]} != {native_plan[k]}")
    lines.append(row("maintenance_backfill", t.elapsed * 1e6,
                     f"chunks{backfill.details['chunks_backfilled']}"
                     f"_pruned{pruned_view.scan_plan['rows_pruned']}"))

    # ---- maintenance smoke: GC dry-run + compaction ---------------------
    orphan_key = f"versions/{pre.commit_id}/tensors/t0/chunks/cdeadbeef"
    pre_base.put(orphan_key, b"orphan payload")
    with Timer() as t:
        gc_report = pre.maintenance().gc_orphans(dry_run=True)
    assert orphan_key in gc_report.actions, "GC dry-run missed the orphan"
    assert pre_base.exists(orphan_key), "dry-run deleted!"
    lines.append(row("maintenance_gc_dryrun", t.elapsed * 1e6,
                     f"orphans{gc_report.details['orphans']}"
                     f"_live{gc_report.details['chunks_live']}"))
    with Timer() as t:
        pre.maintenance().compact_manifest()
    compacted_stats, _ = _cold_open_stats(pre_base)
    assert compacted_stats["requests"] <= COLD_OPEN_BUDGET
    lines.append(row("maintenance_compaction", t.elapsed * 1e6,
                     f"openreq{compacted_stats['requests']}"))
    io_report.record("maintenance_smoke", {
        "backfill": {"chunks_backfilled":
                     backfill.details["chunks_backfilled"],
                     "rows_pruned_after":
                     pruned_view.scan_plan["rows_pruned"]},
        "gc_dryrun": {k: gc_report.details[k]
                      for k in ("orphans", "chunks_live",
                                "bytes_reclaimable")},
        "compacted_cold_open": compacted_stats})
    return lines


if __name__ == "__main__":
    print("\n".join(main()))  # --smoke and full mode are identical here
