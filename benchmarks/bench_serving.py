"""Sharded query serving benchmark (PR-9): N concurrent clients on one
:class:`~repro.core.serving.QueryService` over simulated S3.

Four gates, all under ``--smoke`` in ``scripts/check.sh``:

(a) **same-query storm** — 8 concurrent clients issuing one committed
    query cost at most 2x a single client's provider requests
    (single-flight + versioned result cache collapse the storm);
(b) **distinct-query storm** — aggregate requests for 8 different
    queries on one shared service stay sublinear vs. 8 cold
    single-client runs (shared engine residency + one manifest open);
(c) **shard parity** — the shard-parallel scan's results are
    byte-identical to the ``stream=False`` legacy path;
(d) **cache hit** — a repeat query performs zero planner work
    (``tql.plans`` counter frozen) and zero storage requests.

A traced re-run must keep simulated IO seconds within 5% of the
untraced run and emit ``serve.*`` spans into the Chrome trace artifact
(``--trace-out``).  Each run records a ``serving`` datapoint in
``BENCH_io.json``.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import numpy as np

import repro.core as dl
from repro.core import telemetry
from repro.core.serving import QueryService
from repro.core.storage import MemoryProvider, SimulatedS3Provider

from . import io_report
from .common import Timer, row

N_CLIENTS = 8
Q_SAME = ("SELECT * FROM dataset WHERE MIN(val) > 1450 "
          "ORDER BY MEAN(val) DESC LIMIT 64")
#: distinct per-client thresholds with heavy chunk overlap: low-threshold
#: clients rescan the high-threshold clients' bands
Q_DISTINCT = [f"SELECT * FROM dataset WHERE MIN(val) > {100 * k}"
              for k in range(N_CLIENTS)]


def _build_base() -> MemoryProvider:
    """Clustered 4000-row fixture (same shape as the pushdown bench)."""
    rng = np.random.default_rng(7)
    base = MemoryProvider()
    ds = dl.Dataset(base)
    ds.create_tensor("val", dtype="float32", min_chunk_size=1 << 12,
                     max_chunk_size=1 << 13)
    for i in range(4000):
        band = i // 250
        ds.append({"val": (rng.standard_normal(16).astype(np.float32)
                           + np.float32(100 * band))})
    ds.commit("serving bench")
    return base


def _storm(svc: QueryService, queries: List[str]) -> tuple:
    """Run one query per thread; returns (results, per-client wall s)."""
    res: List = [None] * len(queries)
    lat = [0.0] * len(queries)
    errs: List[Exception] = []

    def client(i: int) -> None:
        t0 = time.perf_counter()
        try:
            res[i] = svc.query(queries[i], tenant=f"client{i}")
        except Exception as e:  # pragma: no cover - diagnostic
            errs.append(e)
        lat[i] = time.perf_counter() - t0

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(len(queries))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    if errs:
        raise errs[0]
    return res, lat


def main(smoke: bool = False, trace_out: Optional[str] = None) -> List[str]:
    lines: List[str] = []
    base = _build_base()
    datapoint: Dict[str, Dict[str, float]] = {}

    # ---------------------------------------------- (c) shard parity
    s3 = SimulatedS3Provider(base, time_scale=0.0)
    remote = dl.Dataset(s3)
    legacy = remote.query(Q_SAME, engine="numpy", stream=False)
    sharded = remote.query(Q_SAME, engine="numpy", shards=4)
    assert sharded.indices.tolist() == legacy.indices.tolist(), \
        "shard-parallel scan is not byte-identical to the legacy path"
    assert (sharded.topk_plan or {}).get("shards") == 4, \
        "sharded top-k plan missing"
    lines.append(row("serving_shard_parity", 0.0,
                     f"rows{len(sharded)}_shards4"))

    # ------------------------------------- single-client request baseline
    s3a = SimulatedS3Provider(base, time_scale=0.0)
    svc_a = QueryService(dl.Dataset(s3a), max_concurrent=N_CLIENTS, shards=2)
    s3a.reset_stats()
    with Timer() as t:
        one = svc_a.query(Q_SAME)
    req_one = s3a.stats["requests"]
    assert req_one > 0, "cold single-client query issued no requests"
    assert one.indices.tolist() == legacy.indices.tolist()
    lines.append(row("serving_single_client", t.elapsed * 1e6,
                     f"req{req_one}_sim{s3a.stats['sim_seconds']:.3f}"))
    datapoint["single_client"] = {"requests": req_one,
                                  "sim_seconds": s3a.stats["sim_seconds"]}

    # ------------------------------------------ (a) same-query storm x8
    s3b = SimulatedS3Provider(base, time_scale=0.0)
    svc_b = QueryService(dl.Dataset(s3b), max_concurrent=4, shards=2)
    s3b.reset_stats()
    with Timer() as t:
        res, lat = _storm(svc_b, [Q_SAME] * N_CLIENTS)
    for r in res:
        assert r.indices.tolist() == legacy.indices.tolist(), \
            "storm client diverged from the serial result"
    req_storm = s3b.stats["requests"]
    assert req_storm <= 2 * req_one, \
        (f"same-query storm cost {req_storm} requests "
         f"(> 2x single client's {req_one})")
    st = svc_b.stats()
    assert st["cache_misses"] == 1, "single-flight did not collapse the storm"
    lines.append(row(
        "serving_storm8_same", t.elapsed * 1e6,
        f"req{req_storm}_vs1client{req_one}_hits{st['cache_hits']}"
        f"_lat_mean_us{int(np.mean(lat) * 1e6)}"
        f"_lat_max_us{int(np.max(lat) * 1e6)}"))
    datapoint["storm8_same"] = {
        "clients": N_CLIENTS, "requests": req_storm,
        "cache_hits": st["cache_hits"], "cache_misses": st["cache_misses"],
        "latency_mean_s": float(np.mean(lat)),
        "latency_max_s": float(np.max(lat)),
        "sim_seconds": s3b.stats["sim_seconds"]}

    # ------------------------------------ (b) distinct-query storm x8
    # cold per-client baseline: each query on its own provider + service
    solo_total = 0
    expects = []
    for q in Q_DISTINCT:
        s3i = SimulatedS3Provider(base, time_scale=0.0)
        svc_i = QueryService(dl.Dataset(s3i))
        s3i.reset_stats()
        expects.append(svc_i.query(q).indices.tolist())
        solo_total += s3i.stats["requests"]
    s3c = SimulatedS3Provider(base, time_scale=0.0)
    svc_c = QueryService(dl.Dataset(s3c), max_concurrent=4, shards=2)
    s3c.reset_stats()
    with Timer() as t:
        res, lat = _storm(svc_c, Q_DISTINCT)
    for r, exp in zip(res, expects):
        assert r.indices.tolist() == exp, "distinct-storm client diverged"
    req_distinct = s3c.stats["requests"]
    assert req_distinct < solo_total, \
        (f"distinct-query storm is not sublinear: {req_distinct} shared "
         f"vs {solo_total} across cold single clients")
    lines.append(row(
        "serving_storm8_distinct", t.elapsed * 1e6,
        f"req{req_distinct}_vs_solo{solo_total}"
        f"_lat_mean_us{int(np.mean(lat) * 1e6)}"))
    datapoint["storm8_distinct"] = {
        "clients": N_CLIENTS, "requests": req_distinct,
        "solo_total_requests": solo_total,
        "latency_mean_s": float(np.mean(lat)),
        "sim_seconds": s3c.stats["sim_seconds"]}

    # ------------------------------------------------ (d) cache hit
    plans0 = telemetry.registry().snapshot().get("tql_plans", 0)
    s3b.reset_stats()
    with Timer() as t:
        again = svc_b.query(Q_SAME)
    assert again.indices.tolist() == legacy.indices.tolist()
    assert s3b.stats["requests"] == 0, \
        "repeat-query cache hit touched storage"
    assert telemetry.registry().snapshot().get("tql_plans", 0) == plans0, \
        "repeat-query cache hit re-ran the planner"
    lines.append(row("serving_cache_hit", t.elapsed * 1e6, "req0_plans0"))
    datapoint["cache_hit"] = {"requests": 0,
                              "latency_s": float(t.elapsed)}

    # -------------------------- tracing overhead + serve.* span artifact
    if smoke or trace_out:
        def traced_workload(provider) -> None:
            svc = QueryService(dl.Dataset(provider), max_concurrent=4,
                               shards=2)
            _storm(svc, [Q_SAME] * 4)
            # a full (stats-off) streamed WHERE guarantees the sharded
            # scan actually runs and emits serve.shard spans
            svc.query("SELECT * FROM dataset WHERE MIN(val) > 700",
                      use_stats=False)

        s3u = SimulatedS3Provider(base, time_scale=0.0)
        traced_workload(s3u)
        sim_u = s3u.stats["sim_seconds"]
        s3t = SimulatedS3Provider(base, time_scale=0.0)
        with telemetry.tracing() as tr:
            traced_workload(s3t)
        sim_t = s3t.stats["sim_seconds"]
        lines.append(row("serving_trace_overhead", abs(sim_t - sim_u) * 1e6,
                         f"untraced{sim_u:.3f}s_traced{sim_t:.3f}s"))
        assert abs(sim_t - sim_u) <= 0.05 * sim_u + 1e-6, (
            f"tracing perturbed serving IO: traced {sim_t:.6f}s vs "
            f"untraced {sim_u:.6f}s")
        for prefix in ("serve.admit", "serve.shard["):
            assert tr.count(prefix) > 0, \
                f"traced serving run produced no {prefix} spans"
        datapoint["trace"] = {
            "sim_untraced_s": sim_u, "sim_traced_s": sim_t,
            "serve_admit_spans": tr.count("serve.admit"),
            "serve_shard_spans": tr.count("serve.shard[")}
        if trace_out:
            tr.write_chrome(trace_out)
            lines.append(row("serving_trace_artifact", len(tr.events()),
                             trace_out))

    io_report.record("serving", datapoint)
    return lines


if __name__ == "__main__":
    import sys

    argv = sys.argv[1:]
    out = None
    if "--trace-out" in argv:
        out = argv[argv.index("--trace-out") + 1]
    print("\n".join(main(smoke="--smoke" in argv, trace_out=out)))
