"""TQL engine benchmark (§4.3): query latency, numpy engine vs XLA (jax)
delegation, and the fused-preprocess kernel as the device-side query tail."""

from __future__ import annotations

from typing import List

import numpy as np

import repro.core as dl

from .common import Timer, row


def main() -> List[str]:
    lines = []
    rng = np.random.default_rng(0)
    ds = dl.dataset()
    ds.create_tensor("v", dtype="float32", min_chunk_size=1 << 18,
                     max_chunk_size=1 << 20)
    ds.create_tensor("lab", htype="class_label")
    for i in range(4000):
        ds.append({"v": rng.standard_normal(64).astype(np.float32),
                   "lab": np.int64(i % 13)})
    ds.commit("bench")
    q = ("SELECT * FROM dataset WHERE MEAN(v) > 0.02 AND lab != 3 "
         "ORDER BY MEAN(v) DESC LIMIT 256")
    from repro.core.tql import execute_query
    execute_query(ds, q, engine="numpy")  # warm caches
    with Timer() as t:
        for _ in range(3):
            v1 = execute_query(ds, q, engine="numpy")
    lines.append(row("tql_numpy_engine", t.elapsed / 3 * 1e6,
                     f"rows{len(v1)}"))
    execute_query(ds, q, engine="jax")    # compile
    with Timer() as t:
        for _ in range(3):
            v2 = execute_query(ds, q, engine="jax")
    lines.append(row("tql_jax_engine", t.elapsed / 3 * 1e6,
                     f"rows{len(v2)}_match{int(np.array_equal(v1.indices, v2.indices))}"))

    lines.extend(_bench_stats_pushdown())

    # device-side tail: crop+normalize of a TQL projection, fused vs unfused
    import jax
    import jax.numpy as jnp
    from repro.kernels.fused_preprocess import fused_preprocess
    from repro.kernels.fused_preprocess.ref import ref_preprocess
    imgs = jnp.asarray(rng.integers(0, 255, (32, 128, 128, 3)), jnp.uint8)
    crop, mean, std = (16, 16, 96, 96), (0.5, 0.5, 0.5), (0.25, 0.25, 0.25)
    ref_jit = jax.jit(lambda x: ref_preprocess(x, crop, mean, std))
    jax.block_until_ready(ref_jit(imgs))
    with Timer() as t:
        for _ in range(10):
            jax.block_until_ready(ref_jit(imgs))
    lines.append(row("tql_postop_xla", t.elapsed / 10 * 1e6, "unfused"))
    jax.block_until_ready(fused_preprocess(imgs, crop, mean, std, True))
    with Timer() as t:
        jax.block_until_ready(fused_preprocess(imgs, crop, mean, std, True))
    lines.append(row("tql_postop_pallas_interp", t.elapsed * 1e6,
                     "fused_interpret_mode"))
    return lines


def _bench_stats_pushdown() -> List[str]:
    """Chunk-statistics pushdown over simulated S3: a selective WHERE must
    fetch far fewer chunk bytes/requests than the same query full-scanned."""
    from repro.core.storage import MemoryProvider, SimulatedS3Provider

    rng = np.random.default_rng(7)
    base = MemoryProvider()
    ds = dl.Dataset(base)
    # clustered values, small chunks: selectivity maps onto chunk boundaries
    ds.create_tensor("val", dtype="float32", min_chunk_size=1 << 12,
                     max_chunk_size=1 << 13)
    for i in range(4000):
        band = i // 250
        ds.append({"val": (rng.standard_normal(16).astype(np.float32)
                           + np.float32(100 * band))})
    ds.commit("pushdown bench")
    q = "SELECT * FROM dataset WHERE MIN(val) > 1450"  # last ~1/16 of bands

    lines = []
    results = {}
    for label, use_stats in (("fullscan", False), ("stats_pushdown", True)):
        s3 = SimulatedS3Provider(base, time_scale=0.0)
        remote = dl.Dataset(s3)  # fresh open: no header/chunk caches
        s3.reset_stats()
        with Timer() as t:
            view = remote.query(q, engine="numpy", use_stats=use_stats)
        results[label] = (len(view), dict(s3.stats))
        lines.append(row(f"tql_{label}_s3", t.elapsed * 1e6,
                         f"rows{len(view)}_req{s3.stats['requests']}"
                         f"_down{s3.stats['bytes_down']}"))
    n_full, full = results["fullscan"]
    n_push, push = results["stats_pushdown"]
    assert n_full == n_push, "pushdown changed the result set"
    assert push["bytes_down"] < full["bytes_down"], \
        "pushdown did not reduce bytes fetched"
    lines.append(row(
        "tql_pushdown_savings", 0.0,
        f"req{full['requests']}to{push['requests']}"
        f"_bytes{full['bytes_down']}to{push['bytes_down']}"))
    return lines


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:  # pushdown datapoint only (no jax warm-up)
        print("\n".join(_bench_stats_pushdown()))
    else:
        print("\n".join(main()))
