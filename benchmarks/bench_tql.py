"""TQL engine benchmark (§4.3): query latency, numpy engine vs XLA (jax)
delegation, and the fused-preprocess kernel as the device-side query tail."""

from __future__ import annotations

from typing import List

import numpy as np

import repro.core as dl

from .common import Timer, row


def main() -> List[str]:
    lines = []
    rng = np.random.default_rng(0)
    ds = dl.dataset()
    ds.create_tensor("v", dtype="float32", min_chunk_size=1 << 18,
                     max_chunk_size=1 << 20)
    ds.create_tensor("lab", htype="class_label")
    for i in range(4000):
        ds.append({"v": rng.standard_normal(64).astype(np.float32),
                   "lab": np.int64(i % 13)})
    ds.commit("bench")
    q = ("SELECT * FROM dataset WHERE MEAN(v) > 0.02 AND lab != 3 "
         "ORDER BY MEAN(v) DESC LIMIT 256")
    from repro.core.tql import execute_query
    execute_query(ds, q, engine="numpy")  # warm caches
    with Timer() as t:
        for _ in range(3):
            v1 = execute_query(ds, q, engine="numpy")
    lines.append(row("tql_numpy_engine", t.elapsed / 3 * 1e6,
                     f"rows{len(v1)}"))
    execute_query(ds, q, engine="jax")    # compile
    with Timer() as t:
        for _ in range(3):
            v2 = execute_query(ds, q, engine="jax")
    lines.append(row("tql_jax_engine", t.elapsed / 3 * 1e6,
                     f"rows{len(v2)}_match{int(np.array_equal(v1.indices, v2.indices))}"))

    lines.extend(_bench_stats_pushdown())

    # device-side tail: crop+normalize of a TQL projection, fused vs unfused
    import jax
    import jax.numpy as jnp
    from repro.kernels.fused_preprocess import fused_preprocess
    from repro.kernels.fused_preprocess.ref import ref_preprocess
    imgs = jnp.asarray(rng.integers(0, 255, (32, 128, 128, 3)), jnp.uint8)
    crop, mean, std = (16, 16, 96, 96), (0.5, 0.5, 0.5), (0.25, 0.25, 0.25)
    ref_jit = jax.jit(lambda x: ref_preprocess(x, crop, mean, std))
    jax.block_until_ready(ref_jit(imgs))
    with Timer() as t:
        for _ in range(10):
            jax.block_until_ready(ref_jit(imgs))
    lines.append(row("tql_postop_xla", t.elapsed / 10 * 1e6, "unfused"))
    jax.block_until_ready(fused_preprocess(imgs, crop, mean, std, True))
    with Timer() as t:
        jax.block_until_ready(fused_preprocess(imgs, crop, mean, std, True))
    lines.append(row("tql_postop_pallas_interp", t.elapsed * 1e6,
                     "fused_interpret_mode"))
    return lines


def _bench_stats_pushdown() -> List[str]:
    """Chunk-statistics pushdown + coalesced batch I/O over simulated S3.

    Three configurations of the same selective query:

    * ``fullscan``            — no stats pushdown, coalesced fetches;
    * ``pushdown_persample``  — pushdown with coalescing disabled: one
      ranged request per sample, the pre-batching (PR-1) request pattern;
    * ``pushdown_coalesced``  — pushdown + the batch I/O engine: at most
      one coalesced request per chunk per tensor.

    All three must return identical rows; coalescing must cut the
    request count of the per-sample baseline at least 3x.  Each run
    records a BENCH_io.json datapoint (requests, coalesced requests,
    bytes, simulated seconds) so the perf trajectory is tracked.
    """
    from repro.core import fetch
    from repro.core.storage import MemoryProvider, SimulatedS3Provider

    from . import io_report

    rng = np.random.default_rng(7)
    base = MemoryProvider()
    ds = dl.Dataset(base)
    # clustered values, small chunks: selectivity maps onto chunk boundaries
    ds.create_tensor("val", dtype="float32", min_chunk_size=1 << 12,
                     max_chunk_size=1 << 13)
    for i in range(4000):
        band = i // 250
        ds.append({"val": (rng.standard_normal(16).astype(np.float32)
                           + np.float32(100 * band))})
    ds.commit("pushdown bench")
    q = "SELECT * FROM dataset WHERE MIN(val) > 1450"  # last ~1/16 of bands

    lines = []
    results = {}
    configs = (("fullscan", False, True),
               ("pushdown_persample", True, False),
               ("pushdown_coalesced", True, True))
    for label, use_stats, use_coalescing in configs:
        s3 = SimulatedS3Provider(base, time_scale=0.0)
        remote = dl.Dataset(s3)  # fresh open: no header/chunk caches
        s3.reset_stats()
        if use_coalescing:
            with Timer() as t:
                view = remote.query(q, engine="numpy", use_stats=use_stats)
        else:
            with fetch.coalescing_disabled(), Timer() as t:
                view = remote.query(q, engine="numpy", use_stats=use_stats)
        # snapshot now — before the next config's provider churn — so the
        # datapoint keeps the full counter set (incl. batched_ranges)
        stats = io_report.provider_snapshot(s3)
        results[label] = (len(view), stats)
        lines.append(row(f"tql_{label}_s3", t.elapsed * 1e6,
                         f"rows{len(view)}_req{stats['requests']}"
                         f"_coal{stats['coalesced_requests']}"
                         f"_batched{stats['batched_ranges']}"
                         f"_down{stats['bytes_down']}"
                         f"_sim{stats['sim_seconds']:.3f}"))
    n_full, full = results["fullscan"]
    n_per, per = results["pushdown_persample"]
    n_coal, coal = results["pushdown_coalesced"]
    assert n_full == n_per == n_coal, "configs disagree on the result set"
    assert coal["bytes_down"] < full["bytes_down"], \
        "pushdown did not reduce bytes fetched"
    assert coal["requests"] * 3 <= per["requests"], \
        (f"coalescing gained <3x on requests: "
         f"{per['requests']} -> {coal['requests']}")
    io_report.record("tql_selective_query", {
        label: stats for label, (_n, stats) in results.items()})
    lines.append(row(
        "tql_pushdown_savings", 0.0,
        f"req{per['requests']}to{coal['requests']}"
        f"_bytes{full['bytes_down']}to{coal['bytes_down']}"
        f"_sim{per['sim_seconds']:.3f}to{coal['sim_seconds']:.3f}"))
    lines.extend(_bench_topk_membership())
    lines.extend(_bench_sparse_coalescing())
    return lines


def _bench_topk_membership() -> List[str]:
    """Top-k + membership pushdown over simulated S3 (the PR-5 datapoint).

    Same clustered selective dataset shape as the pushdown bench, plus a
    gapped ``class_label`` column (even values only).  Two gates:

    * ``ORDER BY x LIMIT 8``: the top-k plan streams chunk groups
      best-bound-first and terminates on the k-th-element cutoff; its
      request count must be **≤ half** the legacy whole-column sort's
      (which fetches every chunk group), results byte-identical;
    * ``lab == odd`` / ``lab IN [odds]``: the membership sketches prune
      every chunk — **zero** payload requests, the verdict rides in the
      manifest's column-statistics section from the cold open.
    """
    from repro.core.storage import MemoryProvider, SimulatedS3Provider

    from . import io_report

    rng = np.random.default_rng(9)
    base = MemoryProvider()
    ds = dl.Dataset(base)
    ds.create_tensor("x", dtype="float32", min_chunk_size=1 << 12,
                     max_chunk_size=1 << 13)
    ds.create_tensor("lab", htype="class_label", min_chunk_size=256,
                     max_chunk_size=512)
    for i in range(4000):
        band = i // 250
        ds.append({"x": (rng.standard_normal(16).astype(np.float32)
                         + np.float32(100 * band)),
                   "lab": np.int64(band * 2)})      # evens: odds are gaps
    ds.commit("topk bench")

    q_topk = "SELECT * FROM dataset ORDER BY MEAN(x) DESC LIMIT 8"
    lines = []
    results = {}
    for label, stream in (("topk_legacy", False), ("topk_pushdown", None)):
        s3 = SimulatedS3Provider(base, time_scale=0.0)
        remote = dl.Dataset(s3)
        s3.reset_stats()
        with Timer() as t:
            view = remote.query(q_topk, engine="numpy", stream=stream)
        stats = io_report.provider_snapshot(s3)
        results[label] = (view, stats)
        plan = view.topk_plan or {}
        lines.append(row(f"tql_{label}_s3", t.elapsed * 1e6,
                         f"rows{len(view)}_req{stats['requests']}"
                         f"_down{stats['bytes_down']}"
                         f"_skip{plan.get('groups_skipped', 0)}"))
    legacy_view, legacy = results["topk_legacy"]
    topk_view, topk = results["topk_pushdown"]
    assert topk_view.indices.tolist() == legacy_view.indices.tolist(), \
        "top-k pushdown changed the result set"
    assert topk_view.topk_plan is not None \
        and topk_view.topk_plan["groups_skipped"] > 0, \
        "top-k plan did not skip any chunk group"
    assert topk["requests"] * 2 <= legacy["requests"], \
        (f"top-k gained <2x on requests: "
         f"{legacy['requests']} -> {topk['requests']}")

    # membership: odd labels exist in no chunk -> sketches prune everything
    member = {}
    for label, qm in (("eq", "SELECT * FROM dataset WHERE lab == 3"),
                      ("in", "SELECT * FROM dataset WHERE lab IN [3, 5]")):
        s3 = SimulatedS3Provider(base, time_scale=0.0)
        remote = dl.Dataset(s3)
        s3.reset_stats()
        view = remote.query(qm, engine="numpy")
        stats = io_report.provider_snapshot(s3)
        assert len(view) == 0, f"{qm}: expected an empty result"
        assert stats["requests"] == 0, \
            f"{qm}: sketch pruning fetched payloads ({stats['requests']})"
        assert view.scan_plan["rows_verify"] == 0, \
            f"{qm}: sketches left verify rows"
        member[f"membership_{label}"] = stats
        lines.append(row(f"tql_membership_{label}_s3", 0.0,
                         f"rows0_req{stats['requests']}"))
    io_report.record("tql_topk_membership", {
        "topk_legacy": legacy, "topk_pushdown": topk, **member})
    lines.append(row(
        "tql_topk_savings", 0.0,
        f"req{legacy['requests']}to{topk['requests']}"
        f"_skip{topk_view.topk_plan['groups_skipped']}"
        f"of{topk_view.topk_plan['groups']}"))
    return lines


def _bench_sparse_coalescing() -> List[str]:
    """Sparse clustered reads over large chunks: the regime where the batch
    engine answers with coalesced ranged requests instead of full GETs.

    Guards the provider's coalescing counters end-to-end: the recorded
    datapoint must show ranges *merged* (batched_ranges > coalesced
    physical spans > 0) — the stats that earlier io_report revisions
    silently dropped as zeros.
    """
    from repro.core import fetch
    from repro.core.storage import MemoryProvider, SimulatedS3Provider

    from . import io_report

    rng = np.random.default_rng(5)
    base = MemoryProvider()
    ds = dl.Dataset(base)
    # ~500 rows of 4KB per 2MB chunk; low-latency link so the cost model
    # prefers ranged reads over whole-chunk GETs
    ds.create_tensor("v", dtype="float32", min_chunk_size=1 << 20,
                     max_chunk_size=1 << 21)
    for _ in range(2000):
        ds.append({"v": rng.standard_normal(1024).astype(np.float32)})
    ds.commit("sparse fixture")
    s3 = SimulatedS3Provider(base, time_scale=0.0, latency_s=0.0002,
                             bandwidth_bps=200e6)
    remote = dl.Dataset(s3)
    engine = fetch.engine_for(s3)
    rows_idx = [i + d for i in range(0, 2000, 40) for d in (0, 1)]
    s3.reset_stats()
    # locked snapshot, not dict(engine.stats): the engine's prefetch worker
    # may be mutating the stats dict concurrently
    eng_before = engine.stats_snapshot()
    with Timer() as t:
        out = remote.v.read_batch(rows_idx)
    assert len(out) == len(rows_idx)
    stats = io_report.provider_snapshot(s3)
    eng_after = engine.stats_snapshot()
    eng_delta = {k: eng_after[k] - eng_before.get(k, 0)
                 for k in ("requests", "ranges")}
    # the engine pre-merges adjacent sample ranges, so the provider sees
    # fewer physical spans than the engine saw logical ranges — exactly
    # the counters earlier io_report revisions dropped as zeros
    assert stats["coalesced_requests"] > 0, "sparse reads stopped coalescing"
    assert stats["requests"] < len(rows_idx), \
        "coalescing no longer beats one-request-per-sample"
    assert eng_delta["ranges"] > eng_delta["requests"] > 0, \
        "adjacent ranges were not merged into shared spans"
    io_report.record("sparse_batch_read",
                     {"coalesced": stats, "engine": eng_delta})
    lines = [row("tql_sparse_batch_read_s3", t.elapsed * 1e6,
                 f"req{stats['requests']}"
                 f"_coal{stats['coalesced_requests']}"
                 f"_ranges{eng_delta['ranges']}"
                 f"_down{stats['bytes_down']}")]
    lines.extend(_bench_tile_fanout())
    return lines


def _bench_tile_fanout() -> List[str]:
    """Multi-object batching on the tiled-sample read path (PR-9).

    A sample larger than ``max_chunk_size`` is stored as a fan-out of
    tile chunks; reading it used to issue one GET per tile.  With
    ``provider.get_many`` the whole fan-out goes out as ONE batched
    round.  Gate: batching must cut the provider request count of the
    per-object baseline by at least 3x, byte-identical samples.
    """
    from repro.core import fetch
    from repro.core.storage import MemoryProvider, SimulatedS3Provider

    from . import io_report

    rng = np.random.default_rng(13)
    base = MemoryProvider()
    ds = dl.Dataset(base)
    # ~576 KB samples over <=64 KB chunks: ~10-tile fan-out per read
    ds.create_tensor("img", dtype="uint8", min_chunk_size=1 << 15,
                     max_chunk_size=1 << 16)
    expect = []
    for _ in range(4):
        a = rng.integers(0, 255, (768, 768), dtype=np.uint8)
        expect.append(a)
        ds.append({"img": a})
    ds.commit("tile fixture")

    lines, results = [], {}
    for label, batched in (("tile_perobject", False),
                           ("tile_batched", True)):
        s3 = SimulatedS3Provider(base, time_scale=0.0)
        remote = dl.Dataset(s3)
        s3.reset_stats()
        if batched:
            with Timer() as t:
                got = [remote.img.read(i) for i in range(4)]
        else:
            with fetch.coalescing_disabled(), Timer() as t:
                got = [remote.img.read(i) for i in range(4)]
        for a, b in zip(expect, got):
            assert np.array_equal(a, b), "tiled read changed bytes"
        stats = io_report.provider_snapshot(s3)
        results[label] = stats
        lines.append(row(f"tql_{label}_s3", t.elapsed * 1e6,
                         f"req{stats['requests']}"
                         f"_batched{stats['batched_objects']}"
                         f"_down{stats['bytes_down']}"))
    per, bat = results["tile_perobject"], results["tile_batched"]
    assert bat["batched_objects"] > 0, "tile reads never used get_many"
    assert bat["requests"] * 3 <= per["requests"], \
        (f"tile batching gained <3x on requests: "
         f"{per['requests']} -> {bat['requests']}")
    io_report.record("tile_fanout", results)
    lines.append(row("tql_tile_fanout_savings", 0.0,
                     f"req{per['requests']}to{bat['requests']}"))
    lines.extend(_bench_aggregation_pushdown())
    return lines


def _bench_aggregation_pushdown() -> List[str]:
    """GROUP BY / aggregate pushdown over simulated S3 (the PR-10 datapoint).

    Same clustered fixture shape as the pushdown bench.  Two gates:

    * ungrouped ``COUNT()/SUM/MIN/MAX/AVG`` over a committed dataset with
      full statistics is answered entirely from the manifest's chunk
      records: **zero** payload requests beyond the cold open, every
      chunk group stats-answered;
    * ``GROUP BY lab`` with single-tensor aggregates: interior chunks are
      single-valued (dictionary sketch answers them), only band-boundary
      chunks fetch+fold — the streamed aggregate's request count must be
      **strictly below** the legacy whole-view fold's (``stream=False``),
      with value-identical group rows (int sums are exact on both paths).
    """
    from repro.core.storage import MemoryProvider, SimulatedS3Provider

    from . import io_report

    rng = np.random.default_rng(11)
    base = MemoryProvider()
    ds = dl.Dataset(base)
    ds.create_tensor("val", dtype="float32", min_chunk_size=1 << 12,
                     max_chunk_size=1 << 13)
    ds.create_tensor("lab", htype="class_label", min_chunk_size=256,
                     max_chunk_size=512)
    for i in range(4000):
        band = i // 247  # NOT a multiple of the chunk row capacity: band
        ds.append({       # boundaries straddle chunks, so some groups fold
            "val": (rng.standard_normal(16).astype(np.float32)
                    + np.float32(100 * band)),
            "lab": np.int64(band)})
    ds.commit("aggregation bench")

    lines = []
    # gate 1: ungrouped aggregate, stats-only — zero payload requests
    s3 = SimulatedS3Provider(base, time_scale=0.0)
    remote = dl.Dataset(s3)
    s3.reset_stats()
    q_scalar = ("SELECT COUNT() AS c, SUM(val) AS s, MIN(val) AS mn, "
                "MAX(val) AS mx, AVG(val) AS av FROM dataset")
    with Timer() as t:
        view = remote.query(q_scalar, engine="numpy")
    scalar = io_report.provider_snapshot(s3)
    plan = view.scan_plan
    assert view.derived["c"][0] == 4000
    assert s3.stats["requests"] == 0, \
        f"stats-only aggregate fetched payloads ({s3.stats['requests']})"
    assert plan["agg_groups_stats_answered"] == plan["agg_groups"] > 0, \
        f"aggregate groups fell back to fold: {plan}"
    lines.append(row("tql_agg_scalar_s3", t.elapsed * 1e6,
                     f"groups{plan['agg_groups']}"
                     f"_statsanswered{plan['agg_groups_stats_answered']}"
                     f"_req{scalar['requests']}"))

    # gate 2: grouped streaming vs legacy whole-view fold
    q_group = ("SELECT lab, COUNT() AS c, SUM(lab) AS s, AVG(lab) AS av "
               "FROM dataset GROUP BY lab")
    results = {}
    for label, stream in (("agg_legacy", False), ("agg_streamed", None)):
        s3 = SimulatedS3Provider(base, time_scale=0.0)
        remote = dl.Dataset(s3)
        s3.reset_stats()
        with Timer() as t:
            gv = remote.query(q_group, engine="numpy", stream=stream)
        stats = io_report.provider_snapshot(s3)
        results[label] = (gv, stats)
        plan = gv.scan_plan or {}
        lines.append(row(f"tql_{label}_s3", t.elapsed * 1e6,
                         f"groups{len(gv)}_req{stats['requests']}"
                         f"_statsanswered"
                         f"{plan.get('agg_groups_stats_answered', 0)}"
                         f"_down{stats['bytes_down']}"))
    legacy_view, legacy = results["agg_legacy"]
    stream_view, streamed = results["agg_streamed"]
    for col in ("lab", "c", "s", "av"):
        assert list(stream_view.derived[col]) == list(legacy_view.derived[col]), \
            f"streamed aggregation changed column {col!r}"
    assert stream_view.scan_plan["agg_groups_stats_answered"] > 0, \
        "no grouped chunk was answered from the dictionary sketch"
    assert streamed["requests"] < legacy["requests"], \
        (f"grouped aggregation pushdown gained nothing on requests: "
         f"{legacy['requests']} -> {streamed['requests']}")
    io_report.record("aggregation_pushdown", {
        "scalar_stats_only": scalar, "grouped_legacy": legacy,
        "grouped_streamed": streamed})
    lines.append(row(
        "tql_agg_pushdown_savings", 0.0,
        f"req{legacy['requests']}to{streamed['requests']}"
        f"_statsanswered{stream_view.scan_plan['agg_groups_stats_answered']}"
        f"of{stream_view.scan_plan['agg_groups']}"))
    return lines


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:  # pushdown datapoint only (no jax warm-up)
        print("\n".join(_bench_stats_pushdown()))
    else:
        print("\n".join(main()))
