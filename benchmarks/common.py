"""Shared benchmark plumbing: dataset builders + a 'file storage' baseline
(one compressed object per sample — the paper's raw-JPEG-files layout; zlib
stands in for JPEG since no libjpeg ships offline)."""

from __future__ import annotations

import io
import time
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro.core as dl


def make_images(n: int, hw: Tuple[int, int], seed: int = 0) -> List[np.ndarray]:
    """Smooth random fields: compress like photos (pure noise wouldn't)."""
    rng = np.random.default_rng(seed)
    h, w = hw
    out = []
    for _ in range(n):
        base = rng.integers(0, 255, (h // 16 + 2, w // 16 + 2, 3)).astype(
            np.float32)
        img = np.kron(base, np.ones((16, 16, 1)))[:h, :w]
        img = (img + np.linspace(0, 30, w)[None, :, None]) % 255
        out.append(img.astype(np.uint8))
    return out


def file_store_write(provider: dl.StorageProvider, images: List[np.ndarray],
                     labels: Optional[List[int]] = None) -> None:
    """Baseline layout: one compressed (JPEG-class) object per sample."""
    for i, img in enumerate(images):
        provider.put(f"files/img_{i:06d}.z",
                     zlib.compress(img.tobytes(), 1))
        provider.put(f"files/img_{i:06d}.meta",
                     np.asarray(img.shape, np.int32).tobytes())
        if labels is not None:
            provider.put(f"files/img_{i:06d}.txt", str(labels[i]).encode())


def file_store_read(provider: dl.StorageProvider, i: int) -> np.ndarray:
    shape = np.frombuffer(provider.get(f"files/img_{i:06d}.meta"), np.int32)
    raw = provider.get(f"files/img_{i:06d}.z")
    return np.frombuffer(zlib.decompress(raw), np.uint8).reshape(shape)


def build_lake(images: List[np.ndarray], *, codec: str,
               storage: Optional[dl.StorageProvider] = None,
               chunk_mb: float = 8.0) -> dl.Dataset:
    ds = dl.Dataset(storage)
    c = int(chunk_mb * (1 << 20))
    ds.create_tensor("images", htype="image", dtype="uint8",
                     sample_compression=codec, min_chunk_size=c // 2,
                     max_chunk_size=c)
    ds.create_tensor("labels", htype="class_label")
    for i, img in enumerate(images):
        ds.append({"images": img, "labels": np.int64(i % 10)})
    ds.commit("bench")
    return ds


@dataclass
class Timer:
    t0: float = 0.0
    elapsed: float = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
