"""BENCH_io.json plumbing: I/O-efficiency datapoints + validation.

Benchmarks that exercise the coalesced batch I/O engine
(:mod:`repro.core.fetch`) append before/after datapoints here so the perf
trajectory (request counts, coalesced-request counts, bytes, simulated
seconds) is tracked across PRs.  ``scripts/check.sh`` runs
``python -m benchmarks.io_report --validate`` after the bench smoke and
fails on a malformed file.

File layout (repo root ``BENCH_io.json``)::

    {"schema": 1,
     "benches": {
        "<bench name>": [            # newest last, capped history
            {"ts": <unix seconds>, "<label>": {<numeric stats>}, ...},
        ]}}

Every leaf value except "ts" must be a number or a flat dict of numbers.

Provider stat snapshots
-----------------------

Benches must record provider stats through :func:`provider_snapshot`,
taken immediately after the measured section and *before* any
``reset_stats()`` — earlier revisions hand-picked stat keys at record
time, which silently dropped ``batched_ranges`` from every datapoint and
recorded zeros for sections whose stats had already been reset.  The
snapshot copies every numeric counter the provider exposes, so new
provider stats (``batched_ranges``, ``cas_requests``, ...) appear in
``BENCH_io.json`` automatically.

Since the telemetry PR this function is a thin alias for
:func:`repro.core.telemetry.provider_snapshot` — the registry-backed
unified snapshot every bench shares (provider keys verbatim, engine keys
``engine_``-prefixed; historical key names unchanged).  Process-wide
counters that are not tied to one provider (``commit_*``,
``storage_wasted_upload_bytes``) live in
``repro.core.telemetry.registry().snapshot()``.

``validate`` additionally checks the ``stall_attribution`` section the
fig6 bench records: every cause a number, and the causes summing to
``total_s`` within 5% (+1e-6 absolute slack for zero-stall runs).
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                    "BENCH_io.json")
SCHEMA = 1
MAX_HISTORY = 20


def provider_snapshot(provider) -> Dict[str, float]:
    """Point-in-time copy of a cost-bearing provider's stats counters,
    plus the fetch-engine counters of any engine whose chain reaches this
    provider (``engine_`` prefix: ``engine_prefetch_hits``,
    ``engine_prefetch_wasted_bytes``, ...) so prefetch efficacy is
    visible in ``BENCH_io.json`` next to the request counts.

    Take it right after the measured section, before the provider is
    reused or ``reset_stats()`` runs; the copy is safe to record later.

    Delegates to the unified registry-backed snapshot in
    :mod:`repro.core.telemetry` so every bench shares one API.
    """
    from repro.core import telemetry
    return telemetry.provider_snapshot(provider)


def record(bench: str, datapoint: Dict[str, dict], path: str = PATH) -> None:
    """Append one datapoint to ``bench``'s history (atomic rewrite)."""
    doc = {"schema": SCHEMA, "benches": {}}
    if os.path.exists(path):
        try:
            with open(path) as f:
                loaded = json.load(f)
            if isinstance(loaded, dict) and loaded.get("schema") == SCHEMA:
                doc = loaded
                doc.setdefault("benches", {})
        except (OSError, ValueError):
            pass  # corrupt file: start fresh rather than fail the bench
    hist = doc["benches"].setdefault(bench, [])
    entry = dict(datapoint)
    entry["ts"] = round(time.time(), 3)
    hist.append(entry)
    del hist[:-MAX_HISTORY]
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def _leaf_errors(prefix: str, value) -> List[str]:
    if isinstance(value, bool) or not isinstance(value, (int, float, dict)):
        return [f"{prefix}: expected number or dict of numbers, "
                f"got {type(value).__name__}"]
    if isinstance(value, dict):
        errs = []
        for k, v in value.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                errs.append(f"{prefix}.{k}: expected number, "
                            f"got {type(v).__name__}")
        return errs
    return []


#: attribution-completeness tolerance: causes must sum to total_s within
#: this relative fraction (plus a tiny absolute slack for ~zero stalls)
STALL_ATTRIBUTION_TOL = 0.05


def _stall_attribution_errors(name: str, i: int, sa) -> List[str]:
    prefix = f"{name}[{i}].stall_attribution"
    if not isinstance(sa, dict):
        return [f"{prefix}: expected object, got {type(sa).__name__}"]
    errs = _leaf_errors(prefix, sa)
    if errs:
        return errs
    total = sa.get("total_s")
    if not isinstance(total, (int, float)):
        return [f"{prefix}: missing numeric 'total_s'"]
    causes = sum(v for k, v in sa.items() if k != "total_s")
    if abs(causes - total) > STALL_ATTRIBUTION_TOL * abs(total) + 1e-6:
        return [f"{prefix}: causes sum to {causes:.6f} but total_s is "
                f"{total:.6f} (tolerance {STALL_ATTRIBUTION_TOL:.0%})"]
    return []


def validate(path: str = PATH) -> List[str]:
    """Structural checks; returns a list of human-readable errors."""
    if not os.path.exists(path):
        return [f"{path} does not exist (run `python -m benchmarks.bench_tql "
                f"--smoke` to produce it)"]
    try:
        with open(path) as f:
            doc = json.load(f)
    except ValueError as e:
        return [f"not valid JSON: {e}"]
    if not isinstance(doc, dict) or doc.get("schema") != SCHEMA:
        return [f"missing or wrong schema marker (want {SCHEMA})"]
    benches = doc.get("benches")
    if not isinstance(benches, dict) or not benches:
        return ["'benches' must be a non-empty object"]
    errors: List[str] = []
    for name, hist in benches.items():
        if not isinstance(hist, list) or not hist:
            errors.append(f"{name}: history must be a non-empty list")
            continue
        for i, entry in enumerate(hist):
            if not isinstance(entry, dict):
                errors.append(f"{name}[{i}]: datapoint must be an object")
                continue
            if not isinstance(entry.get("ts"), (int, float)):
                errors.append(f"{name}[{i}]: missing numeric 'ts'")
            for k, v in entry.items():
                if k == "ts":
                    continue
                if k == "stall_attribution":
                    errors.extend(_stall_attribution_errors(name, i, v))
                    continue
                errors.extend(_leaf_errors(f"{name}[{i}].{k}", v))
        # the fig6 bench must carry the stall-attribution section going
        # forward: require it on the newest entry (older history may predate
        # the telemetry layer)
        if name == "fig6_streaming_train" and isinstance(hist[-1], dict) \
                and "stall_attribution" not in hist[-1]:
            errors.append(f"{name}[-1]: missing 'stall_attribution' section")
    return errors


def main(argv: List[str]) -> int:
    if "--validate" in argv:
        errors = validate()
        if errors:
            print("BENCH_io.json INVALID:")
            for e in errors:
                print(f"  - {e}")
            return 1
        with open(PATH) as f:
            doc = json.load(f)
        n = sum(len(h) for h in doc["benches"].values())
        print(f"BENCH_io.json ok: {len(doc['benches'])} benches, "
              f"{n} datapoints")
        return 0
    print(__doc__)
    return 2


if __name__ == "__main__":
    import sys

    sys.exit(main(sys.argv[1:]))
