"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (bench_chaos, bench_fig5_formats,
                   bench_fig6_streaming_train, bench_fig7_utilization,
                   bench_kernels, bench_tql)
    modules = [
        ("fig5_formats", bench_fig5_formats),
        ("fig6_streaming_train", bench_fig6_streaming_train),
        ("fig7_utilization", bench_fig7_utilization),
        ("tql", bench_tql),
        ("kernels", bench_kernels),
        ("chaos", bench_chaos),
    ]
    print("name,us_per_call,derived")
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            for line in mod.main():
                print(line, flush=True)
        except Exception as e:  # keep the harness running
            print(f"{name},ERROR,{type(e).__name__}:{e}", flush=True)
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
