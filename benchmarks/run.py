"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

``--trace-out PATH`` enables span tracing for the whole harness and dumps
one Chrome ``trace_event`` JSON artifact (load in chrome://tracing or
Perfetto) covering every bench's spans.
"""

from __future__ import annotations

import sys
import time


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    trace_out = None
    if "--trace-out" in argv:
        trace_out = argv[argv.index("--trace-out") + 1]

    from repro.core import telemetry

    from . import (bench_chaos, bench_fig5_formats,
                   bench_fig6_streaming_train, bench_fig7_utilization,
                   bench_kernels, bench_tql)
    modules = [
        ("fig5_formats", bench_fig5_formats),
        ("fig6_streaming_train", bench_fig6_streaming_train),
        ("fig7_utilization", bench_fig7_utilization),
        ("tql", bench_tql),
        ("kernels", bench_kernels),
        ("chaos", bench_chaos),
    ]
    tracer = telemetry.get_tracer()
    if trace_out:
        tracer.clear()
        tracer.start()
    try:
        print("name,us_per_call,derived")
        for name, mod in modules:
            t0 = time.perf_counter()
            try:
                for line in mod.main():
                    print(line, flush=True)
            except Exception as e:  # keep the harness running
                print(f"{name},ERROR,{type(e).__name__}:{e}", flush=True)
            print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
                  file=sys.stderr)
    finally:
        if trace_out:
            tracer.stop()
            tracer.write_chrome(trace_out)
            print(f"# wrote {len(tracer.events())} spans to {trace_out}",
                  file=sys.stderr)


if __name__ == "__main__":
    main()
