"""Quickstart: the full Deep Lake ML loop in one script.

Create a dataset -> version it -> query it with TQL -> stream it ->
visualize a row.  Runs in seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import repro.core as dl
from repro.core.visualize import plan_layout, render_ascii


def main():
    rng = np.random.default_rng(0)

    # 1. create + ingest -----------------------------------------------------
    ds = dl.dataset()  # in-memory; pass "file:///tmp/lake" or s3sim:// too
    ds.create_tensor("images", htype="image", dtype="uint8",
                     sample_compression="quant8")
    ds.create_tensor("labels", htype="class_label")
    ds.create_tensor("boxes", htype="bbox", strict=False)
    for i in range(200):
        ds.append({
            "images": rng.integers(0, 255, (48, 48, 3), dtype=np.uint8),
            "labels": np.int64(i % 5),
            "boxes": rng.uniform(0, 48, (2, 4)).astype(np.float32),
        })
    print(ds.summary())

    # 2. version control ------------------------------------------------------
    first = ds.commit("initial 200 rows")
    ds.checkout("relabel", create=True)
    ds.labels[0] = np.int64(4)
    ds.commit("fix label 0")
    ds.checkout("main")
    ds.merge("relabel")
    print(f"\nbranches: {ds.branches}; label[0] after merge: {int(ds.labels[0])}")
    old = ds.tensor_at("labels", first)
    print(f"time travel: label[0] at {first[:8]} was {int(old.read(0))}")

    # 3. TQL -------------------------------------------------------------------
    view = ds.query("""
        SELECT images[8:40, 8:40, :] AS crop, labels
        FROM dataset
        WHERE labels == 4 AND MEAN(images) > 100
        ORDER BY MEAN(images) DESC
        LIMIT 32
    """)
    print(f"\nTQL view: {len(view)} rows; crop shape "
          f"{view.row(0)['crop'].shape}")

    # 4. stream ---------------------------------------------------------------
    loader = view.dataloader(batch_size=8, shuffle=True, num_workers=4)
    for batch in loader:
        pass
    print(f"streamed {loader.stats.samples} samples at "
          f"{loader.stats.throughput():.0f} samples/s")

    # 5. visualize -------------------------------------------------------------
    print("\nlayout:", [(p.primary, p.overlays) for p in plan_layout(ds)])
    print(render_ascii(ds, 0, width=40))


if __name__ == "__main__":
    main()
