"""Fault tolerance demo: a training run that survives two injected host
failures by restoring from async checkpoints (stored as Deep Lake commits),
with straggler detection active.

    PYTHONPATH=src python examples/resilient_training.py
"""

import dataclasses

import repro.core as dl
from repro.checkpoint import CheckpointManager
from repro.distributed import run_resilient
from repro.launch.train import Trainer, TrainJob


def main():
    job = TrainJob(arch="starcoder2-3b", smoke=True, steps=24, global_batch=4,
                   seq_len=64, checkpoint_every=4, num_docs=32,
                   fail_at=(7, 15), log_every=4)
    ckpt = CheckpointManager(dl.MemoryProvider(), keep=3)
    shared = {}

    def make_runner(_):
        def run():
            # after the first crash the transient fault is gone (new 'host')
            remaining = tuple(s for s in job.fail_at
                              if s not in shared.get("fired", set()))
            j = dataclasses.replace(job, fail_at=remaining)
            t = Trainer(j, ckpt=ckpt, data_ds=shared.get("data"))
            shared["data"] = t.data_ds
            try:
                out = t.run(restore=True)
            finally:
                shared.setdefault("fired", set()).update(t.injector.seen)
            shared["out"] = out
            return out["final_step"]
        return run

    result = run_resilient(
        make_runner, max_restarts=4,
        on_restart=lambda n, e: print(f"--- restart #{n} after: {e}"))
    print(f"\nsurvived {result['restarts']} failures; "
          f"final step {result['final_step']}, "
          f"loss {shared['out']['final_loss']:.4f}")
    print(f"checkpoint history (Deep Lake commits): "
          f"{[n.message for n in ckpt.ds.log()][:6]}")


if __name__ == "__main__":
    main()
