"""Batched serving demo: prompts stream OUT of a Deep Lake dataset, responses
stream back IN (the paper's §3.5 'models storing back predictions along with
the dataset' access pattern), under version control.

    PYTHONPATH=src python examples/serve_batched.py
"""

import numpy as np

import repro.core as dl
from repro.launch.serve import Server, ServeJob


def main():
    rng = np.random.default_rng(0)
    job = ServeJob(arch="starcoder2-3b", smoke=True, batch=4, prompt_len=12,
                   max_new_tokens=12, temperature=0.8)
    server = Server(job)

    # request store: a Deep Lake dataset of prompts
    ds = dl.dataset()
    ds.create_tensor("prompt", htype="tokens", dtype="int32")
    ds.create_tensor("response", htype="tokens", dtype="int32", strict=False)
    for _ in range(8):
        ds.prompt.append(rng.integers(0, server.cfg.vocab_size,
                                      job.prompt_len).astype(np.int32))
    ds.commit("requests")

    # serve in fixed-size batches
    for start in range(0, len(ds.prompt), job.batch):
        idx = list(range(start, min(start + job.batch, len(ds.prompt))))
        prompts = np.stack([ds.prompt[i] for i in idx])
        out = server.generate(prompts)
        for row_i, i in enumerate(idx):
            ds.response[i] = out[row_i, job.prompt_len:].astype(np.int32)
    ds.commit("responses")

    print(f"served {len(ds.prompt)} requests | "
          f"decode throughput {server.throughput():.1f} tok/s (CPU smoke)")
    print("sample response ids:", ds.response[0][:10].tolist())
    print("dataset log:", [n.message for n in ds.log()])


if __name__ == "__main__":
    main()
