"""End-to-end driver: train an LM on token data streamed from Deep Lake.

Default preset is CPU-friendly; ``--preset 100m`` builds a ~100M-parameter
model (the deliverable's end-to-end shape) — a few hundred steps of it are a
long CPU run, so step count stays a flag.

    PYTHONPATH=src python examples/train_lm.py                     # tiny, fast
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import argparse

from repro.configs import get_arch, reduce_for_smoke
from repro.launch.train import Trainer, TrainJob


def build_job(preset: str, steps: int, remote: bool) -> TrainJob:
    if preset == "tiny":
        return TrainJob(arch="gemma-2b", smoke=True, steps=steps,
                        global_batch=8, seq_len=128, remote_data=remote,
                        checkpoint_every=max(steps // 3, 1), num_docs=64)
    if preset == "100m":
        # ~100M params: gemma-family, 12L x d=768 x ff=3072, 16k vocab
        job = TrainJob(arch="gemma-2b", smoke=True, steps=steps,
                       global_batch=16, seq_len=512, remote_data=remote,
                       checkpoint_every=50, num_docs=512, lr=6e-4)
        job._override = dict(num_layers=12, d_model=768, num_heads=12,
                             num_kv_heads=4, head_dim=64, d_ff=3072,
                             vocab_size=16384, dtype="float32")
        return job
    raise SystemExit(f"unknown preset {preset}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--remote", action="store_true",
                    help="stream through the simulated S3 provider")
    args = ap.parse_args()
    job = build_job(args.preset, args.steps, args.remote)
    trainer = Trainer(job)
    if hasattr(job, "_override"):
        from repro.models.model import build_model
        from repro.models import count_params
        trainer.cfg = reduce_for_smoke(get_arch("gemma-2b")).with_(
            **job._override)
        trainer.model = build_model(trainer.cfg, shard_fn=trainer.model.shard)
        import jax
        from repro.launch.steps import make_train_step
        trainer.step_fn = jax.jit(
            make_train_step(trainer.model, trainer.opt), donate_argnums=(0,))
        trainer.data_ds = trainer._make_data()
        print(f"100m preset: {count_params(trainer.model.param_specs())/1e6:.0f}M params")
    out = trainer.run(restore=False)
    print(f"\nfinal step {out['final_step']}  loss {out['final_loss']:.4f}  "
          f"(started at {out['history'][0]['loss']:.4f})")


if __name__ == "__main__":
    main()
