#!/usr/bin/env bash
# One-command gate: tier-1 test suite + TQL pruning/coalescing benchmark
# (smoke mode, incl. the top-k gate: ORDER BY + LIMIT must fetch <= half
# the legacy chunk groups, sketch-pruned membership queries must issue
# zero payload requests, and the aggregation-pushdown gate: ungrouped
# COUNT/SUM/MIN/MAX/AVG over committed stats answers with zero payload
# requests, grouped streaming aggregation value-identical to the legacy
# whole-view fold at strictly fewer requests) + cold-open budget & maintenance smoke (backfill
# -> prune-parity, GC dry-run, compaction) + fig6 streaming smoke with a
# stall-seconds budget (cross-unit prefetch must keep compute the
# bottleneck) + chaos smoke (seeded storage faults: byte-identical stream
# results, visible retry/hedge counters, request amplification <= 1.5x,
# and the write plane: 4 concurrent committers under injected put/cas
# faults with zero lost appends, byte-parity vs a serial run, zero
# stranded chunk bytes, and wasted uploads == 0 on non-overlapping
# contention, plus traced fetch.retry/fetch.hedge/commit.rebase spans)
# + serving smoke (8-client same-query storm <= 2x one client's requests,
# distinct-query storm sublinear, shard-parallel scan byte-parity,
# repeat-query cache hit with zero planner work / zero requests, tracing
# overhead <= 5% with serve.admit / serve.shard[k] spans in the artifact)
# + telemetry gates (fig6 stall-attribution causes sum to total, traced
# run's sim seconds within 5% of untraced, Chrome trace artifact is
# well-formed with scan.group spans) + BENCH_io.json validation (incl.
# the stall_attribution section) + no-tracked-bytecode guard.
# Usage: scripts/check.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== hygiene: no tracked bytecode =="
if git ls-files '*.pyc' '*.pyo' | grep -q .; then
  echo "ERROR: compiled bytecode files are tracked:" >&2
  git ls-files '*.pyc' '*.pyo' >&2
  exit 1
fi

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== TQL pruning + coalesced-I/O benchmark (smoke) =="
python -m benchmarks.bench_tql --smoke

echo "== cold-open budget + maintenance smoke =="
python -m benchmarks.bench_maintenance --smoke

echo "== fig6 streaming smoke (stall budget + attribution + tracing overhead) =="
TRACE_OUT="${TMPDIR:-/tmp}/repro_fig6_trace.json"
python -m benchmarks.bench_fig6_streaming_train --smoke --trace-out "$TRACE_OUT"

echo "== fig6 trace artifact: well-formed Chrome trace with scan spans =="
python - "$TRACE_OUT" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    doc = json.load(f)
events = doc["traceEvents"]
assert events, "trace has no events"
spans = [e for e in events if e.get("ph") == "X"]
assert spans, "trace has no complete ('X') spans"
for e in spans:
    for k in ("name", "cat", "ts", "dur", "tid", "pid"):
        assert k in e, f"span missing {k!r}: {e}"
assert any(e["name"].startswith("scan.group") for e in spans), \
    "trace contains no scan.group spans"
print(f"trace ok: {len(spans)} spans, "
      f"{len({e['name'].split('[')[0] for e in spans})} distinct names")
EOF

echo "== chaos smoke (hostile-storage parity + amplification + write-chaos gates) =="
python -m benchmarks.bench_chaos --smoke

echo "== serving smoke (N-client storms + shard parity + versioned cache) =="
SERVE_TRACE="${TMPDIR:-/tmp}/repro_serving_trace.json"
python -m benchmarks.bench_serving --smoke --trace-out "$SERVE_TRACE"

echo "== serving trace artifact: serve.* spans present =="
python - "$SERVE_TRACE" <<'EOF'
import json, sys
with open(sys.argv[1]) as f:
    doc = json.load(f)
spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
assert spans, "serving trace has no complete spans"
names = {e["name"] for e in spans}
for want in ("serve.admit",):
    assert any(n.startswith(want) for n in names), \
        f"serving trace missing {want} spans"
assert any(n.startswith("serve.shard[") for n in names), \
    "serving trace missing serve.shard[k] spans"
print(f"serving trace ok: {len(spans)} spans, "
      f"{sum(n.startswith('serve.') for n in names)} serve.* names")
EOF

echo "== BENCH_io.json validation =="
python -m benchmarks.io_report --validate

echo "== check.sh: all green =="
