#!/usr/bin/env bash
# One-command gate: tier-1 test suite + TQL pruning/coalescing benchmark
# (smoke mode) + cold-open budget & maintenance smoke (backfill ->
# prune-parity, GC dry-run, compaction) + BENCH_io.json validation.
# Usage: scripts/check.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== TQL pruning + coalesced-I/O benchmark (smoke) =="
python -m benchmarks.bench_tql --smoke

echo "== cold-open budget + maintenance smoke =="
python -m benchmarks.bench_maintenance --smoke

echo "== BENCH_io.json validation =="
python -m benchmarks.io_report --validate

echo "== check.sh: all green =="
