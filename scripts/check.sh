#!/usr/bin/env bash
# One-command gate: tier-1 test suite + TQL pruning benchmark (smoke mode).
# Usage: scripts/check.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== TQL pruning benchmark (smoke) =="
python -m benchmarks.bench_tql --smoke

echo "== check.sh: all green =="
