#!/usr/bin/env bash
# One-command gate: tier-1 test suite + TQL pruning/coalescing benchmark
# (smoke mode) + BENCH_io.json structural validation.
# Usage: scripts/check.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== TQL pruning + coalesced-I/O benchmark (smoke) =="
python -m benchmarks.bench_tql --smoke

echo "== BENCH_io.json validation =="
python -m benchmarks.io_report --validate

echo "== check.sh: all green =="
