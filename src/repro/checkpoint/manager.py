"""Checkpointing INTO a Deep Lake dataset — the lakehouse applied to the
framework itself.

Every save is a *commit* on a Deep Lake dataset whose columns are the
flattened state leaves: time travel across checkpoints, lineage (which data
view trained this step — see views.save), and branch-per-experiment come for
free from §4.1.  Leaves are chunked by the format, so object-storage writes
are parallel-friendly; saves run on a background thread (training never
blocks on storage, matching the paper's async-ingest ethos).

Elastic restore: leaves come back as host numpy and are re-device_put with
the *target* mesh's shardings, so restoring onto a different topology
(elastic rescale after failures) is the same code path as same-mesh restore.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.dataset import Dataset
from repro.core.storage import StorageProvider


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, storage: StorageProvider | str | None = None, *,
                 keep: int = 3, async_save: bool = True) -> None:
        self.ds = Dataset(storage)
        if "leaves" not in self.ds.tensor_names:
            self.ds.create_tensor("leaves", htype="generic", dtype="uint8",
                                  strict=False, sample_compression="raw",
                                  min_chunk_size=1 << 20, max_chunk_size=8 << 20)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.saved_steps: List[int] = self._scan_steps()

    # ------------------------------------------------------------------ save
    def _scan_steps(self) -> List[int]:
        steps = []
        for node in self.ds.log():
            if node.message and node.message.startswith("step="):
                steps.append(int(node.message.split("=")[1]))
        return sorted(set(steps))

    def save(self, state, step: int, *, blocking: Optional[bool] = None) -> None:
        self.wait()
        if self._error:
            raise self._error
        host_leaves = [(k, np.asarray(jax.device_get(v)))
                       for k, v in _flatten_with_paths(state)]
        if blocking or not self.async_save:
            self._write(host_leaves, step)
        else:
            self._thread = threading.Thread(
                target=self._write_safe, args=(host_leaves, step), daemon=True)
            self._thread.start()

    def _write_safe(self, leaves, step):
        try:
            self._write(leaves, step)
        except BaseException as e:  # surfaced on next save/wait
            self._error = e

    def _write(self, leaves, step: int) -> None:
        t = self.ds["leaves"]
        manifest = []
        base = len(t)
        for i, (key, arr) in enumerate(leaves):
            t.append(np.frombuffer(arr.tobytes(), dtype=np.uint8).copy())
            manifest.append({"key": key, "dtype": str(arr.dtype),
                             "shape": list(arr.shape), "row": base + i})
        self.ds.storage.put(f"manifests/step_{step}.json",
                            json.dumps({"step": step, "leaves": manifest,
                                        "time": time.time()}).encode())
        self.ds.commit(f"step={step}")
        self.saved_steps.append(step)
        self._gc()

    def _gc(self) -> None:
        # retention: drop manifests beyond `keep` (chunks stay version-owned)
        while len(self.saved_steps) > self.keep:
            old = self.saved_steps.pop(0)
            self.ds.storage.delete(f"manifests/step_{old}.json")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        self.wait()
        return self.saved_steps[-1] if self.saved_steps else None

    def restore(self, like, step: Optional[int] = None,
                shardings=None):
        """Rebuild the state pytree. ``like`` provides structure (pytree of
        arrays or ShapeDtypeStructs); ``shardings`` (optional pytree) places
        leaves on the *current* mesh — elastic restore is just a different
        shardings argument."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoints saved")
        raw = self.ds.storage.get_or_none(f"manifests/step_{step}.json")
        if raw is None:
            raise FileNotFoundError(f"no manifest for step {step}")
        manifest = json.loads(raw.decode())
        by_key: Dict[str, dict] = {m["key"]: m for m in manifest["leaves"]}
        t = self.ds["leaves"]

        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves_out = []
        if shardings is not None:
            shard_flat = jax.tree_util.tree_leaves(shardings)
        else:
            shard_flat = [None] * len(flat)
        for (path, leaf), shard in zip(flat, shard_flat):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            meta = by_key[key]
            buf = t.read(meta["row"])
            arr = np.frombuffer(buf.tobytes(), dtype=np.dtype(meta["dtype"]))
            arr = arr.reshape(meta["shape"])
            if shard is not None:
                leaves_out.append(jax.device_put(arr, shard))
            else:
                leaves_out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves_out)
