"""Architecture registry: --arch <id> resolves here."""

from typing import Dict

from .base import (SHAPES, LONG_CONTEXT_ARCHS, HybridConfig, MLAConfig,
                   ModelConfig, MoEConfig, ShapeConfig, SSMConfig,
                   reduce_for_smoke)
from .starcoder2_3b import CONFIG as STARCODER2_3B
from .qwen2_72b import CONFIG as QWEN2_72B
from .gemma_2b import CONFIG as GEMMA_2B
from .gemma3_27b import CONFIG as GEMMA3_27B
from .musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from .phi3_vision_4b import CONFIG as PHI3_VISION
from .deepseek_v3_671b import CONFIG as DEEPSEEK_V3
from .granite_moe_1b import CONFIG as GRANITE_MOE
from .mamba2_1b import CONFIG as MAMBA2_1B
from .zamba2_2b import CONFIG as ZAMBA2_2B

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in [
    STARCODER2_3B, QWEN2_72B, GEMMA_2B, GEMMA3_27B, MUSICGEN_MEDIUM,
    PHI3_VISION, DEEPSEEK_V3, GRANITE_MOE, MAMBA2_1B, ZAMBA2_2B,
]}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def cell_is_runnable(arch: str, shape: str) -> bool:
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


__all__ = ["ARCHS", "SHAPES", "LONG_CONTEXT_ARCHS", "ModelConfig",
           "MoEConfig", "MLAConfig", "SSMConfig", "HybridConfig",
           "ShapeConfig", "get_arch", "cell_is_runnable", "reduce_for_smoke"]
