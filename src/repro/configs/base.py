"""Model + run configuration dataclasses.

One :class:`ModelConfig` covers all ten assigned architecture families via
optional sub-configs (MoE / MLA / SSM / hybrid / multi-codebook / vlm-stub).
Shape points (train_4k / prefill_32k / decode_32k / long_500k) are
:class:`ShapeConfig`; the launcher crosses them with architectures.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    num_shared: int = 0           # always-on shared experts (deepseek)
    first_dense_layers: int = 0   # leading layers use dense FFN (deepseek: 3)
    capacity_factor: float = 1.25
    router: str = "softmax"       # softmax | sigmoid (deepseek v3)
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 256
    conv_kernel: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class HybridConfig:
    shared_attn_period: int = 6   # one shared attention block every N ssm blocks
    shared_attn_heads: int = 32
    shared_attn_kv_heads: int = 32
    shared_attn_d_ff: int = 0     # 0: no mlp in shared block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention flavor
    attention: str = "gqa"        # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0       # 0 = all-global
    local_global_pattern: Tuple[str, ...] = ()  # e.g. ("L",)*5+("G",) cycled
    # mlp flavor
    mlp: str = "silu_glu"         # silu_glu | gelu_glu | gelu
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # modality frontends (stubs per task spec)
    num_codebooks: int = 0        # musicgen: EnCodec codebooks
    num_image_tokens: int = 0     # phi3v: precomputed patch embeddings
    # multi-token prediction (deepseek v3)
    mtp_depth: int = 0
    # numerics / misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"           # none | dots | full (full = nothing_saveable)
    # beyond-paper perf knobs (hillclimbed in EXPERIMENTS.md §Perf)
    seq_shard_attn: bool = False  # sequence-shard long decode caches
    fsdp_params: bool = True      # ZeRO-3 param sharding over (pod, data)
    adam_moment_dtype: str = "float32"
    vocab_pad_multiple: int = 256  # pad embeddings/logits so vocab shards

    # -- derived -------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def d_inner(self) -> int:
        return self.expand_dim if self.ssm else self.num_heads * self.head_dim

    @property
    def expand_dim(self) -> int:
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.expand_dim // self.ssm.head_dim if self.ssm else 0

    def layer_kind(self, layer_idx: int) -> str:
        """'G' global attn, 'L' local attn for this layer index."""
        if not self.local_global_pattern:
            return "L" if self.sliding_window else "G"
        return self.local_global_pattern[layer_idx % len(self.local_global_pattern)]

    def param_count_estimate(self) -> int:
        """6·N·D model-flops N term: total (dense) params."""
        from repro.models.model import build_model  # late import
        from repro.models import param as P
        return P.count_params(build_model(self).param_specs())

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs for which long_500k is runnable (sub-quadratic sequence mechanism);
# pure full-attention archs skip it per the task spec (see DESIGN.md §4).
LONG_CONTEXT_ARCHS = ("mamba2-1.3b", "zamba2-2.7b", "gemma3-27b")


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one train step, no NaNs)."""
    kw = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(4, max(1, cfg.num_kv_heads * 4 // max(cfg.num_heads, 1))),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        dtype="float32",
        remat="none",
    )
    if cfg.local_global_pattern:
        # keep both kinds + exercise the tail path (5 = 2 periods + 1 tail)
        kw["local_global_pattern"] = ("L", "G")
        kw["num_layers"] = 5
        kw["sliding_window"] = min(cfg.sliding_window or 64, 64)
    elif cfg.sliding_window:
        kw["sliding_window"] = 64
    if cfg.moe:
        kw["moe"] = MoEConfig(num_experts=8, top_k=2, d_expert=64,
                              num_shared=min(cfg.moe.num_shared, 1),
                              first_dense_layers=min(cfg.moe.first_dense_layers, 1),
                              router=cfg.moe.router)
    if cfg.mla:
        kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32, rope_head_dim=16,
                              nope_head_dim=32, v_head_dim=32)
    if cfg.ssm:
        kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2, chunk_size=32,
                              conv_kernel=cfg.ssm.conv_kernel,
                              n_groups=cfg.ssm.n_groups)
        kw["num_layers"] = 4
    if cfg.hybrid:
        kw["hybrid"] = HybridConfig(shared_attn_period=2, shared_attn_heads=4,
                                    shared_attn_kv_heads=4,
                                    shared_attn_d_ff=cfg.hybrid.shared_attn_d_ff
                                    and 256)
        kw["num_layers"] = 4
    if cfg.num_codebooks:
        kw["num_codebooks"] = 2
    if cfg.num_image_tokens:
        kw["num_image_tokens"] = 8
    if cfg.mtp_depth:
        kw["mtp_depth"] = 1
    return cfg.with_(**kw)
