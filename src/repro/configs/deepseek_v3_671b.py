"""deepseek-v3-671b [arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3].

61L, d_model=7168, 128 heads with MLA (q_lora=1536, kv_lora=512, rope
head 64, nope head 128, v head 128), vocab=129280.  MoE: 1 shared + 256
routed experts, top-8, expert FFN hidden=2048 (the spec's d_ff), first 3
layers dense FFN (hidden 18432 per the paper), sigmoid router with
renormalized top-k weights.  Multi-token prediction depth 1.
Adam moments kept in bf16 (fits one pod; see EXPERIMENTS.md memory table).
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # nominal; MLA replaces KV heads with latents
    head_dim=128,
    d_ff=18432,                # dense FFN width of the 3 leading layers
    vocab_size=129280,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1,
                  first_dense_layers=3, router="sigmoid"),
    mtp_depth=1,
    rope_theta=10_000.0,
    mlp="silu_glu",
    adam_moment_dtype="bfloat16",
)
