"""gemma3-27b [hf:google/gemma-3-27b-pt; pattern per gemma-3 tech report].

62L, d_model=5376, 32 heads (GQA kv=16), head_dim=128, d_ff=21504,
vocab=262144.  5 local (sliding window 1024) : 1 global layer pattern,
128k context.  Single RoPE theta=1e6 (the per-kind dual-theta detail is
noted in DESIGN.md as a simplification).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    local_global_pattern=("L", "L", "L", "L", "L", "G"),
    mlp="gelu_glu",
    tie_embeddings=True,
)
