"""gemma-2b [arXiv:2403.08295; hf:google/gemma-2b].

18L, d_model=2048, 8 heads, MQA (kv=1), head_dim=256, d_ff=16384,
vocab=256000.  GeGLU MLP, tied embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    rope_theta=10_000.0,
    mlp="gelu_glu",
    tie_embeddings=True,
)
