"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L, d_model=1024, 16 heads (GQA kv=8), vocab=49155.  MoE throughout:
32 experts, top-8, expert FFN hidden=512 (the spec's d_ff), softmax router.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(num_experts=32, top_k=8, d_expert=512, router="softmax"),
    rope_theta=10_000.0,
    mlp="silu_glu",
)
