"""mamba2-1.3b [arXiv:2405.21060; state-spaces/mamba2-1.3b].

48L attention-free SSD blocks: d_model=2048, expand=2 (d_inner=4096),
head_dim=64 (64 ssm heads), d_state=128, conv kernel 4, chunk 256,
vocab=50280.  Tied embeddings (as released).
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,               # unused: attention-free
    num_kv_heads=1,
    head_dim=1,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256,
                  conv_kernel=4, n_groups=1),
    tie_embeddings=True,
)
