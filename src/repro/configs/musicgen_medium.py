"""musicgen-medium [arXiv:2306.05284; hf:facebook/musicgen-medium].

48L decoder-only over EnCodec tokens: d_model=1536, 24 heads (full MHA,
kv=24), d_ff=6144, 4 codebooks x vocab=2048.  The EnCodec frontend is a
STUB per the task spec: the data pipeline supplies (B, K, S) token grids
with the delay pattern already applied; the backbone embeds the K codebooks
additively and predicts K vocab heads.  RoPE replaces the original
sinusoidal positions (TPU-idiomatic; noted in DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    num_codebooks=4,
    rope_theta=10_000.0,
    mlp="gelu",
)
