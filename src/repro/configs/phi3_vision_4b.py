"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct].

phi3-mini text backbone: 32L, d_model=3072, 32 heads (kv=32), d_ff=8192,
vocab=32064, SwiGLU.  The CLIP ViT-L/14 frontend is a STUB per the task
spec: ``input_specs()`` provides precomputed patch embeddings
(B, num_image_tokens, 1024) which the model projects into d_model and
splices over the first ``num_image_tokens`` positions.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    num_image_tokens=256,
    rope_theta=10_000.0,
    mlp="silu_glu",
)
