"""starcoder2-3b [arXiv:2402.19173; hf:bigcode/starcoder2-3b].

30L, d_model=3072, 24 heads (GQA kv=2), d_ff=12288, vocab=49152.
GQA + RoPE; sliding-window 4096 attention; GELU MLP with bias-style config
reduced to bias on QKV (hf: use_bias=True).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    qkv_bias=True,
    rope_theta=999_999.0,
    sliding_window=4096,
    mlp="gelu",
)
