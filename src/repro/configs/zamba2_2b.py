"""zamba2-2.7b [arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B].

54 Mamba2 layers (d_model=2560, expand=2 -> d_inner=5120, 80 heads @ 64,
d_state=64) with ONE shared full-attention transformer block invoked every
6 mamba layers (9 invocations share parameters), 32 heads, d_ff=10240,
vocab=32000.  (Zamba2's per-invocation LoRA deltas on the shared block are
omitted — noted in DESIGN.md.)
"""
from .base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    attention="gqa",
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk_size=256,
                  conv_kernel=4, n_groups=1),
    hybrid=HybridConfig(shared_attn_period=6, shared_attn_heads=32,
                        shared_attn_kv_heads=32, shared_attn_d_ff=10240),
    mlp="gelu_glu",
)
