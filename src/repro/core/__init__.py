"""Deep Lake core: the paper's contribution (storage format C1, version
control C2, TQL C3, materialization C4, streaming dataloader C5)."""

from . import telemetry
from .chunk_encoder import ChunkEncoder
from .chunks import ChunkBuilder, parse_header, read_all_samples
from .codecs import available as available_codecs, get_codec
from .dataset import Dataset, Group, MergeConflict, dataset, empty_like
from .fetch import (FetchEngine, RetryPolicy, coalescing_disabled,
                    coalescing_enabled, engine_for)
from .htypes import available_htypes, get_htype, parse_htype
from .maintenance import MaintenanceReport, MaintenanceRunner
from .manifest import Manifest, ManifestConflict
from .serving import CachedResult, QueryService
from .storage import (FaultPolicy, LocalProvider, LRUCacheProvider,
                      MemoryProvider, RetryExhausted, SimulatedS3Provider,
                      StorageError, StorageProvider, StorageTimeout,
                      TornReadError, TornWriteError, TransientStorageError,
                      chain, coalesce_ranges, retry_transient,
                      storage_from_path)
from .telemetry import (MetricsRegistry, Tracer, attribute_stall,
                        provider_snapshot, tracing)
from .tensor import Tensor, TensorMeta
from .version_control import CommitContendedError, VersionControl
from .views import DatasetView, TensorView

__all__ = [
    "CachedResult", "ChunkBuilder", "ChunkEncoder", "CommitContendedError",
    "Dataset",
    "DatasetView", "FaultPolicy",
    "FetchEngine", "Group", "LRUCacheProvider", "LocalProvider",
    "MaintenanceReport", "MaintenanceRunner", "Manifest", "ManifestConflict",
    "MemoryProvider", "MergeConflict", "MetricsRegistry", "QueryService",
    "RetryExhausted", "RetryPolicy",
    "SimulatedS3Provider", "StorageError", "StorageProvider",
    "StorageTimeout", "Tensor", "TensorMeta", "TensorView", "TornReadError",
    "TornWriteError", "Tracer", "TransientStorageError", "VersionControl",
    "attribute_stall", "available_codecs",
    "available_htypes", "chain", "coalesce_ranges", "coalescing_disabled",
    "coalescing_enabled", "dataset", "empty_like", "engine_for", "get_codec",
    "get_htype", "parse_htype", "provider_snapshot", "read_all_samples",
    "retry_transient", "storage_from_path", "telemetry", "tracing",
]
