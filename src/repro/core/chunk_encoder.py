"""Chunk encoder: the compressed index map of §3.4.

Maps a global sample index to ``(chunk name, local index within chunk)``.
Representation is one row per chunk, ``last_global_index`` ascending, so
lookup is ``O(log n_chunks)`` bisect and the whole structure stays tiny:
~16 bytes + name per chunk ⇒ the paper's "150MB encoder per 1PB of data"
scale is matched (16MB chunks ⇒ 62.5M chunks/PB ⇒ ~24B each ≈ 1.5GB naive,
or ~150MB once zlib'd names are amortized — we store names in a deduplicated
table and compress on serialize).

The encoder is copy-on-write friendly: ``replace()`` swaps a chunk's name
in-place (used when an in-place sample update rewrites a chunk under version
control) without disturbing index ranges.

:class:`ChunkStatsTable` is the encoder's statistics sidecar: chunk name ->
:class:`~repro.core.chunks.ChunkStats`, persisted per tensor per version as
``chunk_stats.json`` and consumed by the TQL scan planner for data skipping.
Both structures key by chunk *name*, so they survive commits unchanged while
chunk payloads stay where they were created (§4.1).
"""

from __future__ import annotations

import json
import zlib
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .chunks import ChunkStats


def ords_of_boundaries(last_idx: Union[Sequence[int], np.ndarray],
                       global_indices: Union[Sequence[int], np.ndarray]
                       ) -> np.ndarray:
    """Vectorized global-index -> chunk-ord map over a chunk boundary
    table (``last_idx`` = inclusive last global sample index per chunk,
    ascending).  The single implementation behind
    :meth:`ChunkEncoder.ords_of` and the manifest's
    :meth:`~repro.core.manifest.ColumnStats.ords_of`, so planner verdicts
    are identical whichever source serves the scan index."""
    arr = np.asarray(global_indices, dtype=np.int64)
    bounds = np.asarray(last_idx, dtype=np.int64)
    n = int(bounds[-1]) + 1 if len(bounds) else 0
    if arr.size and (int(arr.min()) < 0 or int(arr.max()) >= n):
        raise IndexError(f"indices out of range [0, {n})")
    return np.searchsorted(bounds, arr, side="left")


class ChunkEncoder:
    def __init__(self) -> None:
        self._last_idx: List[int] = []   # inclusive last global sample idx per chunk
        self._names: List[str] = []

    # -- writes --------------------------------------------------------------
    def register_chunk(self, name: str, num_samples: int) -> None:
        if num_samples <= 0:
            raise ValueError("chunk must contain at least one sample")
        last = (self._last_idx[-1] if self._last_idx else -1) + num_samples
        self._last_idx.append(last)
        self._names.append(name)

    def extend_last(self, extra_samples: int) -> None:
        """Grow the open (final) chunk by ``extra_samples``."""
        if not self._last_idx:
            raise ValueError("no chunk registered")
        self._last_idx[-1] += extra_samples

    def replace(self, chunk_ord: int, new_name: str) -> None:
        self._names[chunk_ord] = new_name

    def pop_last(self) -> str:
        self._last_idx.pop()
        return self._names.pop()

    # -- reads ---------------------------------------------------------------
    @property
    def num_samples(self) -> int:
        return (self._last_idx[-1] + 1) if self._last_idx else 0

    @property
    def num_chunks(self) -> int:
        return len(self._names)

    def chunk_names(self) -> List[str]:
        return list(self._names)

    def chunk_ord_of(self, global_idx: int) -> int:
        n = self.num_samples
        if not 0 <= global_idx < n:
            raise IndexError(f"sample {global_idx} out of range [0, {n})")
        return bisect_left(self._last_idx, global_idx)

    def ords_of(self, global_indices: Union[Sequence[int], np.ndarray]) -> np.ndarray:
        """Vectorized ``chunk_ord_of`` over an index array (scan planning)."""
        return ords_of_boundaries(self._last_idx, global_indices)

    def lookup(self, global_idx: int) -> Tuple[str, int]:
        """global index -> (chunk name, local index inside that chunk)."""
        ord_ = self.chunk_ord_of(global_idx)
        first = (self._last_idx[ord_ - 1] + 1) if ord_ else 0
        return self._names[ord_], global_idx - first

    def chunk_span(self, chunk_ord: int) -> Tuple[int, int]:
        """[first, last] inclusive global index range of chunk ``chunk_ord``."""
        first = (self._last_idx[chunk_ord - 1] + 1) if chunk_ord else 0
        return first, self._last_idx[chunk_ord]

    def name_of(self, chunk_ord: int) -> str:
        return self._names[chunk_ord]

    def samples_in(self, chunk_ord: int) -> int:
        first, last = self.chunk_span(chunk_ord)
        return last - first + 1

    # -- wire -----------------------------------------------------------------
    def serialize(self) -> bytes:
        idx = np.asarray(self._last_idx, dtype="<u8").tobytes()
        names = json.dumps(self._names).encode()
        blob = (len(idx)).to_bytes(8, "little") + idx + names
        return zlib.compress(blob, 1)

    @classmethod
    def deserialize(cls, data: bytes) -> "ChunkEncoder":
        blob = zlib.decompress(data)
        nidx = int.from_bytes(blob[:8], "little")
        enc = cls()
        enc._last_idx = [int(x) for x in np.frombuffer(blob[8:8 + nidx], dtype="<u8")]
        enc._names = json.loads(blob[8 + nidx:].decode())
        return enc

    def copy(self) -> "ChunkEncoder":
        c = ChunkEncoder()
        c._last_idx = list(self._last_idx)
        c._names = list(self._names)
        return c

    def nbytes(self) -> int:
        return 8 * len(self._last_idx) + sum(len(n) for n in self._names)


class ChunkStatsTable:
    """chunk name -> :class:`ChunkStats`; the ``chunk_stats.json`` sidecar.

    Missing entries are legal (pre-stats datasets, ancestor chunks written
    before the sidecar existed): the planner treats them as unknown and keeps
    the chunk, so the table is purely an optimization, never a correctness
    requirement.
    """

    def __init__(self) -> None:
        self._by_name: Dict[str, ChunkStats] = {}

    def set(self, chunk_name: str, stats: ChunkStats) -> None:
        self._by_name[chunk_name] = stats

    def get(self, chunk_name: str) -> Optional[ChunkStats]:
        return self._by_name.get(chunk_name)

    def drop(self, chunk_name: str) -> None:
        self._by_name.pop(chunk_name, None)

    def prune_to(self, live_names: Sequence[str]) -> None:
        """Keep only entries for chunks the encoder still references."""
        live = set(live_names)
        for name in [n for n in self._by_name if n not in live]:
            del self._by_name[name]

    def __len__(self) -> int:
        return len(self._by_name)

    def __contains__(self, chunk_name: str) -> bool:
        return chunk_name in self._by_name

    # -- wire -----------------------------------------------------------------
    def serialize(self) -> bytes:
        return json.dumps(
            {"chunks": {k: v.to_json() for k, v in self._by_name.items()}}
        ).encode()

    @classmethod
    def deserialize(cls, data: bytes) -> "ChunkStatsTable":
        table = cls()
        d = json.loads(data.decode())
        for name, sj in d.get("chunks", {}).items():
            table._by_name[name] = ChunkStats.from_json(sj)
        return table
