"""Chunk binary format (§3.4).

A chunk is a binary blob holding a contiguous run of samples of one tensor:

    magic      4s   b"DLC1"
    header_sz  u32  byte offset where the data section begins
    n_samples  u32
    max_ndim   u8 + 3 pad bytes
    dtype      16s  zero-padded numpy dtype string
    codec      16s  zero-padded codec name
    offsets    u64[n+1]       encoded-payload offsets *within the data section*
    flags      u8[n]          bit0: payload is a tile descriptor, not data
    ndims      u8[n]
    shapes     u32[n*max_ndim] row-major, zero-padded to max_ndim
    data       bytes          concatenated per-sample codec payloads

Byte ranges for a single sample are therefore
``[header_sz + offsets[i], header_sz + offsets[i+1])`` — this is what the
streaming loader's range requests use (§3.5).  Shapes live in the header so
shape-only queries (TQL ``SHAPE(x)``) never touch payload bytes.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .codecs import Codec, get_codec

MAGIC = b"DLC1"
FLAG_TILED = 0x01
_FIXED = struct.Struct("<4sIIB3x16s16s")  # magic, header_sz, n, max_ndim, dtype, codec


def _pad16(s: str) -> bytes:
    b = s.encode()
    if len(b) > 16:
        raise ValueError(f"name too long: {s}")
    return b.ljust(16, b"\x00")


@dataclass
class ChunkHeader:
    num_samples: int
    max_ndim: int
    dtype: str
    codec: str
    offsets: np.ndarray  # (n+1,) u64
    flags: np.ndarray    # (n,)  u8
    shapes: List[Tuple[int, ...]]
    header_size: int

    def byte_range(self, i: int) -> Tuple[int, int]:
        """Absolute [start, end) byte range of sample ``i`` inside the chunk."""
        return (self.header_size + int(self.offsets[i]),
                self.header_size + int(self.offsets[i + 1]))

    def is_tiled(self, i: int) -> bool:
        return bool(self.flags[i] & FLAG_TILED)

    def nbytes_data(self) -> int:
        return int(self.offsets[-1])


def header_size_of(raw_prefix: bytes) -> int:
    """Given ≥12 leading bytes of a chunk, return its header size."""
    magic, header_sz, _n, _ndim, _dt, _cd = _FIXED.unpack_from(
        raw_prefix[:_FIXED.size].ljust(_FIXED.size, b"\x00"))
    if magic != MAGIC:
        raise ValueError("not a Deep Lake chunk")
    return header_sz


def parse_header(raw: bytes) -> ChunkHeader:
    magic, header_sz, n, max_ndim, dtype_b, codec_b = _FIXED.unpack_from(raw)
    if magic != MAGIC:
        raise ValueError("not a Deep Lake chunk")
    off = _FIXED.size
    offsets = np.frombuffer(raw, dtype="<u8", count=n + 1, offset=off)
    off += 8 * (n + 1)
    flags = np.frombuffer(raw, dtype="u1", count=n, offset=off)
    off += n
    ndims = np.frombuffer(raw, dtype="u1", count=n, offset=off)
    off += n
    shp = np.frombuffer(raw, dtype="<u4", count=n * max_ndim, offset=off)
    shp = shp.reshape(n, max_ndim) if n else shp.reshape(0, max(max_ndim, 1))
    shapes = [tuple(int(x) for x in shp[i, : ndims[i]]) for i in range(n)]
    return ChunkHeader(
        num_samples=n,
        max_ndim=max_ndim,
        dtype=dtype_b.rstrip(b"\x00").decode(),
        codec=codec_b.rstrip(b"\x00").decode(),
        offsets=offsets,
        flags=flags,
        shapes=shapes,
        header_size=header_sz,
    )


class ChunkBuilder:
    """Accumulates samples, then serializes to the chunk wire format.

    The builder tracks its *serialized* size so the tensor can honor the
    [min_chunk_size, max_chunk_size] policy from §3.4 while appending.
    """

    def __init__(self, dtype: str, codec: str) -> None:
        self.dtype = np.dtype(dtype)
        self.codec_name = codec
        self._codec: Codec = get_codec(codec)
        self.payloads: List[bytes] = []
        self.shapes: List[Tuple[int, ...]] = []
        self.flags: List[int] = []
        self._data_bytes = 0

    # -- building ------------------------------------------------------------
    def append_array(self, arr: np.ndarray) -> int:
        """Encode + append an ndarray sample; returns its encoded size."""
        if arr.dtype != self.dtype:
            raise TypeError(f"chunk dtype {self.dtype} != sample dtype {arr.dtype}")
        payload = self._codec.encode(arr)
        self._append_payload(payload, tuple(arr.shape), 0)
        return len(payload)

    def append_raw(self, payload: bytes, shape: Tuple[int, ...], flags: int = 0) -> int:
        """Append a pre-encoded payload (used for tile descriptors / copies)."""
        self._append_payload(bytes(payload), shape, flags)
        return len(payload)

    def _append_payload(self, payload: bytes, shape: Tuple[int, ...], flags: int) -> None:
        self.payloads.append(payload)
        self.shapes.append(shape)
        self.flags.append(flags)
        self._data_bytes += len(payload)

    # -- inspection ------------------------------------------------------------
    @property
    def num_samples(self) -> int:
        return len(self.payloads)

    @property
    def max_ndim(self) -> int:
        return max((len(s) for s in self.shapes), default=1)

    def nbytes_serialized(self) -> int:
        n = self.num_samples
        return (_FIXED.size + 8 * (n + 1) + 2 * n + 4 * n * self.max_ndim
                + self._data_bytes)

    # -- wire ------------------------------------------------------------
    def serialize(self) -> bytes:
        n = self.num_samples
        max_ndim = self.max_ndim
        offsets = np.zeros(n + 1, dtype="<u8")
        np.cumsum([len(p) for p in self.payloads], out=offsets[1:])
        ndims = np.array([len(s) for s in self.shapes], dtype="u1")
        shp = np.zeros((n, max_ndim), dtype="<u4")
        for i, s in enumerate(self.shapes):
            shp[i, : len(s)] = s
        header_sz = _FIXED.size + 8 * (n + 1) + 2 * n + 4 * n * max_ndim
        parts = [
            _FIXED.pack(MAGIC, header_sz, n, max_ndim,
                        _pad16(self.dtype.str if self.dtype.names is None else self.dtype.name),
                        _pad16(self.codec_name)),
            offsets.tobytes(),
            np.asarray(self.flags, dtype="u1").tobytes(),
            ndims.tobytes(),
            shp.tobytes(),
        ]
        parts.extend(self.payloads)
        return b"".join(parts)


def decode_sample(header: ChunkHeader, payload: bytes, i: int) -> np.ndarray:
    """Decode sample ``i``'s payload bytes (already range-read) to ndarray."""
    codec = get_codec(header.codec)
    return codec.decode(payload, header.shapes[i], np.dtype(header.dtype))


def read_sample_from_bytes(raw: bytes, i: int,
                           header: Optional[ChunkHeader] = None) -> np.ndarray:
    """Decode sample ``i`` from a fully-fetched chunk blob."""
    h = header or parse_header(raw)
    s, e = h.byte_range(i)
    return decode_sample(h, raw[s:e], i)


def read_all_samples(raw: bytes) -> List[np.ndarray]:
    h = parse_header(raw)
    return [read_sample_from_bytes(raw, i, h) for i in range(h.num_samples)]
