"""Chunk binary format (§3.4).

A chunk is a binary blob holding a contiguous run of samples of one tensor:

    magic      4s   b"DLC1"
    header_sz  u32  byte offset where the data section begins
    n_samples  u32
    max_ndim   u8 + 3 pad bytes
    dtype      16s  zero-padded numpy dtype string
    codec      16s  zero-padded codec name
    offsets    u64[n+1]       encoded-payload offsets *within the data section*
    flags      u8[n]          bit0: payload is a tile descriptor, not data
    ndims      u8[n]
    shapes     u32[n*max_ndim] row-major, zero-padded to max_ndim
    data       bytes          concatenated per-sample codec payloads

Byte ranges for a single sample are therefore
``[header_sz + offsets[i], header_sz + offsets[i+1])`` — this is what the
streaming loader's range requests use (§3.5).  Shapes live in the header so
shape-only queries (TQL ``SHAPE(x)``) never touch payload bytes.

Chunk statistics (TQL data skipping)
-----------------------------------

Alongside the wire format, each :class:`ChunkBuilder` accumulates a
:class:`ChunkStats` record over every sample it absorbs: element-wise
``lo``/``hi`` bounds (widened outward so float rounding can never narrow the
true range), NaN and non-zero element counts, total element count, the
smallest per-sample element count (``min_elems`` — 0 means the chunk may hold
empty samples), sample count and payload byte size.  Samples the builder
cannot inspect (undecodable payloads, or tile descriptors absorbed without
their source array — e.g. a copy-on-write chunk rewrite) flip ``exact`` to
False, which tells the query planner to treat the chunk as unknown.  On the
append path tiled samples stay exact: the tensor hands the builder the
reassembled array a reader would decode.

Membership sketches (equality / IN / CONTAINS pushdown)
-------------------------------------------------------

Bounds answer range predicates; ``=`` / ``IN`` / ``CONTAINS`` need
*membership*.  The accumulator therefore also tracks a per-chunk value
sketch over one of two domains, chosen by dtype:

* ``dom="int"`` — every element value of bool/int samples (``class_label``,
  ``tokens``, masks), provided each sample has ≤ ``SKETCH_MAX_ELEMS``
  elements (keeps the ingest path cheap; larger samples disable the
  sketch for the whole chunk);
* ``dom="str"`` — the whole decoded sample string of 1-D ``uint8``
  samples ≤ ``SKETCH_MAX_STR`` bytes (the ``text`` htype), decoded with
  ``errors="replace"`` — the *same* decode TQL's ``CONTAINS`` applies, so
  substring verdicts from the sketch can never diverge from execution.

Float samples never sketch (rounding makes equality pruning unsound).
Wire form, inside each sidecar record:

* ``≤ SKETCH_DICT_MAX`` (64) distinct values → ``dct`` holds the exact
  sorted value list and no bloom is stored (the dictionary subsumes it);
* ``≤ SKETCH_MAX_DISTINCT`` (256) distinct, ``int`` domain only → ``dct``
  is null and ``bloom`` holds a hex ``SKETCH_BLOOM_BYTES``-byte bloom
  filter (``SKETCH_BLOOM_K`` blake2b-derived probes per value); a
  ``str``-domain dictionary that overflows drops the sketch instead —
  substring probes need the exact values, a bloom of whole strings
  answers nothing;
* more distinct values, oversized samples, or a non-sketchable dtype →
  both null (``dom`` null too).

``sketched`` marks records written by a sketch-aware writer: legacy
records deserialize with ``sketched=False`` so ``backfill_stats`` knows
to lift them (a null sketch on a *sketched* record is a definitive
"inapplicable", not a gap).  Soundness rules consumed by the planner
(:meth:`ChunkStats.might_contain`):

* a sketch is consulted only when the record is ``exact`` and
  ``sketched`` and the probe value matches the sketch domain;
* ``might_contain`` may return false positives (cost: a verify verdict)
  but never false negatives: the dictionary is the exact distinct-value
  set, and the bloom only ever *adds* bits — so "absent" is a proof;
* empty samples contribute no values; membership verdicts must therefore
  derive the empty-sample outcome from ``min_elems``, never the sketch.

Partial aggregates (GROUP BY / aggregate pushdown)
--------------------------------------------------

TQL's aggregation path can answer COUNT/SUM/MIN/MAX/AVG for a chunk
straight from its stats record — zero payload fetches — but only under
rules as strict as the sketch rules above, because a partial aggregate
that is merely *approximate* silently corrupts the merged total (there is
no "verify" second chance once a number is folded in):

* the record must be ``exact`` and the querying view must cover **every**
  row of the chunk exactly once — a partially covered chunk must be
  fetched and folded instead (its stats describe rows the query excluded);
* ``COUNT`` needs only the covered row count; ``SUM`` uses the ``sum``
  field (None on legacy records → fetch+fold), accumulated NaN-skipping
  in float64 for float dtypes and exactly (native integer width) for
  bool/int dtypes; ``AVG`` is ``sum / (n_elements - nan_count)``;
* ``MIN``/``MAX`` use ``lo``/``hi`` only while ``|lo|``/``|hi|`` < 2**53:
  beyond that the outward float widening that keeps *pruning* sound makes
  the bounds unusable as *values* (they may not equal any element);
* a chunk with no numeric values (all samples empty, or all elements NaN)
  contributes the fold identities: 0 to COUNT-of-elements-style sums,
  nothing to MIN/MAX/AVG;
* the grouped fast path additionally requires the grouping key chunk to
  be single-valued: an exact dictionary sketch with exactly one entry and
  scalar samples (``min_elems == 1 and n_elements == count``, no NaNs for
  the int domain), so every row of the chunk provably belongs to that one
  group.

Stats are persisted per tensor per version as a JSON sidecar under the
existing :class:`~repro.core.storage.StorageProvider` key protocol:

    versions/{node}/tensors/{t}/chunk_stats.json
        {"chunks": {chunk_name: {count, nbytes, lo, hi, sum, nan_count,
                                 true_count, n_elements, min_elems, exact,
                                 sketched, dom, dct, bloom}}}

The sidecar is one of the version-control ``STATE_FILES``: ``commit`` copies
it to the child node together with the chunk-encoder snapshot, so stats keep
mapping chunk *names* (which never move between versions, §4.1) to bounds.
``tql/planner.py`` consumes these records to derive per-chunk
prune/keep/verify verdicts for ``WHERE`` clauses without fetching payloads,
and per-chunk ``ORDER BY`` key bounds for top-k chunk skipping.
"""

from __future__ import annotations

import hashlib
import struct
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from .codecs import Codec, get_codec

MAGIC = b"DLC1"
FLAG_TILED = 0x01
_FIXED = struct.Struct("<4sIIB3x16s16s")  # magic, header_sz, n, max_ndim, dtype, codec

_NUMERIC_KINDS = "biuf"

# ---- membership-sketch parameters (see module docstring for the format)
SKETCH_DICT_MAX = 64        # exact dictionary capacity (distinct values)
SKETCH_MAX_DISTINCT = 256   # beyond this the bloom is saturated: disable
SKETCH_BLOOM_BYTES = 128    # 1024-bit filter
SKETCH_BLOOM_K = 4          # probes per value
SKETCH_MAX_ELEMS = 4096     # int-domain samples larger than this don't sketch
SKETCH_MAX_STR = 256        # str-domain (uint8 text) sample byte cap


def _sketch_encode(value: Union[int, str]) -> bytes:
    """Canonical hash input of a sketch value; the domain prefix keeps the
    int and str value spaces collision-free."""
    if isinstance(value, str):
        return b"s:" + value.encode("utf-8", "replace")
    return b"i:%d" % int(value)


def _bloom_positions(value: Union[int, str]) -> List[int]:
    d = hashlib.blake2b(_sketch_encode(value), digest_size=16).digest()
    nbits = SKETCH_BLOOM_BYTES * 8
    return [int.from_bytes(d[4 * i:4 * i + 4], "big") % nbits
            for i in range(SKETCH_BLOOM_K)]


def _bloom_add(bits: bytearray, value: Union[int, str]) -> None:
    for p in _bloom_positions(value):
        bits[p >> 3] |= 1 << (p & 7)


def bloom_might_contain(bloom_hex: str, value: Union[int, str]) -> bool:
    """True unless the filter proves ``value`` was never inserted."""
    bits = bytes.fromhex(bloom_hex)
    return all(bits[p >> 3] & (1 << (p & 7)) for p in _bloom_positions(value))


def _lo_bound(v) -> float:
    """float(v) rounded, if at all, toward -inf (never narrows an interval)."""
    f = float(v)
    return float(np.nextafter(f, -np.inf)) if f > v else f


def _hi_bound(v) -> float:
    f = float(v)
    return float(np.nextafter(f, np.inf)) if f < v else f


@dataclass
class ChunkStats:
    """Per-chunk column statistics used for TQL data skipping.

    ``lo``/``hi`` bound every non-NaN element of every sample in the chunk
    (None when the chunk holds no inspectable numeric values).  ``exact`` is
    False when at least one sample could not be inspected (undecodable
    payload, or a tile descriptor seen without its source array) — the
    planner must then treat the chunk as unknown.

    ``dom``/``dct``/``bloom`` are the membership sketch (module docstring:
    value domains, capacities, soundness rules); ``sketched`` distinguishes
    "sketch-aware writer decided no sketch applies" from "record predates
    sketches" so the maintenance backfill can lift legacy records.
    """

    count: int = 0          # samples
    nbytes: int = 0         # encoded payload bytes
    lo: Optional[float] = None
    hi: Optional[float] = None
    #: NaN-skipping total of every numeric element (float64 accumulation
    #: for float dtypes, exact native-integer for bool/int); 0 when the
    #: chunk has no numeric values, None on inexact or legacy records.
    #: Consumed by the aggregate fast path (module docstring).
    sum: Optional[float] = None
    nan_count: int = 0      # NaN elements seen
    true_count: int = 0     # non-zero elements seen
    n_elements: int = 0     # total elements across samples
    min_elems: int = 0      # smallest per-sample element count
    exact: bool = True
    sketched: bool = False  # record written by a sketch-aware writer
    dom: Optional[str] = None            # 'int' | 'str' | None
    dct: Optional[List] = None           # exact distinct values (sorted)
    bloom: Optional[str] = None          # hex bloom (dct overflowed)

    def to_json(self) -> dict:
        return {"count": self.count, "nbytes": self.nbytes,
                "lo": self.lo, "hi": self.hi, "sum": self.sum,
                "nan_count": self.nan_count, "true_count": self.true_count,
                "n_elements": self.n_elements, "min_elems": self.min_elems,
                "exact": self.exact, "sketched": self.sketched,
                "dom": self.dom, "dct": self.dct, "bloom": self.bloom}

    @classmethod
    def from_json(cls, d: dict) -> "ChunkStats":
        s = cls()
        for k, v in d.items():
            setattr(s, k, v)
        return s

    # ---- membership (sound: False positives allowed, negatives never)
    def sketch_usable(self, dom: str) -> bool:
        """True when membership probes over domain ``dom`` may consult this
        record's sketch (exact, sketch-aware, same value domain)."""
        return (self.exact and self.sketched and self.dom == dom
                and (self.dct is not None or self.bloom is not None))

    def might_contain(self, value: Union[int, str]) -> bool:
        """Sound membership: False ⇒ ``value`` appears in *no* sample of the
        chunk (its domain: elements for ``int``, whole sample strings for
        ``str``).  True means present *or unknown* — including any probe the
        sketch cannot answer (wrong domain, inexact, legacy record)."""
        dom = "str" if isinstance(value, str) else "int"
        if not self.sketch_usable(dom):
            return True
        if self.dct is not None:
            return value in self.dct
        return bloom_might_contain(self.bloom, value)


class _StatsAccumulator:
    """Streaming ChunkStats over decoded samples of one chunk."""

    def __init__(self, dtype: np.dtype) -> None:
        self.dtype = dtype
        self.reset()

    def reset(self) -> None:
        self.count = 0
        self.lo = np.inf
        self.hi = -np.inf
        self.sum = 0                    # Python int/float: exact for ints
        self.nan_count = 0
        self.true_count = 0
        self.n_elements = 0
        self.min_elems: Optional[int] = None
        self.exact = True
        self._values: set = set()       # distinct sketch values so far
        self._dom: Optional[str] = None
        self._sketch_ok = True

    def mark_inexact(self, n_samples: int = 1) -> None:
        self.count += n_samples
        self.exact = False

    def _disable_sketch(self) -> None:
        self._sketch_ok = False
        self._values = set()
        self._dom = None

    def _sketch_sample(self, arr: np.ndarray, kind: str) -> None:
        """Fold one sample's values into the membership sketch (or disable
        it for the chunk when the sample falls outside the sketchable
        envelope — see the module docstring's domain rules)."""
        if not self._sketch_ok:
            return
        if kind == "f":  # float equality pruning is never sound
            self._disable_sketch()
            return
        if kind == "u" and arr.dtype.itemsize == 1:
            # text htype domain: the whole decoded sample string, with the
            # same lossy decode CONTAINS applies at execution time
            if arr.ndim != 1 or arr.size > SKETCH_MAX_STR:
                self._disable_sketch()
                return
            self._values.add(
                np.ascontiguousarray(arr).tobytes().decode(errors="replace"))
            dom = "str"
        else:
            if arr.size > SKETCH_MAX_ELEMS:
                self._disable_sketch()
                return
            self._values.update(int(v) for v in np.unique(arr))
            dom = "int"
        if self._dom is None:
            self._dom = dom
        elif self._dom != dom:          # mixed domains: cannot happen for a
            self._disable_sketch()      # fixed-dtype tensor, but stay sound
            return
        if len(self._values) > SKETCH_MAX_DISTINCT:
            self._disable_sketch()

    def observe(self, arr: np.ndarray) -> None:
        self.count += 1
        size = int(arr.size)
        self.n_elements += size
        self.min_elems = size if self.min_elems is None \
            else min(self.min_elems, size)
        if size == 0:
            return
        self.true_count += int(np.count_nonzero(arr))
        kind = arr.dtype.kind
        if kind not in _NUMERIC_KINDS:
            self.exact = False
            self._disable_sketch()
            return
        self._sketch_sample(arr, kind)
        if kind == "f":
            nan = size - int(np.count_nonzero(arr == arr))
            self.nan_count += nan
            if nan == size:
                return
            self.sum += float(np.nansum(arr, dtype=np.float64))
            lo, hi = float(np.nanmin(arr)), float(np.nanmax(arr))
        else:
            # per-sample native-width sum, cross-sample Python-int (exact)
            self.sum += int(arr.sum(dtype=np.uint64 if kind == "u"
                                    else np.int64))
            lo = _lo_bound(int(arr.min()))
            hi = _hi_bound(int(arr.max()))
        self.lo = min(self.lo, lo)
        self.hi = max(self.hi, hi)

    def _sketch_snapshot(self) -> Tuple[Optional[str], Optional[List],
                                        Optional[str]]:
        """(dom, dct, bloom) wire triple: exact dictionary while it fits,
        bloom beyond that, nothing once saturated/inapplicable.  The bloom
        is int-domain only — every str-domain consumer (CONTAINS substring
        probes) needs the exact dictionary, so a bloom of whole strings
        would be unreachable payload."""
        if not self._sketch_ok or self._dom is None:
            return None, None, None
        values = sorted(self._values)
        if len(values) <= SKETCH_DICT_MAX:
            return self._dom, values, None
        if self._dom != "int":
            return None, None, None
        bits = bytearray(SKETCH_BLOOM_BYTES)
        for v in values:
            _bloom_add(bits, v)
        return self._dom, None, bytes(bits).hex()

    def snapshot(self, nbytes: int) -> ChunkStats:
        has_range = self.lo <= self.hi
        dom, dct, bloom = self._sketch_snapshot()
        return ChunkStats(
            count=self.count, nbytes=int(nbytes),
            lo=self.lo if has_range else None,
            hi=self.hi if has_range else None,
            sum=self.sum if self.exact else None,
            nan_count=self.nan_count, true_count=self.true_count,
            n_elements=self.n_elements,
            min_elems=int(self.min_elems or 0),
            exact=self.exact, sketched=True,
            dom=dom, dct=dct, bloom=bloom)


def _pad16(s: str) -> bytes:
    b = s.encode()
    if len(b) > 16:
        raise ValueError(f"name too long: {s}")
    return b.ljust(16, b"\x00")


@dataclass
class ChunkHeader:
    num_samples: int
    max_ndim: int
    dtype: str
    codec: str
    offsets: np.ndarray  # (n+1,) u64
    flags: np.ndarray    # (n,)  u8
    shapes: List[Tuple[int, ...]]
    header_size: int

    def byte_range(self, i: int) -> Tuple[int, int]:
        """Absolute [start, end) byte range of sample ``i`` inside the chunk."""
        return (self.header_size + int(self.offsets[i]),
                self.header_size + int(self.offsets[i + 1]))

    def is_tiled(self, i: int) -> bool:
        return bool(self.flags[i] & FLAG_TILED)

    def nbytes_data(self) -> int:
        return int(self.offsets[-1])


def header_size_of(raw_prefix: bytes) -> int:
    """Given ≥12 leading bytes of a chunk, return its header size."""
    magic, header_sz, _n, _ndim, _dt, _cd = _FIXED.unpack_from(
        raw_prefix[:_FIXED.size].ljust(_FIXED.size, b"\x00"))
    if magic != MAGIC:
        raise ValueError("not a Deep Lake chunk")
    return header_sz


def parse_header(raw: bytes) -> ChunkHeader:
    magic, header_sz, n, max_ndim, dtype_b, codec_b = _FIXED.unpack_from(raw)
    if magic != MAGIC:
        raise ValueError("not a Deep Lake chunk")
    off = _FIXED.size
    offsets = np.frombuffer(raw, dtype="<u8", count=n + 1, offset=off)
    off += 8 * (n + 1)
    flags = np.frombuffer(raw, dtype="u1", count=n, offset=off)
    off += n
    ndims = np.frombuffer(raw, dtype="u1", count=n, offset=off)
    off += n
    shp = np.frombuffer(raw, dtype="<u4", count=n * max_ndim, offset=off)
    shp = shp.reshape(n, max_ndim) if n else shp.reshape(0, max(max_ndim, 1))
    shapes = [tuple(int(x) for x in shp[i, : ndims[i]]) for i in range(n)]
    return ChunkHeader(
        num_samples=n,
        max_ndim=max_ndim,
        dtype=dtype_b.rstrip(b"\x00").decode(),
        codec=codec_b.rstrip(b"\x00").decode(),
        offsets=offsets,
        flags=flags,
        shapes=shapes,
        header_size=header_sz,
    )


class ChunkBuilder:
    """Accumulates samples, then serializes to the chunk wire format.

    The builder tracks its *serialized* size so the tensor can honor the
    [min_chunk_size, max_chunk_size] policy from §3.4 while appending.
    """

    def __init__(self, dtype: str, codec: str) -> None:
        self.dtype = np.dtype(dtype)
        self.codec_name = codec
        self._codec: Codec = get_codec(codec)
        self.payloads: List[bytes] = []
        self.shapes: List[Tuple[int, ...]] = []
        self.flags: List[int] = []
        self._data_bytes = 0
        self._stats = _StatsAccumulator(self.dtype)
        self._stats_dirty = False

    # -- building ------------------------------------------------------------
    def append_array(self, arr: np.ndarray) -> int:
        """Encode + append an ndarray sample; returns its encoded size."""
        if arr.dtype != self.dtype:
            raise TypeError(f"chunk dtype {self.dtype} != sample dtype {arr.dtype}")
        payload = self._codec.encode(arr)
        self._append_payload(payload, tuple(arr.shape), 0)
        if self._codec.lossy:  # stats must bound what queries will read
            self._observe_payload(payload, tuple(arr.shape), 0)
        else:
            self._stats.observe(arr)
        return len(payload)

    def append_raw(self, payload: bytes, shape: Tuple[int, ...], flags: int = 0,
                   source: Optional[np.ndarray] = None) -> int:
        """Append a pre-encoded payload (used for tile descriptors / copies).

        ``source`` is the decoded array the payload represents, when the
        caller still has it in hand.  For lossless non-tiled payloads its
        stats equal the payload's, so passing it skips a decode on the
        ingest hot path (lossy codecs re-decode — stats must bound what
        queries will read).  For FLAG_TILED payloads the caller guarantees
        ``source`` is the array a reader reassembles from the tiles
        (``Tensor._write_tiled`` hands back the lossy round-trip), which
        keeps tiled chunks *exact* instead of degrading them to planner
        'verify'.
        """
        payload = bytes(payload)
        self._append_payload(payload, shape, flags)
        if source is not None and (flags & FLAG_TILED
                                   or not self._codec.lossy):
            self._stats.observe(source)
        else:
            self._observe_payload(payload, shape, flags)
        return len(payload)

    def replace_payload(self, local: int, payload: bytes,
                        shape: Tuple[int, ...], flags: int) -> None:
        """In-place sample update of the open chunk; stats recompute lazily."""
        self._data_bytes += len(payload) - len(self.payloads[local])
        self.payloads[local] = bytes(payload)
        self.shapes[local] = shape
        self.flags[local] = flags
        self._stats_dirty = True

    def _append_payload(self, payload: bytes, shape: Tuple[int, ...], flags: int) -> None:
        self.payloads.append(payload)
        self.shapes.append(shape)
        self.flags.append(flags)
        self._data_bytes += len(payload)

    # -- statistics ----------------------------------------------------------
    def _observe_payload(self, payload: bytes, shape: Tuple[int, ...],
                         flags: int) -> None:
        if flags & FLAG_TILED:
            self._stats.mark_inexact()
            return
        try:
            self._stats.observe(self._codec.decode(payload, shape, self.dtype))
        except Exception:
            self._stats.mark_inexact()

    def stats_snapshot(self) -> ChunkStats:
        """Current :class:`ChunkStats` of the chunk being built."""
        if self._stats_dirty:
            self._stats.reset()
            for payload, shape, flags in zip(self.payloads, self.shapes,
                                             self.flags):
                self._observe_payload(payload, shape, flags)
            self._stats_dirty = False
        return self._stats.snapshot(self._data_bytes)

    # -- inspection ------------------------------------------------------------
    @property
    def num_samples(self) -> int:
        return len(self.payloads)

    @property
    def max_ndim(self) -> int:
        return max((len(s) for s in self.shapes), default=1)

    def nbytes_serialized(self) -> int:
        n = self.num_samples
        return (_FIXED.size + 8 * (n + 1) + 2 * n + 4 * n * self.max_ndim
                + self._data_bytes)

    # -- wire ------------------------------------------------------------
    def serialize(self) -> bytes:
        n = self.num_samples
        max_ndim = self.max_ndim
        offsets = np.zeros(n + 1, dtype="<u8")
        np.cumsum([len(p) for p in self.payloads], out=offsets[1:])
        ndims = np.array([len(s) for s in self.shapes], dtype="u1")
        shp = np.zeros((n, max_ndim), dtype="<u4")
        for i, s in enumerate(self.shapes):
            shp[i, : len(s)] = s
        header_sz = _FIXED.size + 8 * (n + 1) + 2 * n + 4 * n * max_ndim
        parts = [
            _FIXED.pack(MAGIC, header_sz, n, max_ndim,
                        _pad16(self.dtype.str if self.dtype.names is None else self.dtype.name),
                        _pad16(self.codec_name)),
            offsets.tobytes(),
            np.asarray(self.flags, dtype="u1").tobytes(),
            ndims.tobytes(),
            shp.tobytes(),
        ]
        parts.extend(self.payloads)
        return b"".join(parts)


def decode_sample(header: ChunkHeader, payload: bytes, i: int) -> np.ndarray:
    """Decode sample ``i``'s payload bytes (already range-read) to ndarray."""
    codec = get_codec(header.codec)
    return codec.decode(payload, header.shapes[i], np.dtype(header.dtype))


def read_sample_from_bytes(raw: bytes, i: int,
                           header: Optional[ChunkHeader] = None) -> np.ndarray:
    """Decode sample ``i`` from a fully-fetched chunk blob."""
    h = header or parse_header(raw)
    s, e = h.byte_range(i)
    return decode_sample(h, raw[s:e], i)


def read_all_samples(raw: bytes) -> List[np.ndarray]:
    h = parse_header(raw)
    return [read_sample_from_bytes(raw, i, h) for i in range(h.num_samples)]
