"""Sample compression codecs.

Deep Lake compresses *samples* (not whole chunks) so that ranged reads can
decompress a single sample without touching the rest of the chunk (§3.4/§3.5).
Offline container ⇒ no libjpeg/ffmpeg; the codec set is:

    raw     -- np.tobytes, zero-copy decode
    zlib    -- DEFLATE (stdlib), lossless; stands in for PNG-class codecs
    lzma    -- higher-ratio lossless; stands in for archival codecs
    quant8  -- lossy 8-bit min/max quantization + zlib; stands in for
               JPEG-class lossy image compression (benchmarks use it for the
               "jpeg" datasets of Fig 5)

Codecs encode a single ndarray to bytes and back; dtype/shape travel in the
chunk header, NOT in the codec payload (except quant8's dequant scale).
"""

from __future__ import annotations

import struct
import zlib
import lzma
from typing import Dict, Tuple

import numpy as np


class Codec:
    name: str = "abstract"
    lossy: bool = False

    def encode(self, arr: np.ndarray) -> bytes:
        raise NotImplementedError

    def decode(self, data: bytes, shape: Tuple[int, ...], dtype: np.dtype) -> np.ndarray:
        raise NotImplementedError


class RawCodec(Codec):
    name = "raw"

    def encode(self, arr: np.ndarray) -> bytes:
        return np.ascontiguousarray(arr).tobytes()

    def decode(self, data: bytes, shape, dtype) -> np.ndarray:
        return np.frombuffer(data, dtype=dtype).reshape(shape)


class ZlibCodec(Codec):
    name = "zlib"

    def __init__(self, level: int = 1) -> None:
        self.level = level

    def encode(self, arr: np.ndarray) -> bytes:
        return zlib.compress(np.ascontiguousarray(arr).tobytes(), self.level)

    def decode(self, data: bytes, shape, dtype) -> np.ndarray:
        return np.frombuffer(zlib.decompress(data), dtype=dtype).reshape(shape)


class LzmaCodec(Codec):
    name = "lzma"

    def encode(self, arr: np.ndarray) -> bytes:
        return lzma.compress(np.ascontiguousarray(arr).tobytes(), preset=0)

    def decode(self, data: bytes, shape, dtype) -> np.ndarray:
        return np.frombuffer(lzma.decompress(data), dtype=dtype).reshape(shape)


class Quant8Codec(Codec):
    """Lossy min/max 8-bit quantization + DEFLATE.  JPEG-class stand-in.

    Payload: f64 lo | f64 hi | zlib(uint8 quantized).  Roundtrip error is
    bounded by (hi-lo)/255, analogous to JPEG quality loss.
    """

    name = "quant8"
    lossy = True

    def encode(self, arr: np.ndarray) -> bytes:
        a = np.ascontiguousarray(arr)
        if a.dtype == np.uint8:  # already 8-bit: just deflate
            lo, hi = 0.0, 255.0
            q = a
        else:
            af = a.astype(np.float64)
            lo = float(af.min()) if a.size else 0.0
            hi = float(af.max()) if a.size else 0.0
            scale = (hi - lo) or 1.0
            q = np.round((af - lo) / scale * 255.0).astype(np.uint8)
        return struct.pack("<dd", lo, hi) + zlib.compress(q.tobytes(), 1)

    def decode(self, data: bytes, shape, dtype) -> np.ndarray:
        lo, hi = struct.unpack("<dd", data[:16])
        q = np.frombuffer(zlib.decompress(data[16:]), dtype=np.uint8).reshape(shape)
        if np.dtype(dtype) == np.uint8 and lo == 0.0 and hi == 255.0:
            return q
        scale = (hi - lo) or 1.0
        return (q.astype(np.float64) / 255.0 * scale + lo).astype(dtype)


_REGISTRY: Dict[str, Codec] = {}


def register(codec: Codec) -> Codec:
    _REGISTRY[codec.name] = codec
    return codec


register(RawCodec())
register(ZlibCodec())
register(LzmaCodec())
register(Quant8Codec())


def get_codec(name: str) -> Codec:
    try:
        return _REGISTRY[name or "raw"]
    except KeyError:
        raise ValueError(f"unknown codec {name!r}; have {sorted(_REGISTRY)}") from None


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
