"""Streaming dataloader (§4.5): chunk-aware parallel fetch + decode + shuffle
buffer + collate, designed so the *training step*, not the pipeline, is the
bottleneck.

Pipeline per epoch:

 1. **Order plan** — view positions, shuffled chunk-group-wise: samples are
    grouped by the chunk (of the largest "primary" tensor) they live in; chunk
    groups are visited in random order, samples shuffled within group.  Each
    chunk is therefore fetched ~once per epoch while the emission stream is
    still well mixed — the paper's "shuffled stream access ... without a
    separate shuffle cluster" (§3.5), with the sample-level shuffle buffer
    providing the final decorrelation.
 2. **Fetch units** — contiguous runs of planned positions are work items on
    the :class:`SmartScheduler`.  A pool of threads (the C++-worker analogue:
    numpy/zlib decode releases the GIL) fetches each needed chunk ONCE per
    unit — as a single coalesced request via :meth:`Tensor.read_batch`,
    full GET vs. ranged reads decided by the fetch engine's cost model —
    decodes only the needed samples in place, applies the user transform,
    and deposits samples under a :class:`MemoryBudget` gate.
 3. **Emission** — shuffle mode draws uniformly from the ready buffer once it
    reaches ``shuffle_buffer`` samples; sequential mode emits in exact plan
    order via a reorder buffer.  Samples are collated (stack / list) into
    batch dicts.

The loader is re-iterable; every epoch reshuffles with ``seed + epoch``.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

import numpy as np

from . import fetch as fetchlib
from .scheduler import CostModel, MemoryBudget, SmartScheduler
from .views import DatasetView


@dataclass
class LoaderStats:
    samples: int = 0
    batches: int = 0
    bytes_fetched: int = 0
    io_requests: int = 0        # physical (coalesced) storage requests
    fetch_seconds: float = 0.0
    decode_seconds: float = 0.0
    wait_seconds: float = 0.0   # consumer blocked on pipeline
    wall_seconds: float = 0.0
    # data-skipping accounting, inherited from the view's TQL scan plan:
    # rows/chunks the planner proved dead, so this loader never fetches them
    rows_pruned: int = 0
    chunks_pruned: int = 0
    stats_groups_decided: int = 0

    def throughput(self) -> float:
        return self.samples / self.wall_seconds if self.wall_seconds else 0.0

    def utilization(self, step_seconds_per_batch: float) -> float:
        """Fraction of wall time the consumer would be busy given a fixed
        per-batch compute time — the Fig-7 'GPU utilization' analogue."""
        busy = self.batches * step_seconds_per_batch
        total = busy + self.wait_seconds
        return busy / total if total else 0.0


class _Unit:
    __slots__ = ("positions", "needed_at")

    def __init__(self, positions: List[int], needed_at: float) -> None:
        self.positions = positions
        self.needed_at = needed_at


class DeepLakeLoader:
    def __init__(
        self,
        view: DatasetView,
        *,
        batch_size: int = 32,
        shuffle: bool = False,
        shuffle_buffer: int = 1024,
        num_workers: int = 8,
        tensors: Optional[Sequence[str]] = None,
        transform: Optional[Callable[[Dict[str, Any]], Dict[str, Any]]] = None,
        collate: str = "stack",            # stack | list | callable
        drop_last: bool = False,
        seed: int = 0,
        prefetch_units: int = 8,
        unit_size: int = 16,
        memory_budget_bytes: int = 512 << 20,
        ranged_reads: Optional[bool] = None,
    ) -> None:
        self.view = view
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.shuffle_buffer = max(1, shuffle_buffer)
        self.num_workers = max(1, num_workers)
        self.tensor_names = list(tensors) if tensors else list(view.tensor_names)
        self.transform = transform
        self.collate = collate
        self.drop_last = drop_last
        self.seed = seed
        self.prefetch_units = prefetch_units
        self.unit_size = max(1, unit_size)
        self.memory = MemoryBudget(memory_budget_bytes)
        self.ranged_reads = ranged_reads
        self.costs = CostModel()
        self.stats = LoaderStats()
        self._engine = fetchlib.engine_for(view.dataset.storage)
        self._epoch = 0
        for t in self.tensor_names:
            if t not in view.tensor_names:
                raise KeyError(f"loader tensor {t!r} not in view")
        # a query view arrives with its scan plan: dead chunks were already
        # dropped from view.indices, so the order plan below never visits
        # them — here we only account for the work the planner saved.
        plan = getattr(view, "scan_plan", None)
        if plan:
            self.stats.rows_pruned = plan.get("rows_pruned", 0)
            self.stats.chunks_pruned = plan.get("chunks_pruned", 0)
            self.stats.stats_groups_decided = plan.get("groups_decided", 0)
            self.costs.note("chunks_pruned", self.stats.chunks_pruned)
            self.costs.note("rows_pruned", self.stats.rows_pruned)

    # ------------------------------------------------------------- planning
    def _primary_tensor(self) -> Optional[str]:
        best, best_bytes = None, -1
        for name in self.tensor_names:
            if name in self.view.derived:
                continue
            t = self.view._base_tensor(name)
            if t.meta.max_shape is None:
                continue
            nb = int(np.prod(t.meta.max_shape)) * np.dtype(t.meta.dtype).itemsize
            if nb > best_bytes:
                best, best_bytes = name, nb
        return best

    def _plan(self, rng: np.random.Generator) -> List[int]:
        n = len(self.view)
        if not self.shuffle:
            return list(range(n))
        primary = self._primary_tensor()
        if primary is None:
            order = np.arange(n)
            rng.shuffle(order)
            return order.tolist()
        enc = self.view._base_tensor(primary).encoder
        groups: Dict[int, List[int]] = defaultdict(list)
        for pos in range(n):
            groups[enc.chunk_ord_of(int(self.view.indices[pos]))].append(pos)
        keys = list(groups)
        rng.shuffle(keys)
        plan: List[int] = []
        for k in keys:
            g = groups[k]
            rng.shuffle(g)
            plan.extend(g)
        return plan

    # ------------------------------------------------------------ fetch unit
    def _prefetch_upcoming(self, units: List["_Unit"]) -> None:
        """Warm the fetch engine with the leading units' chunks so the
        first batches don't pay cold-start latency.  Futures carry this
        loader as owner: teardown cancels only them, and fetches they
        cause are attributed to this loader's stats.  Queued bytes are
        bounded by half the destination buffer (LRU tier or resident
        store), chunk sizes estimated from the stats sidecar."""
        if not fetchlib.coalescing_enabled():
            return  # A/B mode: measure the pre-batching request pattern
        if fetchlib.provider_cost_params(self.view.dataset.storage) is None:
            return  # local/memory: prefetch threads cost more than they save

        def account(nbytes: int) -> None:
            self.stats.bytes_fetched += nbytes
            self.stats.io_requests += 1
            self.costs.note("io_requests", 1)

        queued_bytes = 0
        for name in self.tensor_names:
            if name in self.view.derived:
                continue
            t = self.view._base_tensor(name)
            ords: List[int] = []
            seen: set = set()
            for u in units:
                for p in u.positions:
                    o = t.encoder.chunk_ord_of(int(self.view.indices[p]))
                    if o not in seen:
                        seen.add(o)
                        ords.append(o)
            queued_bytes = t.prefetch_chunks(ords, owner=self,
                                             on_fetched=account,
                                             queued_bytes=queued_bytes)

    def _estimate_sample_bytes(self) -> int:
        total = 0
        for name in self.tensor_names:
            if name in self.view.derived:
                continue
            t = self.view._base_tensor(name)
            if t.meta.max_shape:
                total += int(np.prod(t.meta.max_shape)) * np.dtype(t.meta.dtype).itemsize
        return max(total, 1024)

    def _fetch_unit(self, unit: _Unit) -> List[tuple]:
        """Fetch+decode all samples of a unit. Returns [(pos, sample_dict)].

        All storage I/O goes through :meth:`Tensor.read_batch`: one
        coalesced request per chunk (full GET vs. ranged reads decided by
        the fetch engine's cost model, replacing the old ``len(rows) <= 2``
        heuristic), with chunk ``k+1``'s fetch overlapping chunk ``k``'s
        decode on the engine pool.
        """
        out: Dict[int, Dict[str, Any]] = {p: {} for p in unit.positions}
        io: Dict[str, Any] = {"io_s": 0.0, "cpu_s": 0.0, "bytes": 0,
                              "requests": 0}
        gidxs = [int(self.view.indices[p]) for p in unit.positions]
        for name in self.tensor_names:
            if name in self.view.derived:
                for p in unit.positions:
                    out[p][name] = self.view.derived[name][p]
                continue
            tensor = self.view._base_tensor(name)
            vals = tensor.read_batch(gidxs, ranged=self.ranged_reads,
                                     io_stats=io)
            for p, v in zip(unit.positions, vals):
                out[p][name] = v
        t2 = time.perf_counter()
        result = []
        for p in unit.positions:
            sample = out[p]
            if self.transform is not None:
                sample = self.transform(sample)
            result.append((p, sample))
        t_io = io["io_s"]
        t_cpu = io["cpu_s"] + time.perf_counter() - t2
        self.costs.observe("unit", t_io, t_cpu)
        if io["requests"]:
            self.costs.note("io_requests", io["requests"])
        self.stats.fetch_seconds += t_io
        self.stats.decode_seconds += t_cpu
        self.stats.bytes_fetched += io["bytes"]
        self.stats.io_requests += io["requests"]
        return result

    # -------------------------------------------------------------- iterate
    def __iter__(self) -> Iterator[Dict[str, Any]]:
        rng = np.random.default_rng(self.seed + self._epoch)
        self._epoch += 1
        plan = self._plan(rng)
        n = len(plan)
        if n == 0:
            return
        units = [
            _Unit(plan[i: i + self.unit_size], needed_at=float(i))
            for i in range(0, n, self.unit_size)
        ]
        sched = SmartScheduler(self.costs)
        ready: "queue.Queue[Optional[List[tuple]]]" = queue.Queue()
        est_bytes = self._estimate_sample_bytes()
        inflight = threading.Semaphore(self.prefetch_units)
        stop = threading.Event()

        for u in units:
            sched.submit(u, u.needed_at, "unit")
        sched.close()
        self._prefetch_upcoming(units[: self.prefetch_units])

        def worker() -> None:
            while not stop.is_set():
                u = sched.take(timeout=0.1)
                if u is None:
                    break
                inflight.acquire()
                if stop.is_set():
                    inflight.release()
                    break
                if not self.memory.acquire(est_bytes * len(u.positions), timeout=30):
                    # budget still saturated after the timeout: hand the
                    # unit back to the scheduler so it is retried, never
                    # dropped (a lost unit hangs sequential iteration on
                    # the reorder buffer forever)
                    inflight.release()
                    sched.submit(u, u.needed_at, "unit")
                    continue
                try:
                    ready.put(self._fetch_unit(u))
                except Exception as e:  # surface worker errors to consumer
                    ready.put(e)  # type: ignore[arg-type]

        threads = [threading.Thread(target=worker, daemon=True)
                   for _ in range(self.num_workers)]
        t_start = time.perf_counter()
        for t in threads:
            t.start()

        emitted = 0
        batch: List[Dict[str, Any]] = []
        buffer: List[Dict[str, Any]] = []          # shuffle mode
        reorder: Dict[int, Dict[str, Any]] = {}    # sequential mode
        next_pos_i = 0
        plan_rank = {p: i for i, p in enumerate(plan)}

        def drain_one(block: bool) -> bool:
            """Move one completed unit into the emission buffers."""
            nonlocal emitted
            try:
                t0 = time.perf_counter()
                item = ready.get(timeout=60 if block else 0.001)
                self.stats.wait_seconds += time.perf_counter() - t0
            except queue.Empty:
                return False
            if isinstance(item, Exception):
                stop.set()
                raise item
            inflight.release()
            self.memory.release(est_bytes * len(item))
            for pos, sample in item:
                if self.shuffle:
                    buffer.append(sample)
                else:
                    reorder[plan_rank[pos]] = sample
            return True

        try:
            while emitted < n:
                if self.shuffle:
                    target = min(self.shuffle_buffer, n - emitted)
                    while len(buffer) < target and emitted + len(buffer) < n:
                        if not drain_one(block=True):
                            break
                    while not drain_one(block=False):
                        break
                    if not buffer:
                        continue
                    j = int(rng.integers(len(buffer)))
                    buffer[j], buffer[-1] = buffer[-1], buffer[j]
                    sample = buffer.pop()
                else:
                    while next_pos_i not in reorder:
                        drain_one(block=True)
                    sample = reorder.pop(next_pos_i)
                    next_pos_i += 1
                emitted += 1
                self.stats.samples += 1
                batch.append(sample)
                if len(batch) == self.batch_size:
                    self.stats.batches += 1
                    yield self._collate(batch)
                    batch = []
            if batch and not self.drop_last:
                self.stats.batches += 1
                yield self._collate(batch)
        finally:
            stop.set()
            sched.close()
            self._engine.cancel_pending(owner=self)  # drop OUR prefetches
            # unblock any workers stuck on inflight/memory gates
            for _ in threads:
                inflight.release()
            while not ready.empty():
                try:
                    item = ready.get_nowait()
                    if not isinstance(item, Exception):
                        self.memory.release(est_bytes * len(item))
                except queue.Empty:
                    break
            for t in threads:
                t.join(timeout=2)
            self.stats.wall_seconds += time.perf_counter() - t_start

    # --------------------------------------------------------------- collate
    def _collate(self, samples: List[Dict[str, Any]]) -> Dict[str, Any]:
        if callable(self.collate):
            return self.collate(samples)
        out: Dict[str, Any] = {}
        keys = samples[0].keys()
        for k in keys:
            vals = [s[k] for s in samples]
            if self.collate == "stack":
                shapes = {np.asarray(v).shape for v in vals}
                out[k] = (np.stack([np.asarray(v) for v in vals])
                          if len(shapes) == 1 else vals)
            else:
                out[k] = vals
        return out

    def __len__(self) -> int:
        n = len(self.view)
        return n // self.batch_size if self.drop_last \
            else (n + self.batch_size - 1) // self.batch_size
