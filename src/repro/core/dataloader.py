"""Streaming dataloader (§4.5) on the unified scan pipeline: **plan →
schedule → prefetch → stream-decode**, designed so the *training step*, not
the pipeline, is the bottleneck.

Per epoch:

 1. **Plan** — view positions, shuffled chunk-group-wise: samples are
    grouped by the chunk (of the largest "primary" tensor) they live in; chunk
    groups are visited in random order, samples shuffled within group.
    Within each :data:`~DeepLakeLoader.WARM_WINDOW`-sized window of that
    order, groups whose chunks are already resident or in flight on the
    fetch engine are visited first (pipeline-aware shuffle; stats-neutral
    ``has_blob`` probe) — the epoch-level sample distribution is unchanged
    and a cold engine reduces to the exact seeded order.  Each
    chunk is therefore fetched ~once per epoch while the emission stream is
    still well mixed — the paper's "shuffled stream access ... without a
    separate shuffle cluster" (§3.5), with the sample-level shuffle buffer
    providing the final decorrelation.  A query view arrives with its TQL
    scan plan already applied: pruned chunks were dropped before the loader
    ever saw them.
 2. **Schedule** — contiguous runs of planned positions become fetch units
    on the :class:`SmartScheduler`.  ``unit_size`` and ``prefetch_units``
    default to values derived from the fetch engine's latency/bandwidth
    estimates via :meth:`CostModel.derive_unit_size` /
    :meth:`~repro.core.scheduler.CostModel.derive_prefetch_units` (the old
    fixed defaults remain the local-storage fallback and can be pinned
    explicitly).
 3. **Prefetch** — the whole order plan registers with a
    :class:`~repro.core.pipeline.ScanPipeline`; as workers start and finish
    units, the pipeline keeps a ``prefetch_units``-deep, byte-bounded
    window of upcoming units' chunks in flight on the shared
    :class:`~repro.core.fetch.FetchEngine` — **across unit boundaries**, so
    the fetch horizon always runs ahead of the worker pool instead of only
    warming the first units of the epoch.  Teardown cancels only this
    loader's queued prefetches.
 4. **Stream-decode** — a pool of threads (the C++-worker analogue:
    numpy/zlib decode releases the GIL) fetches each needed chunk ONCE per
    unit — as a single coalesced request via :meth:`Tensor.read_batch`
    (resident prefetched blobs are sliced for free), full GET vs. ranged
    reads decided by the engine's cost model — decodes only the needed
    samples, applies the user transform, and deposits samples under a
    :class:`MemoryBudget` gate.  Shuffle mode then draws uniformly from
    the ready buffer; sequential mode emits in exact plan order via a
    reorder buffer; samples are collated (stack / list) into batch dicts.

The loader is re-iterable; every epoch reshuffles with ``seed + epoch``.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import defaultdict
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

import numpy as np

from . import fetch as fetchlib
from . import telemetry
from .pipeline import ScanPipeline, derive_schedule_params
from .scheduler import CostModel, MemoryBudget, SmartScheduler
from .views import DatasetView

#: fixed fallbacks for cost-free (local/memory) providers, where adaptive
#: sizing has no latency signal to work from
DEFAULT_UNIT_SIZE = 16
DEFAULT_PREFETCH_UNITS = 8


@dataclass
class LoaderStats:
    samples: int = 0
    batches: int = 0
    bytes_fetched: int = 0
    io_requests: int = 0        # physical (coalesced) storage requests
    fetch_seconds: float = 0.0
    decode_seconds: float = 0.0
    wait_seconds: float = 0.0   # consumer blocked on pipeline
    # wait_seconds partitioned by what the workers were doing when the
    # consumer blocked (fetch | decode | buffer_full): values always sum
    # exactly to wait_seconds (same timing measurement, one cause each)
    stall_by_cause: Dict[str, float] = field(default_factory=dict)
    wall_seconds: float = 0.0
    # data-skipping accounting, inherited from the view's TQL scan plan:
    # rows/chunks the planner proved dead, so this loader never fetches them
    rows_pruned: int = 0
    chunks_pruned: int = 0
    stats_groups_decided: int = 0
    # aggregation pushdown: chunk groups whose partial aggregates were
    # answered from ChunkStats alone (zero payload fetches)
    agg_groups_stats_answered: int = 0
    # ORDER BY + LIMIT top-k accounting (view's topk plan): chunk groups the
    # bound cutoff proved irrelevant, terminated before fetch or decode
    topk_groups_skipped: int = 0

    def throughput(self) -> float:
        return self.samples / self.wall_seconds if self.wall_seconds else 0.0

    def utilization(self, step_seconds_per_batch: float) -> float:
        """Fraction of wall time the consumer would be busy given a fixed
        per-batch compute time — the Fig-7 'GPU utilization' analogue."""
        busy = self.batches * step_seconds_per_batch
        total = busy + self.wait_seconds
        return busy / total if total else 0.0


class _Unit:
    __slots__ = ("positions", "needed_at", "index")

    def __init__(self, positions: List[int], needed_at: float,
                 index: int) -> None:
        self.positions = positions
        self.needed_at = needed_at
        self.index = index      # plan-order rank; the pipeline's step key


class DeepLakeLoader:
    def __init__(
        self,
        view: DatasetView,
        *,
        batch_size: int = 32,
        shuffle: bool = False,
        shuffle_buffer: int = 1024,
        num_workers: int = 8,
        tensors: Optional[Sequence[str]] = None,
        transform: Optional[Callable[[Dict[str, Any]], Dict[str, Any]]] = None,
        collate: str = "stack",            # stack | list | callable
        drop_last: bool = False,
        seed: int = 0,
        prefetch_units: Optional[int] = None,
        unit_size: Optional[int] = None,
        memory_budget_bytes: int = 512 << 20,
        ranged_reads: Optional[bool] = None,
    ) -> None:
        self.view = view
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.shuffle_buffer = max(1, shuffle_buffer)
        self.num_workers = max(1, num_workers)
        self.tensor_names = list(tensors) if tensors else list(view.tensor_names)
        self.transform = transform
        self.collate = collate
        self.drop_last = drop_last
        self.seed = seed
        # None = adaptive: re-derived every epoch from the fetch engine's
        # latency/bandwidth EWMA + observed per-unit decode times
        self.prefetch_units = None if prefetch_units is None \
            else max(1, prefetch_units)
        self.unit_size = None if unit_size is None else max(1, unit_size)
        self.memory = MemoryBudget(memory_budget_bytes)
        self.ranged_reads = ranged_reads
        self.costs = CostModel()
        self.stats = LoaderStats()
        self._engine = fetchlib.engine_for(view.dataset.storage)
        self._epoch = 0
        # live worker-phase occupancy, sampled when the consumer blocks to
        # attribute that stall to a cause (fetch | decode | buffer_full)
        self._phase_lock = threading.Lock()
        self._phases = {"fetch": 0, "decode": 0, "buffer_full": 0}
        for t in self.tensor_names:
            if t not in view.tensor_names:
                raise KeyError(f"loader tensor {t!r} not in view")
        # a query view arrives with its scan plan: dead chunks were already
        # dropped from view.indices, so the order plan below never visits
        # them — here we only account for the work the planner saved.
        plan = getattr(view, "scan_plan", None)
        if plan:
            self.stats.rows_pruned = plan.get("rows_pruned", 0)
            self.stats.chunks_pruned = plan.get("chunks_pruned", 0)
            self.stats.stats_groups_decided = plan.get("groups_decided", 0)
            self.stats.agg_groups_stats_answered = plan.get(
                "agg_groups_stats_answered", 0)
            self.costs.note("chunks_pruned", self.stats.chunks_pruned)
            self.costs.note("rows_pruned", self.stats.rows_pruned)
        topk = getattr(view, "topk_plan", None)
        if topk:
            self.stats.topk_groups_skipped = topk.get("groups_skipped", 0)
            self.costs.note("topk_groups_skipped",
                            self.stats.topk_groups_skipped)

    # ------------------------------------------------------------- planning
    def _primary_tensor(self) -> Optional[str]:
        best, best_bytes = None, -1
        for name in self.tensor_names:
            if name in self.view.derived:
                continue
            t = self.view._base_tensor(name)
            if t.meta.max_shape is None:
                continue
            nb = int(np.prod(t.meta.max_shape)) * np.dtype(t.meta.dtype).itemsize
            if nb > best_bytes:
                best, best_bytes = name, nb
        return best

    def _plan(self, rng: np.random.Generator) -> List[int]:
        n = len(self.view)
        if not self.shuffle:
            return list(range(n))
        primary = self._primary_tensor()
        if primary is None:
            order = np.arange(n)
            rng.shuffle(order)
            return order.tolist()
        enc = self.view._base_tensor(primary).encoder
        groups: Dict[int, List[int]] = defaultdict(list)
        for pos in range(n):
            groups[enc.chunk_ord_of(int(self.view.indices[pos]))].append(pos)
        keys = list(groups)
        rng.shuffle(keys)
        keys = self._warm_first(keys, primary)
        plan: List[int] = []
        for k in keys:
            g = groups[k]
            rng.shuffle(g)
            plan.extend(g)
        return plan

    #: shuffle unit: chunk groups are reordered warm-first only within
    #: windows of this many groups, so the visit order stays a local
    #: permutation of the seeded shuffle
    WARM_WINDOW = 8

    def _warm_first(self, keys: List[int], primary: str) -> List[int]:
        """Pipeline-aware shuffle: within each :data:`WARM_WINDOW`-sized
        window of the seeded group order, visit chunk groups whose blobs
        are already resident or in flight on the engine before cold ones
        (stats-neutral :meth:`FetchEngine.has_blob` probe).  The epoch
        still covers exactly the same groups and samples — only the order
        *within* each window changes — and on a cold engine every probe
        misses, so the reorder is the identity and the plan is exactly the
        seeded ``seed + epoch`` shuffle (determinism baseline)."""
        if len(keys) <= 1:
            return keys
        tensor = self.view._base_tensor(primary)
        enc = tensor.encoder
        out: List[int] = []
        for i in range(0, len(keys), self.WARM_WINDOW):
            window = keys[i: i + self.WARM_WINDOW]
            # stable partition: warm groups first, seeded order preserved
            # inside each class
            out.extend(sorted(
                window,
                key=lambda k: not self._engine.has_blob(
                    tensor._chunk_key(enc.name_of(k)))))
        return out

    # ------------------------------------------------------------ scheduling
    def _schedule_params(self) -> tuple:
        """(unit_size, prefetch_units) for this epoch: explicit values win;
        otherwise derived from the engine's latency/bandwidth estimates
        (cost-bearing providers) or the fixed local defaults."""
        unit_size, pf_units = self.unit_size, self.prefetch_units
        if unit_size is not None and pf_units is not None:
            return unit_size, pf_units
        if fetchlib.provider_cost_params(self.view.dataset.storage) is None:
            d_us, d_pf = DEFAULT_UNIT_SIZE, DEFAULT_PREFETCH_UNITS
        else:
            d_us, d_pf = derive_schedule_params(
                self._engine, self.costs, self._estimate_sample_bytes(),
                self.memory.max_bytes)
        return (unit_size if unit_size is not None else d_us,
                pf_units if pf_units is not None else d_pf)

    @contextmanager
    def _phase(self, name: str) -> Iterator[None]:
        with self._phase_lock:
            self._phases[name] += 1
        try:
            yield
        finally:
            with self._phase_lock:
                self._phases[name] -= 1

    def _stall_cause(self) -> str:
        """What the worker pool is doing right now — the cause charged to a
        consumer stall that starts at this instant.  Priority: a worker
        blocked on the memory budget dominates (the buffer, not I/O, is the
        ceiling); otherwise decoding only counts when nothing is fetching;
        the default is ``fetch`` (workers idle-waiting on I/O or the
        scheduler)."""
        with self._phase_lock:
            if self._phases["buffer_full"]:
                return "buffer_full"
            if self._phases["decode"] and not self._phases["fetch"]:
                return "decode"
            return "fetch"

    def _account_prefetch(self, nbytes: int) -> None:
        """Physical fetches the pipeline's prefetch window caused are
        attributed to this loader's stats (never dedup'd re-requests)."""
        self.stats.bytes_fetched += nbytes
        self.stats.io_requests += 1
        self.costs.note("io_requests", 1)

    def _estimate_sample_bytes(self) -> int:
        total = 0
        for name in self.tensor_names:
            if name in self.view.derived:
                continue
            t = self.view._base_tensor(name)
            if t.meta.max_shape:
                total += int(np.prod(t.meta.max_shape)) * np.dtype(t.meta.dtype).itemsize
        return max(total, 1024)

    def _fetch_unit(self, unit: _Unit) -> List[tuple]:
        """Fetch+decode all samples of a unit. Returns [(pos, sample_dict)].

        All storage I/O goes through :meth:`Tensor.read_batch`: one
        coalesced request per chunk (full GET vs. ranged reads decided by
        the fetch engine's cost model, replacing the old ``len(rows) <= 2``
        heuristic), with chunk ``k+1``'s fetch overlapping chunk ``k``'s
        decode on the engine pool.
        """
        out: Dict[int, Dict[str, Any]] = {p: {} for p in unit.positions}
        io: Dict[str, Any] = {"io_s": 0.0, "cpu_s": 0.0, "bytes": 0,
                              "requests": 0}
        faults_before = self._engine.fault_events()
        gidxs = [int(self.view.indices[p]) for p in unit.positions]
        with self._phase("fetch"), \
                telemetry.gspan(unit.index, "fetch", rows=len(unit.positions)):
            for name in self.tensor_names:
                if name in self.view.derived:
                    for p in unit.positions:
                        out[p][name] = self.view.derived[name][p]
                    continue
                tensor = self.view._base_tensor(name)
                vals = tensor.read_batch(gidxs, ranged=self.ranged_reads,
                                         io_stats=io)
                for p, v in zip(unit.positions, vals):
                    out[p][name] = v
        t2 = time.perf_counter()
        result = []
        with self._phase("decode"), telemetry.gspan(unit.index, "decode"):
            for p in unit.positions:
                sample = out[p]
                if self.transform is not None:
                    sample = self.transform(sample)
                result.append((p, sample))
        t_io = io["io_s"]
        t_cpu = io["cpu_s"] + time.perf_counter() - t2
        # a unit whose reads hit injected faults / retries / hedges carries
        # backoff + duplicate-request time: keep it out of the unit EWMA
        # that sizes next epoch's units and prefetch depth
        self.costs.observe("unit", t_io, t_cpu,
                           clean=self._engine.fault_events() == faults_before)
        if io["requests"]:
            self.costs.note("io_requests", io["requests"])
        self.stats.fetch_seconds += t_io
        self.stats.decode_seconds += t_cpu
        self.stats.bytes_fetched += io["bytes"]
        self.stats.io_requests += io["requests"]
        return result

    # -------------------------------------------------------------- iterate
    def __iter__(self) -> Iterator[Dict[str, Any]]:
        rng = np.random.default_rng(self.seed + self._epoch)
        self._epoch += 1
        plan = self._plan(rng)
        n = len(plan)
        if n == 0:
            return
        unit_size, prefetch_units = self._schedule_params()
        units = [
            _Unit(plan[i: i + unit_size], needed_at=float(i),
                  index=i // unit_size)
            for i in range(0, n, unit_size)
        ]
        sched = SmartScheduler(self.costs)
        ready: "queue.Queue[Optional[List[tuple]]]" = queue.Queue()
        est_bytes = self._estimate_sample_bytes()
        inflight = threading.Semaphore(prefetch_units)
        stop = threading.Event()

        for u in units:
            sched.submit(u, u.needed_at, "unit")
        sched.close()
        # the whole order plan registers with the scan pipeline: the
        # prefetch window follows the workers across unit boundaries
        pipe = ScanPipeline.for_units(
            self.view, [t for t in self.tensor_names
                        if t not in self.view.derived],
            [u.positions for u in units], prefetch_units=prefetch_units,
            owner=self, on_fetched=self._account_prefetch)
        pipe.on_unit_start(0)  # warm the leading window before workers spin

        def worker() -> None:
            while not stop.is_set():
                u = sched.take(timeout=0.1)
                if u is None:
                    break
                pipe.on_unit_start(u.index)
                inflight.acquire()
                if stop.is_set():
                    inflight.release()
                    break
                with self._phase("buffer_full"):
                    got = self.memory.acquire(est_bytes * len(u.positions),
                                              timeout=30)
                if not got:
                    # budget still saturated after the timeout: hand the
                    # unit back to the scheduler so it is retried, never
                    # dropped (a lost unit hangs sequential iteration on
                    # the reorder buffer forever)
                    inflight.release()
                    sched.submit(u, u.needed_at, "unit")
                    continue
                try:
                    result = self._fetch_unit(u)
                    # unit decoded: its chunks leave the prefetch window,
                    # freeing budget for the next units' chunks
                    pipe.on_unit_done(u.index)
                    ready.put(result)
                except Exception as e:  # surface worker errors to consumer
                    ready.put(e)  # type: ignore[arg-type]

        threads = [threading.Thread(target=worker, daemon=True)
                   for _ in range(self.num_workers)]
        t_start = time.perf_counter()
        for t in threads:
            t.start()

        emitted = 0
        batch: List[Dict[str, Any]] = []
        buffer: List[Dict[str, Any]] = []          # shuffle mode
        reorder: Dict[int, Dict[str, Any]] = {}    # sequential mode
        next_pos_i = 0
        plan_rank = {p: i for i, p in enumerate(plan)}

        def drain_one(block: bool) -> bool:
            """Move one completed unit into the emission buffers."""
            nonlocal emitted
            # sample the worker pool's phase BEFORE blocking: that is the
            # cause this stall is charged to (exactly one per wait, so
            # stall_by_cause always sums to wait_seconds)
            cause = self._stall_cause()
            sp = telemetry.span("loader.stall", cause=cause) if block \
                else telemetry.null_span()
            try:
                with sp:
                    t0 = time.perf_counter()
                    item = ready.get(timeout=60 if block else 0.001)
                waited = time.perf_counter() - t0
                self.stats.wait_seconds += waited
                self.stats.stall_by_cause[cause] = \
                    self.stats.stall_by_cause.get(cause, 0.0) + waited
            except queue.Empty:
                return False
            if isinstance(item, Exception):
                stop.set()
                raise item
            inflight.release()
            self.memory.release(est_bytes * len(item))
            for pos, sample in item:
                if self.shuffle:
                    buffer.append(sample)
                else:
                    reorder[plan_rank[pos]] = sample
            return True

        try:
            while emitted < n:
                if self.shuffle:
                    target = min(self.shuffle_buffer, n - emitted)
                    while len(buffer) < target and emitted + len(buffer) < n:
                        if not drain_one(block=True):
                            break
                    while not drain_one(block=False):
                        break
                    if not buffer:
                        continue
                    j = int(rng.integers(len(buffer)))
                    buffer[j], buffer[-1] = buffer[-1], buffer[j]
                    sample = buffer.pop()
                else:
                    while next_pos_i not in reorder:
                        drain_one(block=True)
                    sample = reorder.pop(next_pos_i)
                    next_pos_i += 1
                emitted += 1
                self.stats.samples += 1
                batch.append(sample)
                if len(batch) == self.batch_size:
                    self.stats.batches += 1
                    yield self._collate(batch)
                    batch = []
            if batch and not self.drop_last:
                self.stats.batches += 1
                yield self._collate(batch)
        finally:
            stop.set()
            sched.close()
            pipe.close()  # drop OUR queued prefetches (owner-scoped)
            # unblock any workers stuck on inflight/memory gates
            for _ in threads:
                inflight.release()
            while not ready.empty():
                try:
                    item = ready.get_nowait()
                    if not isinstance(item, Exception):
                        self.memory.release(est_bytes * len(item))
                except queue.Empty:
                    break
            for t in threads:
                t.join(timeout=2)
            self.stats.wall_seconds += time.perf_counter() - t_start

    # --------------------------------------------------------------- collate
    def _collate(self, samples: List[Dict[str, Any]]) -> Dict[str, Any]:
        if callable(self.collate):
            return self.collate(samples)
        out: Dict[str, Any] = {}
        keys = samples[0].keys()
        for k in keys:
            vals = [s[k] for s in samples]
            if self.collate == "stack":
                shapes = {np.asarray(v).shape for v in vals}
                out[k] = (np.stack([np.asarray(v) for v in vals])
                          if len(shapes) == 1 else vals)
            else:
                out[k] = vals
        return out

    def __len__(self) -> int:
        n = len(self.view)
        return n // self.batch_size if self.drop_last \
            else (n + self.batch_size - 1) // self.batch_size
