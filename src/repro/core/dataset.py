"""Dataset: parallel tensor columns + groups + version control (§3.1, §4.1).

A *sample* is one row indexed across parallel tensors.  Tensors are logically
independent columns (partial column access is what makes streaming selected
tensors cheap).  Groups are syntactic nesting: tensor names may contain ``/``
and a :class:`Group` proxy scopes creation/access, avoiding hierarchical
layout in the format itself (§3.1).
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterator, List, Optional, Sequence, Union

import numpy as np

from . import manifest as manifestlib
from .htypes import get_htype, parse_htype
from .storage import (MemoryProvider, StorageError, StorageProvider,
                      storage_from_path)
from .tensor import DEFAULT_MAX_CHUNK, DEFAULT_MIN_CHUNK, Tensor, TensorMeta
from .version_control import VersionControl

DS_META_KEY = "ds_meta.json"


class MergeConflict(RuntimeError):
    pass


class Group:
    """Syntactic-nesting proxy: ``ds.group('a').create_tensor('b')`` == 'a/b'."""

    def __init__(self, ds: "Dataset", prefix: str) -> None:
        self._ds = ds
        self._prefix = prefix.rstrip("/")

    def create_tensor(self, name: str, **kw) -> Tensor:
        return self._ds.create_tensor(f"{self._prefix}/{name}", **kw)

    def __getitem__(self, name: str) -> Tensor:
        return self._ds[f"{self._prefix}/{name}"]

    def group(self, name: str) -> "Group":
        return Group(self._ds, f"{self._prefix}/{name}")

    def tensors(self) -> List[str]:
        p = self._prefix + "/"
        return [t for t in self._ds.tensor_names if t.startswith(p)]


class Dataset:
    def __init__(self, storage: Union[str, StorageProvider, None] = None) -> None:
        if storage is None:
            storage = MemoryProvider()
        elif isinstance(storage, str):
            storage = storage_from_path(storage)
        self.storage = storage
        # manifest-first cold open: the pointer (one GET) carries the format
        # marker and the version tree; its segments carry all per-tensor
        # state, so no per-file probing happens at all.  Legacy datasets
        # (no pointer) keep the per-file path and adopt a manifest on their
        # next commit or via maintenance compaction.
        m = manifestlib.Manifest.load(storage)
        if m is None and storage.get_or_none(DS_META_KEY) is None:
            # brand-new dataset: manifest-native from birth
            storage.put_verified(DS_META_KEY,
                                 json.dumps({"format": "deeplake-repro-v1"}).encode())
            m = manifestlib.Manifest.create(storage)
        self.vc = VersionControl(storage, manifest=m)
        self._tensors: Dict[str, Tensor] = {}

    @property
    def manifest(self):
        """The dataset manifest (None on a legacy per-file dataset)."""
        return self.vc.manifest

    def maintenance(self) -> "maintenance.MaintenanceRunner":
        """Background-maintenance entry point: stats backfill, manifest
        compaction, orphan-chunk GC (:mod:`repro.core.maintenance`)."""
        from . import maintenance
        return maintenance.MaintenanceRunner(self)

    # ----------------------------------------------------------------- schema
    @property
    def tensor_names(self) -> List[str]:
        return self.vc.schema_tensors()

    @property
    def groups(self) -> List[str]:
        seen = set()
        for t in self.tensor_names:
            parts = t.split("/")[:-1]
            for i in range(1, len(parts) + 1):
                seen.add("/".join(parts[:i]))
        return sorted(seen)

    def group(self, name: str) -> Group:
        return Group(self, name)

    def create_tensor(self, name: str, htype: str = "generic",
                      dtype: Optional[str] = None,
                      sample_compression: Optional[str] = None,
                      min_chunk_size: int = DEFAULT_MIN_CHUNK,
                      max_chunk_size: int = DEFAULT_MAX_CHUNK,
                      strict: bool = True) -> Tensor:
        self.vc.require_writable()
        if name in self.tensor_names:
            raise ValueError(f"tensor {name!r} exists")
        parse_htype(htype)  # validate
        spec = get_htype(htype)
        meta = TensorMeta(
            htype=htype,
            dtype=dtype or spec.default_dtype,
            codec=sample_compression or spec.default_codec,
            min_chunk_size=min_chunk_size,
            max_chunk_size=max_chunk_size,
            strict=strict,
        )
        t = Tensor(name, self.vc, meta=meta)
        self.vc.set_schema_tensors(self.tensor_names + [name])
        self.vc.record_created(name)
        self._tensors[name] = t
        t.flush()
        return t

    def delete_tensor(self, name: str) -> None:
        """Schema evolution: drop a column in the current version."""
        self.vc.require_writable()
        names = self.tensor_names
        if name not in names:
            raise KeyError(name)
        names.remove(name)
        self.vc.set_schema_tensors(names)
        self._tensors.pop(name, None)

    # ----------------------------------------------------------------- access
    def _tensor(self, name: str) -> Tensor:
        if name not in self._tensors:
            if name not in self.tensor_names:
                raise KeyError(f"no tensor {name!r}; have {self.tensor_names}")
            self._tensors[name] = Tensor(name, self.vc)
        return self._tensors[name]

    @property
    def tensors(self) -> Dict[str, Tensor]:
        return {n: self._tensor(n) for n in self.tensor_names}

    def __getitem__(self, item):
        if isinstance(item, str):
            return self._tensor(item)
        from .views import DatasetView
        n = len(self)
        if isinstance(item, (int, np.integer)):
            return DatasetView(self, np.asarray([int(item) % n if item < 0 else int(item)]))
        if isinstance(item, slice):
            return DatasetView(self, np.arange(*item.indices(n)))
        if isinstance(item, (list, np.ndarray)):
            return DatasetView(self, np.asarray(item, dtype=np.int64))
        raise TypeError(f"bad index {item!r}")

    def __getattr__(self, name: str) -> Tensor:
        # attribute access for tensors: ds.images
        if name.startswith("_"):
            raise AttributeError(name)
        try:
            return self._tensor(name)
        except KeyError:
            raise AttributeError(name) from None

    def __len__(self) -> int:
        return max((len(t) for t in self.tensors.values()), default=0)

    @property
    def min_len(self) -> int:
        return min((len(t) for t in self.tensors.values()), default=0)

    def append(self, row: Dict[str, Any]) -> int:
        """Append one row across tensors; returns the new row index."""
        unknown = set(row) - set(self.tensor_names)
        if unknown:
            raise KeyError(f"unknown tensors in row: {sorted(unknown)}")
        idx = -1
        for name, value in row.items():
            idx = self._tensor(name).append(value)
        return idx

    def extend(self, rows: Union[Dict[str, Sequence[Any]], Sequence[Dict[str, Any]]]) -> None:
        if isinstance(rows, dict):
            lengths = {len(v) for v in rows.values()}
            if len(lengths) > 1:
                raise ValueError("column lengths differ")
            n = lengths.pop() if lengths else 0
            for i in range(n):
                self.append({k: v[i] for k, v in rows.items()})
        else:
            for r in rows:
                self.append(r)

    def read_row(self, idx: int, tensors: Optional[Sequence[str]] = None) -> Dict[str, np.ndarray]:
        names = list(tensors) if tensors else self.tensor_names
        return {n: self._tensor(n).read(idx) for n in names}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        for i in range(self.min_len):
            yield self.read_row(i)

    # ------------------------------------------------------------------ I/O
    def flush(self) -> None:
        for t in self._tensors.values():
            t.flush()
        self.vc.save_info()

    # -------------------------------------------------------------- version control
    def commit(self, message: str = "") -> str:
        # flush is passed as a callback so the rebase-and-retry loop in
        # VersionControl.commit can re-run it after relocating the head
        # (a conflicting foreign commit can surface *during* flush, at the
        # first put_state -> mark_stale fence).
        sealed = self.vc.commit(message, flush=self.flush)
        self._tensors.clear()  # state moved to the new head
        return sealed

    def checkout(self, ref: str, create: bool = False) -> str:
        self.flush()
        nid = self.vc.checkout(ref, create=create)
        self._tensors.clear()
        return nid

    @property
    def branch(self) -> str:
        return self.vc.current.branch

    @property
    def commit_id(self) -> str:
        return self.vc.current_id

    @property
    def branches(self) -> List[str]:
        return sorted(self.vc.branches)

    def log(self):
        return self.vc.log()

    def diff(self, ref_a: Optional[str] = None, ref_b: Optional[str] = None):
        self.flush()
        a = ref_a or self.vc.current_id
        b = ref_b or self.vc.current_id
        return self.vc.diff_between(a, b)

    def tensor_at(self, name: str, ref: str) -> Tensor:
        """Read-only tensor bound to another version (time travel)."""
        return Tensor(name, self.vc, node_id=self.vc.resolve_ref(ref))

    # ------------------------------------------------------------------ merge
    def merge(self, ref: str, policy: str = "theirs") -> str:
        """Merge ``ref`` into the current branch (§4.1).

        Sample identity is by sample id.  Conflicts (same sample updated on
        both sides since the LCA) resolve per ``policy``:
        ``theirs`` | ``ours`` | ``raise``.
        """
        if policy not in ("theirs", "ours", "raise"):
            raise ValueError(f"bad policy {policy!r}")
        self.vc.require_writable()
        self.flush()
        src_id = self.vc.resolve_ref(ref)
        diffs = self.vc.diff_between(self.vc.current_id, src_id)
        theirs_all, ours_all = diffs["b"], diffs["a"]
        src_tensors = self.vc.schema_tensors(src_id)
        for tname in src_tensors:
            src_t = Tensor(tname, self.vc, node_id=src_id)
            if tname not in self.tensor_names:
                # tensor created on src: adopt schema + all rows
                meta = TensorMeta.from_json(src_t.meta.to_json())
                meta.min_shape = meta.max_shape = None
                dst = Tensor(tname, self.vc, meta=meta)
                self.vc.set_schema_tensors(self.tensor_names + [tname])
                self.vc.record_created(tname)
                self._tensors[tname] = dst
                for i in range(len(src_t)):
                    dst.append(src_t.read(i), sample_id=src_t.sample_ids[i])
                dst.flush()
                continue
            dst = self._tensor(tname)
            their_d = theirs_all.get(tname)
            if not their_d:
                continue
            our_d = ours_all.get(tname, {})
            ours_ids = {dst.sample_ids[i]: i for i in range(len(dst))}
            our_updated_ids = {dst.sample_ids[i] for i in our_d.get("updated", [])
                               if i < len(dst)}
            # 1) their appends -> append if id unseen
            first, count = their_d.get("added_first", -1), their_d.get("added_count", 0)
            if count:
                for i in range(first, first + count):
                    sid = src_t.sample_ids[i]
                    if sid not in ours_ids:
                        dst.append(src_t.read(i), sample_id=sid)
            # 2) their updates -> apply by id, respecting policy on conflict
            for i in their_d.get("updated", []):
                if i >= len(src_t):
                    continue
                sid = src_t.sample_ids[i]
                if sid not in ours_ids:
                    continue
                if sid in our_updated_ids:
                    if policy == "raise":
                        raise MergeConflict(
                            f"tensor {tname!r}: sample id {sid} updated on both sides")
                    if policy == "ours":
                        continue
                dst[ours_ids[sid]] = src_t.read(i)
            dst.flush()
        return self.commit(f"merge {ref!r} into {self.branch!r}")

    # ------------------------------------------------------------------ query
    def query(self, tql: str, engine: str = "auto", use_stats: bool = True,
              stream: Optional[bool] = None, shards: Optional[int] = None,
              tenant: Optional[str] = None):
        """Run a TQL query.  ``stream``: None = auto (WHERE evaluates per
        chunk group on the scan pipeline when the view spans several
        groups), False = whole-view column stack, True = force streaming.
        ``shards`` > 1 runs the per-chunk-group scan shard-parallel.  All
        modes return byte-identical result sets.  ``tenant`` tags the
        scan's prefetches for the engine's fair scheduler."""
        from .tql import execute_query
        return execute_query(self, tql, engine=engine, use_stats=use_stats,
                             stream=stream, shards=shards, tenant=tenant)

    def dataloader(self, **kw):
        from .dataloader import DeepLakeLoader
        from .views import DatasetView
        return DeepLakeLoader(DatasetView.full(self), **kw)

    def pytorch_like(self, **kw):
        return self.dataloader(**kw)

    # ------------------------------------------------------------------ misc
    def summary(self) -> str:
        lines = [f"Dataset @ {self.storage.kind} | branch={self.branch} "
                 f"head={self.commit_id[:8]} rows={len(self)}"]
        for n, t in sorted(self.tensors.items()):
            lines.append(f"  {n:24s} {t.htype:16s} {str(t.dtype):8s} "
                         f"shape={t.shape} chunks={t.num_chunks}")
        return "\n".join(lines)


def dataset(storage: Union[str, StorageProvider, None] = None) -> Dataset:
    """Public constructor, mirroring ``deeplake.dataset(path)``."""
    return Dataset(storage)


def empty_like(ds: Dataset, storage: Union[str, StorageProvider, None] = None) -> Dataset:
    out = Dataset(storage)
    for name, t in ds.tensors.items():
        out.create_tensor(name, htype=t.meta.htype, dtype=t.meta.dtype,
                          sample_compression=t.meta.codec,
                          min_chunk_size=t.meta.min_chunk_size,
                          max_chunk_size=t.meta.max_chunk_size,
                          strict=t.meta.strict)
    return out
