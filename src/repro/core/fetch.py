"""Coalesced batch I/O engine: one ranged request per chunk (§3.5, §4.5).

On object storage, request *count* — not bytes — dominates latency and cost,
so every hot read path routes through a :class:`FetchEngine` that turns
per-sample reads into per-chunk batched requests.

Contract
--------

**Coalescing rule.**  Sample byte-ranges inside one chunk are sorted and
merged whenever the gap between two ranges costs less to download than a
fresh request round-trip: ``gap_bytes / bandwidth < latency``, i.e. the gap
threshold is ``latency_s * bandwidth_bps``.  The threshold is never
hardcoded — it comes from a :class:`CostEstimator` that seeds itself from
the provider chain when a cost-bearing provider exposes
``latency_s``/``bandwidth_bps`` (:class:`~repro.core.storage
.SimulatedS3Provider`), and otherwise learns both parameters from observed
request wall times through the scheduler's
:class:`~repro.core.scheduler.CostModel` EWMA.  The same estimator decides
full-GET vs. coalesced ranges per chunk:
``cost(full) = latency + object_bytes/bandwidth`` against
``cost(ranged) = n_spans * latency + needed_bytes/bandwidth`` (+ one header
round-trip when the chunk header is not yet cached).  When an LRU cache
tier sits above the cost-bearing provider and the object fits comfortably,
the full GET wins outright — the cache absorbs the object and later units
read it for free.

**In-flight dedup.**  :meth:`FetchEngine.prefetch` dedups on chunk key:
concurrent prefetches of the same key share one :class:`Future`, and a
completed prefetch parks its blob in a byte-bounded *resident* LRU that
``Tensor.read_batch`` / ``Tensor._payload_of`` consult before touching
storage, so a prefetched chunk is charged exactly one request no matter how
many consumers race for it.  Residency is skipped when an LRU cache tier
above the provider already absorbs full objects (no double caching).
Writers must invalidate: any path that rewrites or deletes a chunk key
(the open chunk is re-flushed under the SAME key as it grows) calls
:meth:`FetchEngine.discard` — ``Tensor._discard_cached`` covers every
such site — or readers sharing the engine would see stale bytes.

**Multi-object batching.**  :meth:`fetch_many` (tile fan-outs, manifest
segment prefetch) issues ONE ``provider.get_many`` round for all missing
keys instead of a request per object — a 16-tile sample costs one
round-trip, not 16.  The batched round is a single attempt: any transient
falls back to the existing per-key retry loop, so the convergence
guarantee (a transient on key N never forces re-reads of keys 1..N-1)
is unchanged, at the cost of at most one wasted round per batch.
``coalescing_disabled()`` also disables batching so benchmarks can
record the per-object "before" datapoint.

**Cancellation.**  Futures are owned by the issuing calls: ``read_batch``
cancels its own lookahead future if decoding raises, and every
:meth:`FetchEngine.prefetch` carries an *owner* token —
``DeepLakeLoader`` teardown calls ``cancel_pending(owner=loader)``,
cancelling only its own queued-but-not-started prefetches and never a
concurrent consumer's (engines are shared per provider).  A key wanted by
several owners records ALL of them: dedup adds the caller's owner to the
in-flight entry, and an owner-scoped cancel only cancels a future once
*every* owner that asked for it has cancelled — one pipeline's teardown
can never drop a blob another tenant's scan is waiting on.  A cancelled
or failed in-flight future is never trusted by readers — they fall back
to a direct synchronous fetch — so cancellation is always safe, merely
wasteful.

**Multi-tenant fairness.**  The serving tier admits many concurrent
queries over one shared engine.  :meth:`register_tenant` gives each
tenant an optional byte budget on the staging buffer; tenant-tagged
prefetches (``prefetch(..., tenant=..., est_bytes=...)``) enter a
per-tenant FIFO drained by a deficit-round-robin scheduler
(:data:`DRR_QUANTUM` bytes of credit per tenant per cycle): a heavy
scan's backlog queues behind its own budget while a selective query's
one-group prefetch dispatches on the next cycle, so the heavy tenant can
never starve the light one.  Staged bytes are charged at dispatch and
released when the blob is consumed, evicted, or discarded; a tenant's
in-flight + unconsumed staged bytes never exceed its budget (one
oversized blob is always admitted so a budget below the chunk size
cannot deadlock).  Untagged prefetches bypass the scheduler entirely —
single-consumer paths (the loader) behave exactly as before.
:meth:`tenant_stats` splits the prefetch-plane counters per tenant
(dispatches, bytes, hits, throttle events, staged peak).

**Failure handling.**  Every physical fetch the engine issues runs under a
:class:`RetryPolicy`: :class:`~repro.core.storage.TransientStorageError`
(timeouts, 5xx, torn reads) retries with capped exponential backoff +
jitter, and exhaustion raises :class:`~repro.core.storage.RetryExhausted`
(a ``StorageError``) — counted in ``stats["errors_transient"]`` /
``stats["retries"]`` / ``stats["errors_permanent"]``.  Permanent errors
propagate immediately.  The retry budget is *adaptive*: an EWMA over
attempt outcomes tracks the observed transient-fault rate, and the
effective attempt count scales with it — one attempt fewer on a quiet
store (rate ≤ 1%), two extra under heavy faults (rate ≥ 25%) — with the
starting backoff stretched proportionally so a loaded store sees fewer,
later retries.  Downward adaptation is clamped at the provider chain's
``FaultPolicy.max_consecutive_per_key + 1`` so the deterministic
convergence guarantee (any single logical fetch eventually succeeds)
survives adaptation — though an explicitly configured budget below that
cap is honored as-is; ``stats["adaptive_attempts"]`` exposes the current
effective budget.  Prefetches AND blocking demand fetches
(:meth:`fetch_full` / the coalesced path of :meth:`fetch_ranges`) *hedge*:
clean fetch wall times feed a
:class:`~repro.distributed.fault_tolerance.StragglerDetector`
EWMA, and a fetch outliving ``hedge_multiplier ×`` that baseline fires
a duplicate request — first responder wins, the loser's retries are
cancelled, exactly one result is consumed (``stats["hedges"]`` /
``stats["hedge_wins"]`` / ``stats["stragglers"]``).  Readers racing an
in-flight prefetch (:meth:`FetchEngine.resident` /
:meth:`FetchEngine.wait_inflight`) treat ONLY storage errors as a fallback
to direct I/O (``stats["inflight_fallbacks"]``); any other exception (a
decode bug, a programming error) re-raises — a failed prefetch must never
masquerade as a cache miss.  Fault-polluted timings (retried or hedged
requests) never feed the latency/bandwidth EWMA, so one straggler cannot
distort ``gap_threshold`` / ``derive_unit_size`` for the rest of the epoch.

Benchmarks can bracket a run with :func:`coalescing_disabled` to measure
the per-range "before" datapoint against the coalesced "after".
"""

from __future__ import annotations

import random
import threading
import time
import weakref
from collections import OrderedDict, deque
from concurrent.futures import CancelledError, Future, ThreadPoolExecutor
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..distributed.fault_tolerance import StragglerDetector
from . import telemetry
from .scheduler import CostModel
from .storage import (LRUCacheProvider, Range, RetryExhausted, StorageError,
                      StorageProvider, TransientStorageError, coalesce_ranges,
                      slice_spans)

# Conservative prior for providers that expose no cost parameters (POSIX /
# in-memory): sub-millisecond "requests", fast local bandwidth.  The EWMA
# refines both from observed wall times.
_DEFAULT_LATENCY_S = 1e-4
_DEFAULT_BANDWIDTH_BPS = 500e6

_coalescing_on = True
_toggle_lock = threading.Lock()


def coalescing_enabled() -> bool:
    return _coalescing_on


@contextmanager
def coalescing_disabled():
    """Force one physical request per range (the pre-batching behavior).

    Used by benchmarks to record the "before" datapoint of a before/after
    pair and by equivalence tests; never used on production paths.
    Re-entrant: the previous state is restored on exit, so nesting keeps
    the outer context's measurement honest.
    """
    global _coalescing_on
    with _toggle_lock:
        prev, _coalescing_on = _coalescing_on, False
    try:
        yield
    finally:
        with _toggle_lock:
            _coalescing_on = prev


def provider_cost_params(provider) -> Optional[Tuple[float, float]]:
    """(latency_s, bandwidth_bps) of the first cost-bearing provider in the
    chain, walking ``.base`` links top-down; None when the chain is free
    (pure memory / POSIX)."""
    p = provider
    while isinstance(p, StorageProvider):
        lat = getattr(p, "latency_s", None)
        bw = getattr(p, "bandwidth_bps", None)
        if lat is not None and bw is not None:
            return float(lat), float(bw)
        p = getattr(p, "base", None)
    return None


def fault_streak_cap(provider) -> int:
    """Largest ``FaultPolicy.max_consecutive_per_key`` of any provider in
    the chain (0 when no tier injects faults).  The adaptive retry budget
    is floored at cap + 1 so a full fault streak can never exhaust it."""
    cap = 0
    p = provider
    while isinstance(p, StorageProvider):
        fp = getattr(p, "fault_policy", None)
        if fp is not None:
            cap = max(cap, int(getattr(fp, "max_consecutive_per_key", 0)))
        p = getattr(p, "base", None)
    return cap


def cache_capacity_above(provider) -> int:
    """Bytes of LRU cache sitting *above* the first cost-bearing provider
    (0 when there is no such cache, or no cost-bearing tier at all)."""
    cap = 0
    p = provider
    while isinstance(p, StorageProvider):
        if getattr(p, "latency_s", None) is not None:
            return cap
        if isinstance(p, LRUCacheProvider):
            cap += p.capacity_bytes
        p = getattr(p, "base", None)
    return 0


class CostEstimator:
    """Latency/bandwidth model behind the coalescing threshold.

    Seeds from the provider chain when possible; otherwise starts from a
    conservative local prior and EWMA-learns both parameters from observed
    request wall times via :class:`~repro.core.scheduler.CostModel`.
    """

    def __init__(self, provider, cost_model: Optional[CostModel] = None
                 ) -> None:
        self.costs = cost_model or CostModel()
        params = provider_cost_params(provider)
        self.seeded = params is not None
        if params is not None:
            self.latency_s, self.bandwidth_bps = params
        else:
            self.latency_s = _DEFAULT_LATENCY_S
            self.bandwidth_bps = _DEFAULT_BANDWIDTH_BPS
        self.costs.observe("fetch_request", self.latency_s, 0.0)

    def observe_request(self, nbytes: int, seconds: float) -> None:
        """Fold one observed request into the EWMA (no-op when seeded from
        exact provider parameters)."""
        if self.seeded or seconds <= 0:
            return
        transfer = nbytes / self.bandwidth_bps
        self.costs.observe("fetch_request", max(seconds - transfer, 1e-7), 0.0)
        self.latency_s, _ = self.costs.estimate("fetch_request")
        if nbytes and seconds > self.latency_s:
            bw = nbytes / max(seconds - self.latency_s, 1e-9)
            a = self.costs.alpha
            self.bandwidth_bps = (1 - a) * self.bandwidth_bps + a * bw

    def gap_threshold(self) -> int:
        """Bytes of gap cheaper to download than a fresh round-trip."""
        return max(0, int(self.latency_s * self.bandwidth_bps))

    def request_cost(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_bps

    def full_get_is_cheaper(self, n_spans: int, needed_bytes: int,
                            object_bytes: int, extra_requests: int = 0,
                            amortization: float = 1.0) -> bool:
        """Model cost of one whole-object GET vs. ``n_spans`` coalesced
        ranged requests (+ ``extra_requests`` round-trips the ranged plan
        needs first, e.g. an uncached header).  ``amortization`` > 1
        tolerates a costlier full GET when later reads of the object will
        be served from a cache it fills."""
        cost_full = self.request_cost(object_bytes)
        cost_ranged = ((n_spans + extra_requests) * self.latency_s
                       + needed_bytes / self.bandwidth_bps)
        return cost_full <= amortization * cost_ranged


@dataclass(frozen=True)
class RetryPolicy:
    """Retry + hedging knobs for one :class:`FetchEngine`.

    ``max_attempts`` is the *baseline* try budget per physical request
    (first + retries); the engine adapts the effective budget around it
    from the observed transient-fault rate (see the module docstring),
    never below the provider chain's fault-streak cap + 1.  Backoff
    doubles from ``backoff_base_s`` (stretched by the observed fault
    rate) up to ``backoff_cap_s``, with up to ``jitter ×`` extra
    randomization per sleep.  A fetch — prefetch or blocking demand
    read — is hedged (duplicated) once it outlives ``hedge_multiplier ×``
    the straggler detector's clean-fetch EWMA, floored at ``hedge_min_s``
    so micro-variance on fast stores can never trigger a duplicate;
    ``hedge_multiplier <= 0`` disables hedging outright.
    """

    max_attempts: int = 4
    backoff_base_s: float = 0.01
    backoff_cap_s: float = 0.25
    jitter: float = 0.5
    hedge_multiplier: float = 3.0
    hedge_min_s: float = 0.05


class _TenantState:
    """Per-tenant fair-scheduling state (all fields guarded by the
    engine lock)."""

    __slots__ = ("budget", "staged", "staged_peak", "deficit", "queue",
                 "stats")

    def __init__(self, budget: Optional[int]) -> None:
        self.budget = budget          # staging-byte budget; None = unlimited
        self.staged = 0               # in-flight + unconsumed staged bytes
        self.staged_peak = 0
        self.deficit = 0.0            # DRR credit (bytes)
        # queued prefetches: (key, owner, on_fetched, est_bytes, proxy)
        self.queue: deque = deque()
        self.stats = {"prefetch_requests": 0, "prefetch_dispatched": 0,
                      "prefetch_hits": 0, "bytes_fetched": 0,
                      "throttle_events": 0, "queued_peak": 0}


class FetchEngine:
    """Batched fetch front-end shared by TQL, tensor reads, and the loader.

    See the module docstring for the coalescing / dedup / cancellation /
    failure-handling contract.  One engine exists per storage provider
    (``engine_for``); all tensors and loaders bound to that provider share
    its resident store, in-flight table, thread pool, retry policy, and
    straggler detector.
    """

    def __init__(self, provider: StorageProvider, *,
                 cost_model: Optional[CostModel] = None,
                 max_workers: int = 8,
                 resident_bytes: int = 64 << 20,
                 retry: Optional[RetryPolicy] = None) -> None:
        # weak ref: the engine registry must not keep providers (and with
        # them engines, blobs, pools) alive after their last external user
        self._provider_ref = weakref.ref(provider)
        self.est = CostEstimator(provider, cost_model)
        self.cache_above = cache_capacity_above(provider)
        self.resident_bytes = int(resident_bytes)
        self.max_workers = max(1, int(max_workers))
        self.retry = retry if retry is not None else RetryPolicy()
        # the distributed-training straggler detector doubles as the hedge
        # trigger: clean fetch walls feed its EWMA, a fired hedge is the
        # mitigation (patience=1: every straggler hedges immediately)
        self.detector = StragglerDetector(
            threshold=max(self.retry.hedge_multiplier, 1.0), patience=1)
        # adaptive retry budget: EWMA of per-attempt transient-fault
        # outcomes; floor keeps the streak-cap convergence guarantee
        self._fault_rate = 0.0
        self._fault_alpha = 0.05
        self._attempts_floor = fault_streak_cap(provider) + 1
        self._backoff_rng = random.Random(0xFE7C)
        self._op_seq = 0
        # two pools so a work task (which may block on a prefetch future)
        # can never starve the prefetch that would unblock it
        self._work_pool: Optional[ThreadPoolExecutor] = None
        self._prefetch_pool: Optional[ThreadPoolExecutor] = None
        self._lock = threading.RLock()
        # key -> (future, set of owners that asked for it): owner-scoped
        # cancel only cancels once every requesting owner has cancelled
        self._inflight: Dict[str, Tuple[Future, set]] = {}
        # fair multi-tenant prefetch scheduling (see module docstring)
        self._tenants: Dict[str, _TenantState] = {}
        self._key_tenant: Dict[str, Tuple[str, int]] = {}  # key -> (tenant, est)
        self._dispatching = False
        self._drr_rerun = False
        self._resident: "OrderedDict[str, bytes]" = OrderedDict()
        self._resident_size = 0
        # prefetch-efficacy bookkeeping: resident blobs not yet consumed
        # (key -> nbytes) and in-flight fetches already consumed by a waiter
        self._unconsumed: Dict[str, int] = {}
        self._inflight_consumed: set = set()
        self.stats = {"requests": 0, "ranges": 0, "bytes": 0, "hits": 0,
                      "prefetch_hits": 0, "prefetch_wasted_bytes": 0,
                      "retries": 0, "errors_transient": 0,
                      "errors_permanent": 0, "hedges": 0, "hedge_wins": 0,
                      "stragglers": 0, "prefetch_failures": 0,
                      "inflight_fallbacks": 0,
                      "adaptive_attempts": max(1, self.retry.max_attempts)}

    @property
    def provider(self) -> StorageProvider:
        p = self._provider_ref()
        if p is None:  # unreachable while any caller can still reach us
            raise RuntimeError("storage provider was garbage-collected")
        return p

    def stats_snapshot(self) -> Dict[str, int]:
        """Consistent point-in-time copy of :attr:`stats`.

        Every mutation of the stats dict happens under ``self._lock``, so
        copying under the same lock can never observe a torn multi-key
        update (e.g. ``requests`` incremented but ``bytes`` not yet) the
        way iterating the live dict from another thread could.
        """
        with self._lock:
            return dict(self.stats)

    # ------------------------------------------------------- resident blobs
    def has_blob(self, key: str) -> bool:
        """Stats-neutral warmth probe: True when ``key`` is resident, in
        flight, or tracked as unconsumed in an LRU tier above.  The
        loader's pipeline-aware shuffle consults it to visit warm chunk
        groups before cold ones; it never mutates LRU order or counters."""
        with self._lock:
            return (key in self._resident or key in self._inflight
                    or key in self._unconsumed)

    def resident(self, key: str) -> Optional[bytes]:
        """Fully-fetched blob for ``key`` if one is parked here (no I/O).
        Also resolves an in-flight prefetch that already completed."""
        with self._lock:
            data = self._resident.get(key)
            if data is not None:
                self._resident.move_to_end(key)
                self.stats["hits"] += 1
                self._mark_consumed(key)
                return data
            entry = self._inflight.get(key)
        if entry is not None and entry[0].done():
            try:
                blob = entry[0].result()
            except CancelledError:
                return None            # cancelled: caller fetches directly
            except StorageError:
                # the prefetch burned its whole retry budget; the caller's
                # direct fetch gets a fresh one (counted, never silent)
                with self._lock:
                    self.stats["inflight_fallbacks"] += 1
                return None
            # anything else (decode bug, KeyError, ...) re-raises: a failed
            # prefetch must never masquerade as a cache miss
            with self._lock:
                self._mark_inflight_consumed(key)
            return blob
        return None

    def _mark_consumed(self, key: str) -> None:
        """A resident prefetched blob was read (lock held): first
        consumption counts as a prefetch hit."""
        if self._unconsumed.pop(key, None) is not None:
            self.stats["prefetch_hits"] += 1
        self._tenant_release(key)

    def _mark_inflight_consumed(self, key: str) -> None:
        """An in-flight prefetch's result was consumed before admission
        (lock held)."""
        if key not in self._inflight_consumed:
            self._inflight_consumed.add(key)
            self.stats["prefetch_hits"] += 1

    def _waste(self, key: str, nbytes: int) -> None:
        """A prefetched blob leaves the engine unconsumed (lock held)."""
        if self._unconsumed.pop(key, None) is not None:
            self.stats["prefetch_wasted_bytes"] += nbytes
        self._tenant_release(key)

    #: bound on consumption-tracking keys when an LRU tier holds the blobs
    _TRACK_KEYS_MAX = 4096

    def _admit(self, key: str, data: bytes, consumed: bool = False) -> None:
        if consumed:  # consumed before admission: staged charge is over
            with self._lock:
                self._tenant_release(key)
        # an LRU tier above the charged provider already holds full objects;
        # track the KEY (no blob) so a later engine read of it still counts
        # as a prefetch hit — eviction there is invisible, so such entries
        # can only hit, never count as wasted
        if self.cache_above:
            if not consumed:
                with self._lock:
                    self._unconsumed[key] = 0
                    while len(self._unconsumed) > self._TRACK_KEYS_MAX:
                        self._unconsumed.pop(next(iter(self._unconsumed)))
            return
        if len(data) > self.resident_bytes:
            if not consumed:  # fetched, never held, never read: pure waste
                with self._lock:
                    self.stats["prefetch_wasted_bytes"] += len(data)
                    self._tenant_release(key)
            return
        with self._lock:
            old = self._resident.pop(key, None)
            if old is not None:
                self._resident_size -= len(old)
                self._waste(key, len(old))
            self._resident[key] = data
            self._resident_size += len(data)
            if not consumed:
                self._unconsumed[key] = len(data)
            while self._resident_size > self.resident_bytes and self._resident:
                # evict already-consumed blobs first (LRU among them): a
                # staged, never-read prefetch is the one blob eviction
                # would turn into pure waste
                victim = next((k for k in self._resident
                               if k not in self._unconsumed), None)
                if victim is not None:
                    self._resident_size -= len(self._resident.pop(victim))
                    continue
                k, v = self._resident.popitem(last=False)
                self._resident_size -= len(v)
                self._waste(k, len(v))

    def discard(self, key: str) -> None:
        """Writer invalidation: drop the resident blob AND abandon any
        in-flight prefetch of the key, so a fetch that raced the rewrite
        can neither be served to readers nor re-admit stale bytes when it
        completes (its done-callback only admits while still current)."""
        with self._lock:
            v = self._resident.pop(key, None)
            if v is not None:
                self._resident_size -= len(v)
                self._waste(key, len(v))
            else:
                self._unconsumed.pop(key, None)  # key-only tracking entry
            self._inflight_consumed.discard(key)
            self._tenant_release(key)
            entry = self._inflight.pop(key, None)
        if entry is not None:
            entry[0].cancel()  # best effort; a running fetch is abandoned

    # -------------------------------------------------------- sync fetching
    def _observe(self, n_requests: int, n_ranges: int, nbytes: int,
                 seconds: float, clean: bool = True) -> None:
        """Account one logical fetch.  ``clean=False`` (the timing includes
        injected faults, retry backoff, or a hedge race) still counts the
        request but NEVER feeds the latency/bandwidth EWMA — one straggler
        must not distort the coalescing threshold or unit sizing."""
        with self._lock:
            self.stats["requests"] += n_requests
            self.stats["ranges"] += n_ranges
            self.stats["bytes"] += nbytes
        if n_requests and clean:
            self.est.observe_request(nbytes // n_requests,
                                     seconds / n_requests)

    def _note_attempt(self, faulted: bool) -> None:
        """Fold one physical attempt outcome into the fault-rate EWMA
        (lock held by callers via _issue)."""
        a = self._fault_alpha
        self._fault_rate = (1 - a) * self._fault_rate + a * (1.0 if faulted
                                                             else 0.0)

    def _adaptive_attempts(self) -> int:
        """Effective attempt budget for the next physical request: one
        fewer than ``max_attempts`` on a quiet store (observed transient
        rate ≤ 1%), two extra under heavy faults (≥ 25%), the baseline in
        between.  Downward adaptation never crosses the provider chain's
        fault-streak cap + 1 (so the deterministic convergence guarantee
        survives), but an explicitly configured budget *below* that cap is
        honored as-is — adaptation only shrinks what the policy granted,
        it never overrides it."""
        base = max(1, self.retry.max_attempts)
        rate = self._fault_rate
        if rate <= 0.01:
            att = max(2, base - 1)
        elif rate >= 0.25:
            att = base + 2
        else:
            att = base
        return max(att, min(self._attempts_floor, base))

    def _issue(self, fn, key: str = "",
               cancelled: Optional[threading.Event] = None):
        """Run one physical fetch closure under the (adaptive) retry
        policy.

        Transients retry with capped exponential backoff + jitter;
        exhaustion raises :class:`RetryExhausted` chained on the last
        transient.  ``cancelled`` (hedging) aborts between attempts.
        Returns ``(result, first_try)`` — ``first_try`` is False whenever
        a retry happened, i.e. the caller's wall time is fault-polluted.
        """
        policy = self.retry
        with self._lock:
            attempts = self._adaptive_attempts()
            self.stats["adaptive_attempts"] = attempts
            # loaded store → start backoff later (fewer, gentler probes)
            delay = policy.backoff_base_s * (1.0 + 4.0 * self._fault_rate)
        last: Optional[TransientStorageError] = None
        for i in range(attempts):
            if cancelled is not None and cancelled.is_set():
                raise CancelledError()
            try:
                if i == 0:
                    out = fn()
                else:
                    # retried attempts get their own span and IO cause so
                    # their sim charges land in the "retry" stall bucket
                    with telemetry.span("fetch.retry", key=key, attempt=i), \
                            telemetry.io_cause("retry"):
                        out = fn()
                with self._lock:
                    self._note_attempt(False)
                return out, i == 0
            except TransientStorageError as e:
                last = e
                with self._lock:
                    self._note_attempt(True)
                    self.stats["errors_transient"] += 1
                    if i + 1 < attempts:
                        self.stats["retries"] += 1
                    u = self._backoff_rng.random()
                if i + 1 >= attempts:
                    break
                time.sleep(delay * (1.0 + policy.jitter * u))
                delay = min(delay * 2.0, policy.backoff_cap_s)
        with self._lock:
            self.stats["errors_permanent"] += 1
        raise RetryExhausted(
            f"fetch retries exhausted after {attempts} attempts: {key!r}"
        ) from last

    def _note_clean_wall(self, seconds: float) -> None:
        """Feed one clean (unretried, unhedged) fetch wall time to the
        straggler detector's baseline EWMA."""
        with self._lock:
            self._op_seq += 1
            seq = self._op_seq
        self.detector.observe(seq, seconds)

    def fault_events(self) -> int:
        """Monotone count of fault-path events (transient errors + hedges).
        Consumers bracket a timed section with it to decide whether that
        timing is clean enough for their own EWMAs (the loader's per-unit
        cost model does)."""
        with self._lock:
            s = self.stats
            return s["errors_transient"] + s["errors_permanent"] + s["hedges"]

    def wait_inflight(self, key: str) -> Optional[bytes]:
        """Result of an in-flight prefetch of ``key``, waiting for it to
        finish; None when nothing is in flight or it was cancelled or
        failed with a *storage* error (the caller then falls back to
        direct I/O, which retries with a fresh budget).  Non-storage
        exceptions re-raise — they are bugs, not cache misses."""
        with self._lock:
            entry = self._inflight.get(key)
        if entry is None:
            return None
        try:
            blob = entry[0].result()
        except CancelledError:
            return None
        except StorageError:
            with self._lock:
                self.stats["inflight_fallbacks"] += 1
            return None
        with self._lock:
            self._mark_inflight_consumed(key)
        return blob

    def fetch_full(self, key: str) -> bytes:
        """Whole-object read, resident/in-flight aware.

        Deliberately does NOT park the blob in the resident store: caching
        fetched objects is the job of an :class:`LRUCacheProvider` tier;
        residency is reserved for :meth:`prefetch` handoff (the paper's
        "buffer of fetched and unutilized data" belongs to the consumer,
        not the cache).
        """
        blob = self.resident(key)
        if blob is None:
            blob = self.wait_inflight(key)
        if blob is not None:
            return blob
        t0 = time.perf_counter()
        # demand reads hedge too: a blocking consumer is exactly who a
        # straggling request hurts most
        data, first_try = self._hedged(lambda: self.provider.get(key), key)
        wall = time.perf_counter() - t0
        self._observe(1, 0, len(data), wall, clean=first_try)
        if first_try:
            self._note_clean_wall(wall)
        with self._lock:  # prefetched into an LRU tier above: still a hit
            self._mark_consumed(key)
        return data

    def fetch_ranges(self, key: str, ranges: Sequence[Range],
                     counters: Optional[Dict[str, int]] = None
                     ) -> List[bytes]:
        """Batched ranged read: payload ``i`` equals
        ``provider.get_range(key, *ranges[i])``, issued as coalesced spans
        (or served free from a resident blob).  ``counters``, when given,
        receives the physical ``requests`` and new ``bytes`` this call
        actually issued (both 0 on a resident hit)."""
        ranges = [(int(s), int(e)) for s, e in ranges]
        if counters is not None:
            counters.setdefault("requests", 0)
            counters.setdefault("bytes", 0)
        if not ranges:
            return []
        blob = self.resident(key)
        if blob is not None:
            return [blob[s:max(s, e)] for s, e in ranges]
        if not coalescing_enabled():
            t0 = time.perf_counter()
            out, first_try = self._issue(
                lambda: [self.provider.get_range(key, s, e)
                         for s, e in ranges], key=key)
            nbytes = sum(len(p) for p in out)
            self._observe(len(ranges), len(ranges), nbytes,
                          time.perf_counter() - t0, clean=first_try)
            if counters is not None:
                counters["requests"] += len(ranges)
                counters["bytes"] += nbytes
            return out
        spans, assign = coalesce_ranges(ranges, self.est.gap_threshold())
        t0 = time.perf_counter()
        with self._lock:  # prefetched into an LRU tier above: still a hit
            self._mark_consumed(key)
        payloads, first_try = self._hedged(
            lambda: self.provider.get_ranges(key, spans), key)
        nbytes = sum(len(p) for p in payloads)
        wall = time.perf_counter() - t0
        self._observe(len(spans), len(ranges), nbytes, wall, clean=first_try)
        if first_try:
            self._note_clean_wall(wall / max(1, len(spans)))
        if counters is not None:
            counters["requests"] += len(spans)
            counters["bytes"] += nbytes
        return slice_spans(ranges, spans, assign, payloads)

    def fetch_many(self, keys: Sequence[str],
                   counters: Optional[Dict[str, int]] = None
                   ) -> Dict[str, bytes]:
        """Batched whole-object reads (tile fan-out, manifest segment
        prefetch on ``Dataset`` open), resident aware.  ``counters``, when
        given, accumulates the physical ``requests``/``bytes`` issued —
        the cold-open budget accounting reads them.

        All missing keys go out as ONE ``provider.get_many`` round (a
        batching provider charges one round-trip for the lot).  The batch
        is a single attempt: a transient anywhere in it falls back to the
        per-key retry loop — a transient on key N must never force
        re-reads of keys 1..N-1 (a whole-batch retry could outlive any
        budget once per-key fault streaks stack up), so convergence costs
        at most one wasted round.  ``coalescing_disabled()`` forces the
        per-object path for "before" benchmarks."""
        if counters is not None:
            counters.setdefault("requests", 0)
            counters.setdefault("bytes", 0)
        out: Dict[str, bytes] = {}
        missing: List[str] = []
        for k in keys:
            if k in out or k in missing:
                continue
            blob = self.resident(k)
            if blob is not None:
                out[k] = blob
            else:
                missing.append(k)
        if missing:
            t0 = time.perf_counter()
            with self._lock:  # LRU-tier prefetch consumption
                for k in missing:
                    self._mark_consumed(k)
            fetched: Dict[str, bytes] = {}
            n_requests = 0
            all_clean = True
            if coalescing_enabled() and len(missing) > 1:
                try:
                    fetched = dict(self.provider.get_many(missing))
                    n_requests = 1
                except TransientStorageError:
                    with self._lock:
                        self.stats["errors_transient"] += 1
                    fetched = {}
                    all_clean = False
            if not fetched:
                for k in missing:
                    blob, first_try = self._issue(
                        lambda k=k: self.provider.get(k), key=k)
                    fetched[k] = blob
                    all_clean = all_clean and first_try
                n_requests += len(fetched)
            nbytes = sum(len(v) for v in fetched.values())
            self._observe(n_requests, 0, nbytes,
                          time.perf_counter() - t0, clean=all_clean)
            if counters is not None:
                counters["requests"] += n_requests
                counters["bytes"] += nbytes
            out.update(fetched)
        return out

    #: with an LRU tier above the remote, a full GET fills the cache and
    #: later reads of the chunk are free — worth paying up to this factor
    #: over the one-shot ranged cost (but never an unconditional win: a
    #: sparse one-shot read of a huge chunk must stay ranged)
    CACHE_AMORTIZATION = 4.0

    # --------------------------------------------------------- chunk planning
    def plan_full_get(self, *, n_spans: int, needed_bytes: int,
                      object_bytes: int, header_cached: bool) -> bool:
        """True → fetch the whole chunk in one GET; False → coalesced
        ranges.  With coalescing disabled the answer is always ranged, so
        the "before" benchmark measures the per-range request pattern."""
        if not coalescing_enabled():
            return False
        cacheable = self.cache_above and object_bytes <= self.cache_above // 4
        return self.est.full_get_is_cheaper(
            n_spans, needed_bytes, object_bytes,
            extra_requests=0 if header_cached else 1,
            amortization=self.CACHE_AMORTIZATION if cacheable else 1.0)

    # ------------------------------------------------------------- prefetch
    def _ensure_pool(self, attr: str, prefix: str) -> ThreadPoolExecutor:
        with self._lock:
            pool = getattr(self, attr)
            if pool is None:
                pool = ThreadPoolExecutor(max_workers=self.max_workers,
                                          thread_name_prefix=prefix)
                setattr(self, attr, pool)
            return pool

    def submit(self, fn, *args) -> Future:
        """Run ``fn(*args)`` on the engine work pool (fetch/decode
        overlap).  Work tasks may wait on prefetch futures — those run on
        a separate pool, so the wait always makes progress."""
        return self._ensure_pool("_work_pool", "fetch-work").submit(fn, *args)

    #: DRR scheduling quantum: bytes of dispatch credit each tenant earns
    #: per scheduler cycle (roughly one chunk-group's worth)
    DRR_QUANTUM = 1 << 20

    def register_tenant(self, tenant: str,
                        byte_budget: Optional[int] = None) -> None:
        """Declare (or re-budget) a tenant for fair prefetch scheduling.
        ``byte_budget`` bounds the tenant's staged bytes (in-flight +
        unconsumed resident); None = unlimited (fair ordering only)."""
        with self._lock:
            st = self._tenants.get(tenant)
            if st is None:
                self._tenants[tenant] = _TenantState(byte_budget)
            else:
                st.budget = byte_budget
        self._kick()

    def tenant_stats(self, tenant: str) -> Dict[str, int]:
        """Point-in-time copy of one tenant's prefetch-plane split
        (``engine_*`` counters scoped to the tenant) plus live staging
        state."""
        with self._lock:
            st = self._tenants[tenant]
            out = dict(st.stats)
            out["staged_bytes"] = st.staged
            out["staged_peak_bytes"] = st.staged_peak
            out["queued"] = len(st.queue)
            return out

    def tenants_snapshot(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            names = list(self._tenants)
        return {t: self.tenant_stats(t) for t in names}

    def _tenant_release(self, key: str) -> None:
        """Release a key's staged-byte charge (lock held) and let the
        scheduler re-fill the freed headroom."""
        ent = self._key_tenant.pop(key, None)
        if ent is None:
            return
        name, est = ent
        st = self._tenants.get(name)
        if st is not None:
            st.staged = max(0, st.staged - est)
            if st.queue:
                self._kick()

    def _drr_collect(self) -> List[tuple]:
        """One deficit-round-robin sweep (lock held): pop every queued
        prefetch that fits its tenant's credit and budget, cycling until
        a full round makes no progress."""
        todo: List[tuple] = []
        progress = True
        while progress:
            progress = False
            for name, st in list(self._tenants.items()):
                if not st.queue:
                    st.deficit = 0.0
                    continue
                st.deficit = min(st.deficit + self.DRR_QUANTUM,
                                 8.0 * self.DRR_QUANTUM)
                while st.queue:
                    key, owner, on_fetched, est, proxy = st.queue[0]
                    if proxy.cancelled():
                        st.queue.popleft()
                        continue
                    # budget gate: always admit one item into an empty
                    # stage so a budget below the chunk size can't deadlock
                    if (st.budget is not None and st.staged > 0
                            and st.staged + est > st.budget):
                        break
                    if st.deficit < est:
                        break
                    st.queue.popleft()
                    st.deficit -= est
                    st.staged += est
                    st.staged_peak = max(st.staged_peak, st.staged)
                    todo.append((name, key, owner, on_fetched, est, proxy))
                    progress = True
        return todo

    def _kick(self) -> None:
        """Drain dispatchable tenant queues.  Re-entrant-safe: a nested
        call (e.g. a dispatch consuming a resident blob) only flags a
        re-run for the outer loop."""
        with self._lock:
            if self._dispatching:
                self._drr_rerun = True
                return
            self._dispatching = True
        try:
            while True:
                with self._lock:
                    self._drr_rerun = False
                    todo = self._drr_collect()
                for item in todo:
                    self._dispatch_one(*item)
                with self._lock:
                    if not todo and not self._drr_rerun:
                        return
        finally:
            with self._lock:
                self._dispatching = False

    def _dispatch_one(self, tenant: str, key: str, owner: object,
                      on_fetched, est: int, proxy: Future) -> None:
        """Issue one scheduled tenant prefetch and tie its outcome to the
        proxy future handed out at enqueue time."""
        st = self._tenants[tenant]

        def counted(nbytes: int) -> None:
            with self._lock:
                st.stats["bytes_fetched"] += nbytes
            if on_fetched is not None:
                on_fetched(nbytes)

        with self._lock:
            st.stats["prefetch_dispatched"] += 1
            # charge the staged bytes against the key so consumption /
            # eviction / discard releases them; a key already charged to
            # another tenant is not double-charged
            if key in self._key_tenant:
                st.staged = max(0, st.staged - est)
            else:
                self._key_tenant[key] = (tenant, est)
        real = self._prefetch_now(key, owner, counted)

        def _copy(f: Future) -> None:
            if f.cancelled():
                with self._lock:
                    self._tenant_release(key)
                proxy.cancel()
            elif f.exception() is not None:
                with self._lock:
                    self._tenant_release(key)
                if not proxy.cancelled():
                    proxy.set_exception(f.exception())
            else:
                if not proxy.cancelled():
                    proxy.set_result(f.result())

        real.add_done_callback(_copy)
        # dedup against an already-consumed resident blob: nothing will
        # ever release the charge, so drop it now
        with self._lock:
            if key not in self._inflight and key not in self._unconsumed:
                self._tenant_release(key)

    def prefetch(self, key: str, owner: object = None, on_fetched=None, *,
                 tenant: Optional[str] = None, est_bytes: int = 0) -> Future:
        """Schedule a whole-chunk fetch; dedups in-flight keys.

        The completed blob is parked in the resident store (unless an LRU
        tier above already caches it), where readers pick it up for free.
        ``owner`` scopes cancellation: :meth:`cancel_pending` with the
        same owner cancels only that owner's still-queued futures, so one
        consumer's teardown never drops another's prefetches.  A key
        already in flight gains the caller's owner as an additional owner
        — the future is only cancellable once every owner has cancelled.
        ``on_fetched(nbytes)`` fires only when THIS call causes a physical
        fetch (never on resident/in-flight dedup), so issuers can
        attribute the I/O to their own accounting.

        ``tenant`` (registered via :meth:`register_tenant`) routes the
        request through the fair deficit-round-robin scheduler under the
        tenant's staging-byte budget; ``est_bytes`` is the charge
        (estimated blob size).  Untagged calls dispatch immediately.
        """
        if tenant is not None:
            with self._lock:
                st = self._tenants.get(tenant)
                if st is None:
                    st = self._tenants[tenant] = _TenantState(None)
                st.stats["prefetch_requests"] += 1
                # dedup before queuing: an in-flight or resident key needs
                # no scheduling (and no staged-byte charge)
                entry = self._inflight.get(key)
                if entry is not None:
                    entry[1].add(owner)
                    st.stats["prefetch_hits"] += 1
                    return entry[0]
                data = self._resident.get(key)
                if data is not None:
                    st.stats["prefetch_hits"] += 1
                    done: Future = Future()
                    done.set_result(data)
                    return done
                if (st.budget is not None and st.staged > 0
                        and st.staged + max(0, est_bytes) > st.budget):
                    st.stats["throttle_events"] += 1
                proxy: Future = Future()
                st.queue.append((key, owner, on_fetched,
                                 max(0, int(est_bytes)), proxy))
                st.stats["queued_peak"] = max(st.stats["queued_peak"],
                                              len(st.queue))
            self._kick()
            return proxy
        return self._prefetch_now(key, owner, on_fetched)

    def _prefetch_now(self, key: str, owner: object = None,
                      on_fetched=None) -> Future:
        """Unscheduled prefetch dispatch (the pre-serving behavior)."""
        with self._lock:
            entry = self._inflight.get(key)
            if entry is not None:
                entry[1].add(owner)
                return entry[0]
            data = self._resident.get(key)
        if data is not None:
            done: Future = Future()
            done.set_result(data)
            return done
        pool = self._ensure_pool("_prefetch_pool", "fetch-prefetch")

        def work() -> bytes:
            t0 = time.perf_counter()
            # tag the pool thread so provider charges (and any non-hedged
            # _issue attempts) land in the "prefetch" stall bucket
            with telemetry.io_cause("prefetch"):
                blob, clean = self._hedged_get(key)
            wall = time.perf_counter() - t0
            self._observe(1, 0, len(blob), wall, clean=clean)
            if clean:
                self._note_clean_wall(wall)
            if on_fetched is not None:
                on_fetched(len(blob))
            return blob

        with self._lock:
            entry = self._inflight.get(key)
            if entry is not None:
                entry[1].add(owner)
                return entry[0]
            fut = pool.submit(work)
            self._inflight[key] = (fut, {owner})

        def _done(f: Future, key: str = key) -> None:
            with self._lock:
                cur = self._inflight.get(key)
                current = cur is not None and cur[0] is f
                if current:
                    del self._inflight[key]
                consumed = key in self._inflight_consumed
                self._inflight_consumed.discard(key)
                if current and (f.cancelled() or f.exception() is not None):
                    self._tenant_release(key)
            # admit only while still current: a discard() (writer rewrote
            # the key) or supersession while in flight abandons the result
            if not current or f.cancelled():
                return
            if f.exception() is None:
                self._admit(key, f.result(), consumed=consumed)
            else:
                # the failure stays on the future for waiters to see; it
                # must also be visible when nobody ever waits
                with self._lock:
                    self.stats["prefetch_failures"] += 1

        fut.add_done_callback(_done)
        return fut

    def _hedged_get(self, key: str) -> Tuple[bytes, bool]:
        """Whole-object GET with straggler hedging (the prefetch pool's
        physical fetch)."""
        return self._hedged(lambda: self.provider.get(key), key)

    def _hedged(self, fn, key: str):
        """Run one physical fetch closure with straggler hedging.

        The primary request runs under the retry policy on its own thread;
        once it outlives ``hedge_multiplier ×`` the straggler detector's
        clean-fetch baseline (floored at ``hedge_min_s``), a duplicate
        request fires and the first responder wins — the loser's remaining
        retries are cancelled and its payload discarded, so exactly one
        result is consumed.  No hedge before a baseline exists (the first
        fetch has nothing to straggle against).  Used by prefetch AND the
        blocking demand paths (:meth:`fetch_full`, the coalesced branch of
        :meth:`fetch_ranges`) — ``fn`` must be re-runnable and
        side-effect-free.  Returns ``(result, clean)`` where ``clean``
        means first attempt, no hedge.
        """
        policy = self.retry
        base = self.detector.baseline
        if policy.hedge_multiplier <= 0 or base is None:
            return self._issue(fn, key=key)
        deadline = max(policy.hedge_min_s, self.detector.threshold * base)
        cond = threading.Condition()
        cancel = threading.Event()
        state = {"winner": None, "blob": None, "first_try": False,
                 "done": 0, "errors": []}
        # the IO cause is thread-local and the arms run on fresh threads,
        # so capture the caller's cause here and re-tag explicitly: the
        # primary arm keeps it, the hedge arm charges the "hedge" bucket
        caller_cause = telemetry.current_io_cause()

        def arm(tag: str) -> None:
            try:
                cause = "hedge" if tag == "hedge" else caller_cause
                with telemetry.io_cause(cause):
                    blob, first_try = self._issue(fn, key=key,
                                                  cancelled=cancel)
            except BaseException as e:  # noqa: BLE001 - relayed to waiter
                with cond:
                    state["done"] += 1
                    state["errors"].append(e)
                    cond.notify_all()
                return
            with cond:
                state["done"] += 1
                if state["winner"] is None:
                    state["winner"] = tag
                    state["blob"] = blob
                    state["first_try"] = first_try
                cond.notify_all()
            cancel.set()  # first responder wins: stop the other arm

        threading.Thread(target=arm, args=("primary",), daemon=True,
                         name="fetch-hedge-primary").start()
        arms = 1
        with cond:
            cond.wait_for(lambda: state["done"] >= 1, timeout=deadline)
            straggling = state["done"] == 0
        if straggling:
            with self._lock:
                self.stats["hedges"] += 1
                self.stats["stragglers"] += 1
                self._op_seq += 1
                seq = self._op_seq
            # record the straggler with the detector (patience=1: the
            # fired hedge IS the mitigation); the elapsed time is clamped
            # above the flag threshold so the floor can't hide it
            self.detector.observe(
                seq, max(deadline, self.detector.threshold * base * 1.01))
            arms = 2
            threading.Thread(target=arm, args=("hedge",), daemon=True,
                             name="fetch-hedge-dup").start()
        hedge_span = telemetry.span("fetch.hedge", key=key) if arms == 2 \
            else telemetry.null_span()
        with hedge_span, cond:
            cond.wait_for(lambda: state["winner"] is not None
                          or state["done"] >= arms)
        if state["winner"] is None:
            raise state["errors"][0]
        if state["winner"] == "hedge":
            with self._lock:
                self.stats["hedge_wins"] += 1
        return state["blob"], bool(arms == 1 and state["first_try"])

    def cancel_pending(self, owner: object = None) -> int:
        """Cancel queued-but-not-started prefetches; running fetches
        complete and park normally.  ``owner`` restricts cancellation to
        futures issued with that owner (None cancels everything — only
        for full engine shutdown) — and an in-flight key wanted by OTHER
        owners too is left alone: the owner is merely removed from the
        entry, and the future is cancelled only when no owner remains,
        so one pipeline's teardown never drops a blob a concurrent
        consumer is waiting on.  Returns #cancelled."""
        futs: List[Future] = []
        with self._lock:
            for f, owners in self._inflight.values():
                if owner is None:
                    futs.append(f)
                    continue
                owners.discard(owner)
                if not owners:
                    futs.append(f)
            # still-queued tenant prefetches by this owner are dequeued
            # outright (their proxy futures cancel; nothing was staged yet)
            for st in self._tenants.values():
                kept = deque()
                for item in st.queue:
                    if owner is None or item[1] is owner:
                        futs.append(item[4])
                    else:
                        kept.append(item)
                st.queue = kept
        return sum(1 for f in futs if f.cancel())

    def close(self) -> None:
        self.cancel_pending()
        with self._lock:
            pools = (self._work_pool, self._prefetch_pool)
            self._work_pool = self._prefetch_pool = None
        for pool in pools:
            if pool is not None:
                pool.shutdown(wait=False)


_engines: "weakref.WeakKeyDictionary[StorageProvider, FetchEngine]" = \
    weakref.WeakKeyDictionary()
_engines_lock = threading.Lock()


def engine_for(provider: StorageProvider) -> FetchEngine:
    """The shared :class:`FetchEngine` of a storage provider (one per
    provider instance, created on first use, garbage-collected with it)."""
    with _engines_lock:
        eng = _engines.get(provider)
        if eng is None:
            eng = FetchEngine(provider)
            _engines[provider] = eng
        return eng


def engine_stats_for(provider: StorageProvider) -> Dict[str, int]:
    """Summed stats of every live engine whose provider chain contains
    ``provider`` (walking ``.base`` links).  Benchmarks snapshot the
    cost-bearing provider at the bottom of a cache chain while the engine
    is keyed on the chain's top — this bridges the two so prefetch-efficacy
    counters (``prefetch_hits``, ``prefetch_wasted_bytes``) land in
    ``BENCH_io.json`` next to the provider's request counters."""
    out: Dict[str, int] = {}
    with _engines_lock:
        items = [(p, e) for p, e in _engines.items()]
    for top, eng in items:
        p: Optional[StorageProvider] = top
        while isinstance(p, StorageProvider):
            if p is provider:
                # locked snapshot, not the live dict: worker/prefetch
                # threads mutate stats concurrently
                for k, v in eng.stats_snapshot().items():
                    out[k] = out.get(k, 0) + int(v)
                break
            p = getattr(p, "base", None)
    return out
