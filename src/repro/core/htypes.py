"""Htype system (§3.3).

An htype declares what samples in a tensor are expected to look like: dtype,
dimensionality constraints, and a default sample codec.  Typed tensors make
framework handover well-defined and enable layout/visualization decisions.

Meta-htypes wrap a base htype:

    sequence[image]   -- a sample is an ordered list of image samples
    link[image]       -- a sample is a reference (url/key) into another
                         storage provider, resolved lazily (§4.4)

``parse_htype("sequence[image]")`` -> (meta="sequence", base="image").
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class HtypeSpec:
    name: str
    default_dtype: Optional[str] = None     # enforced if tensor doesn't override
    ndim: Optional[Tuple[int, ...]] = None  # allowed sample ndims (None = any)
    default_codec: str = "raw"
    display: str = "secondary"              # visualizer layout hint: primary/secondary/overlay
    extra: Dict[str, object] = field(default_factory=dict)

    def validate(self, arr: np.ndarray, dtype_override: Optional[str] = None) -> None:
        want = np.dtype(dtype_override or self.default_dtype) if (
            dtype_override or self.default_dtype) else None
        if want is not None and arr.dtype != want:
            raise TypeError(
                f"htype {self.name!r} expects dtype {want}, got {arr.dtype}")
        if self.ndim is not None and arr.ndim not in self.ndim:
            raise ValueError(
                f"htype {self.name!r} expects ndim in {self.ndim}, got {arr.ndim}"
                f" (shape {arr.shape})")


_REGISTRY: Dict[str, HtypeSpec] = {}


def register_htype(spec: HtypeSpec) -> HtypeSpec:
    _REGISTRY[spec.name] = spec
    return spec


# generic permits anything; it is the default htype.
register_htype(HtypeSpec("generic"))
register_htype(HtypeSpec("image", default_dtype="uint8", ndim=(2, 3),
                         default_codec="quant8", display="primary"))
register_htype(HtypeSpec("video", default_dtype="uint8", ndim=(4,),
                         default_codec="zlib", display="primary",
                         extra={"keyframe_stride": 8}))
register_htype(HtypeSpec("audio", default_dtype="float32", ndim=(1, 2),
                         default_codec="zlib", display="primary"))
register_htype(HtypeSpec("bbox", default_dtype="float32", ndim=(1, 2),
                         display="overlay", extra={"coords": "LTRB"}))
register_htype(HtypeSpec("class_label", default_dtype="int64", ndim=(0, 1),
                         display="overlay"))
register_htype(HtypeSpec("text", default_dtype="uint8", ndim=(1,),
                         default_codec="zlib", display="secondary"))
register_htype(HtypeSpec("binary_mask", default_dtype="uint8", ndim=(2, 3),
                         default_codec="zlib", display="overlay"))
register_htype(HtypeSpec("segment_mask", default_dtype="int32", ndim=(2,),
                         default_codec="zlib", display="overlay"))
register_htype(HtypeSpec("embedding", default_dtype="float32", ndim=(1,),
                         display="secondary"))
register_htype(HtypeSpec("dicom", default_dtype="int16", ndim=(2, 3),
                         default_codec="zlib", display="primary"))
register_htype(HtypeSpec("tokens", default_dtype="int32", ndim=(1,),
                         display="secondary"))

_META_RE = re.compile(r"^(sequence|link)\[([a-z_0-9\[\]]+)\]$")


def parse_htype(htype: str) -> Tuple[Optional[str], str]:
    """'sequence[image]' -> ('sequence', 'image'); 'image' -> (None, 'image')."""
    htype = (htype or "generic").strip()
    m = _META_RE.match(htype)
    if m:
        meta, base = m.group(1), m.group(2)
        parse_htype(base)  # validate base recursively
        return meta, base
    if htype not in _REGISTRY:
        raise ValueError(f"unknown htype {htype!r}; have {sorted(_REGISTRY)}")
    return None, htype


def get_htype(htype: str) -> HtypeSpec:
    meta, base = parse_htype(htype)
    spec = _REGISTRY[base]
    if meta == "link":
        # links store keys (uint8 strings); payload htype applies post-resolve
        return HtypeSpec(name=f"link[{base}]", default_dtype="uint8", ndim=(1,),
                         default_codec="raw", display=spec.display,
                         extra={"base": base})
    if meta == "sequence":
        # one sample = stack of base samples; ndim = base ndim + 1 where known
        nd = tuple(n + 1 for n in spec.ndim) if spec.ndim else None
        return HtypeSpec(name=f"sequence[{base}]", default_dtype=spec.default_dtype,
                         ndim=nd, default_codec=spec.default_codec,
                         display=spec.display, extra={"base": base})
    return spec


def available_htypes() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
