"""Linked tensors (§4.4): ``link[htype]`` columns store pointers into other
storage providers instead of payload bytes, giving a consolidated view over
data scattered across sources.  All features (query, version control,
streaming) work on linked tensors; streaming is slower than materialized
data — which is exactly the materialization motivation the paper gives.
"""

from __future__ import annotations

import io
import threading
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from .storage import StorageProvider


class LinkRegistry:
    """Maps provider aliases ('s3a', 'gcs1', ...) to storage providers.

    Link values are strings ``alias://key``.  Payloads are stored in .npy
    format (self-describing shape/dtype), the offline stand-in for raw
    JPEG/PNG files referenced by URL.
    """

    _global: Optional["LinkRegistry"] = None

    def __init__(self) -> None:
        self._providers: Dict[str, StorageProvider] = {}
        self._lock = threading.Lock()

    @classmethod
    def global_registry(cls) -> "LinkRegistry":
        if cls._global is None:
            cls._global = cls()
        return cls._global

    def register(self, alias: str, provider: StorageProvider) -> None:
        with self._lock:
            self._providers[alias] = provider

    def split(self, url: str) -> Tuple[str, str]:
        if "://" not in url:
            raise ValueError(f"bad link {url!r}; want alias://key")
        alias, key = url.split("://", 1)
        return alias, key

    def provider(self, alias: str) -> StorageProvider:
        with self._lock:
            if alias not in self._providers:
                raise KeyError(f"no provider registered for alias {alias!r}")
            return self._providers[alias]

    # ------------------------------------------------------------------ I/O
    def put_array(self, url: str, arr: np.ndarray) -> None:
        alias, key = self.split(url)
        buf = io.BytesIO()
        np.save(buf, arr)
        self.provider(alias).put(key, buf.getvalue())

    def fetch_array(self, url: str) -> np.ndarray:
        alias, key = self.split(url)
        raw = self.provider(alias).get(key)
        return np.load(io.BytesIO(raw), allow_pickle=False)


def link_value(url: str) -> np.ndarray:
    """Encode a link url as the uint8 payload stored in a link[...] tensor."""
    return np.frombuffer(url.encode(), dtype=np.uint8).copy()


def resolve_link(value: np.ndarray, registry: Optional[LinkRegistry] = None) -> np.ndarray:
    reg = registry or LinkRegistry.global_registry()
    return reg.fetch_array(bytes(value.tobytes()).decode())


def resolving_transform(link_tensors, registry: Optional[LinkRegistry] = None
                        ) -> Callable[[dict], dict]:
    """Loader transform that resolves the given link columns on the fly."""
    names = set(link_tensors)

    def tf(sample: dict) -> dict:
        out = dict(sample)
        for k in names & set(out):
            out[k] = resolve_link(np.asarray(out[k]), registry)
        return out

    return tf
