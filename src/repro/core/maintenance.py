"""Dataset maintenance engine: background jobs over the manifest catalog.

Three jobs keep a long-lived lakehouse dataset healthy (the paper's
petabyte-catalog story needs all three; Delta/Iceberg call them statistics
collection, checkpointing, and vacuum):

``backfill_stats``
    Computes :class:`~repro.core.chunks.ChunkStats` sidecars for chunks
    that predate the stats format (PR-1), by decoding each uncovered
    chunk once — tiled samples are reassembled from their tiles so the
    backfilled bounds are *exact*.  Records that exist but predate the
    membership sketches (``sketched=False``, PR-5) are recomputed the
    same way, so legacy datasets gain ``=``/``IN``/``CONTAINS`` prune
    verdicts too (``sketches_lifted`` in the report; the planner's
    ``ScanPlan.sketch_coverage`` shows the remaining gap).  After a
    backfill, the TQL planner prunes a pre-stats dataset exactly like a
    natively-written one, and query results are byte-identical (stats
    are an optimization, never a correctness input — this job only
    tightens the planner's intervals).

``compact_manifest``
    Folds the manifest's delta-segment chain — plus any stale or
    never-covered nodes re-read from the loose per-file layout — into one
    fresh consolidated segment and collapses the pointer to it (the
    Delta-checkpoint pattern).  Legacy datasets without a manifest adopt
    one here.  Node snapshots are rebuilt through
    :meth:`VersionControl.node_snapshot`, which now derives the manifest's
    **column-statistics section** (format v2) from each tensor's encoder +
    stats sidecar — so compaction is also how a legacy or pre-v2 dataset
    gains plan-at-open: after it, TQL ``WHERE`` planning runs from the
    2-request cold open with zero tensor binds (run ``backfill_stats``
    first on pre-stats datasets so the lifted section carries real
    bounds).  After compaction a cold ``Dataset`` open costs exactly two
    requests: pointer + one segment.  Superseded segment objects are left
    on storage on purpose (a reader that fetched the old pointer a moment
    ago may still be reading them) and become orphans for the GC.

``gc_orphans``
    Mark-and-sweep of unreachable objects.  **Reachability rule**: a chunk
    object ``versions/{node}/tensors/{t}/chunks/{name}`` is *live* iff its
    node is in the commit tree AND some commit node whose schema contains
    ``t`` references ``name`` in its ``chunk_set`` (chunks + tile chunks
    are registered at their creation node) or its chunk-encoder snapshot
    (covers chunks whose chunk_set entry was lost mid-crash — the encoder
    still resolves them, so deleting would break reads).  A manifest
    segment is live iff the pointer references it.  Any key under a node
    directory absent from the commit tree is dead.  Everything else under
    ``versions/`` (state files of scheduled tensors) is never touched.
    Orphans come from crashed flushes, ``delete_tensor`` leftovers,
    superseded manifest segments, and aborted branches.  The job defaults
    to ``dry_run=True`` and reports what it *would* delete; the sweep is
    the only destructive operation in this module and is conservative by
    construction: *unknown means live*.

All jobs flush the dataset first so in-memory state (open chunk builders,
pending diffs) is on storage before any scan, and all report through
:class:`MaintenanceReport` so callers/benchmarks can assert budgets.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from . import chunks as chunklib
from . import fetch
from .chunk_encoder import ChunkEncoder
from .chunks import _StatsAccumulator
from .codecs import get_codec
from .manifest import SEGMENT_PREFIX, Manifest
from .storage import StorageError
from .tensor import Tensor
from .tiling import TileDescriptor, assemble_from_tiles

_CHUNK_KEY_RE = re.compile(
    r"^versions/(?P<node>[^/]+)/tensors/(?P<tensor>.+)/chunks/(?P<name>[^/]+)$")
_NODE_KEY_RE = re.compile(r"^versions/(?P<node>[^/]+)/")

JOBS = ("backfill_stats", "compact_manifest", "gc_orphans")


@dataclass
class MaintenanceReport:
    """Outcome of one maintenance job."""

    job: str
    dry_run: bool
    #: keys the job wrote/deleted (or would, under dry_run)
    actions: List[str] = field(default_factory=list)
    #: job-specific counters (chunks backfilled, bytes reclaimed, ...)
    details: Dict[str, int] = field(default_factory=dict)

    def summary(self) -> str:
        det = ", ".join(f"{k}={v}" for k, v in sorted(self.details.items()))
        tag = " (dry-run)" if self.dry_run else ""
        return f"{self.job}{tag}: {len(self.actions)} actions; {det}"


class MaintenanceRunner:
    """Job runner bound to one :class:`~repro.core.dataset.Dataset`."""

    def __init__(self, ds) -> None:
        self.ds = ds

    def run(self, jobs: Sequence[str] = JOBS, *,
            dry_run: bool = False) -> List[MaintenanceReport]:
        out = []
        for job in jobs:
            if job not in JOBS:
                raise ValueError(f"unknown maintenance job {job!r}; "
                                 f"have {JOBS}")
            out.append(getattr(self, job)(dry_run=dry_run))
        return out

    # ------------------------------------------------------- stats backfill
    def backfill_stats(self, ref: Optional[str] = None, *,
                       dry_run: bool = False) -> MaintenanceReport:
        """Compute missing ChunkStats sidecars for one version (default:
        the current node).  Decodes each stat-less chunk exactly once;
        tiled samples fetch + reassemble their tiles so bounds are exact.
        Pre-sketch records (``sketched=False``) are recomputed the same
        way so legacy datasets gain membership sketches.
        """
        ds = self.ds
        ds.flush()
        vc = ds.vc
        nid = vc.resolve_ref(ref) if ref else vc.current_id
        report = MaintenanceReport("backfill_stats", dry_run)
        engine = fetch.engine_for(vc.storage)
        chunks_done = 0
        sketches_lifted = 0
        for tname in vc.schema_tensors(nid):
            t = Tensor(tname, vc, node_id=nid)
            missing = []
            for n in t.encoder.chunk_names():
                st = t.stats.get(n)
                if st is None:
                    missing.append(n)
                elif not st.sketched:
                    missing.append(n)
                    sketches_lifted += 1
            if not missing:
                continue
            for cname in missing:
                key = vc.resolve_chunk_key(tname, cname, nid)
                t.stats.set(cname, self._compute_chunk_stats(t, key, engine))
                chunks_done += 1
            report.actions.append(vc.state_key(tname, "chunk_stats.json", nid))
            if not dry_run:
                vc.put_state(tname, "chunk_stats.json", t.stats.serialize(),
                             nid)
        if not dry_run and nid == vc.current_id:
            # live Tensor objects cached pre-backfill hold the stale (empty)
            # table; drop them so the planner sees the new sidecar
            ds._tensors.clear()
        report.details.update(chunks_backfilled=chunks_done,
                              sketches_lifted=sketches_lifted,
                              tensors_touched=len(report.actions))
        return report

    @staticmethod
    def _compute_chunk_stats(t: Tensor, key: str,
                             engine: "fetch.FetchEngine"):
        """Exact ChunkStats of one persisted chunk, from its payload."""
        raw = engine.fetch_full(key)
        header = chunklib.parse_header(raw)
        codec = get_codec(header.codec)
        dtype = np.dtype(header.dtype)
        acc = _StatsAccumulator(dtype)
        for i in range(header.num_samples):
            s, e = header.byte_range(i)
            payload = raw[s:e]
            try:
                if header.is_tiled(i):
                    desc = TileDescriptor.from_bytes(payload)
                    blobs = engine.fetch_many(
                        [t._chunk_key(nm) for nm in desc.chunk_names])
                    acc.observe(assemble_from_tiles(
                        desc, [blobs[t._chunk_key(nm)]
                               for nm in desc.chunk_names]))
                else:
                    acc.observe(codec.decode(payload, header.shapes[i],
                                             dtype))
            except Exception:
                acc.mark_inexact()
        return acc.snapshot(header.nbytes_data())

    # --------------------------------------------------- manifest compaction
    def compact_manifest(self, *, dry_run: bool = False) -> MaintenanceReport:
        """Fold delta segments + stale/uncovered nodes into one consolidated
        segment; adopt a manifest for legacy datasets."""
        ds = self.ds
        ds.flush()
        vc = ds.vc
        report = MaintenanceReport("compact_manifest", dry_run)
        adopted = vc.manifest is None
        segments_before = 0 if adopted else len(vc.manifest.segments)
        stale_before = 0 if adopted else len(vc.manifest.stale
                                             & set(vc.manifest.nodes))
        nodes = {nid: vc.node_snapshot(nid) for nid in vc.commits}
        report.details.update(
            nodes_folded=len(nodes), segments_folded=segments_before,
            stale_readopted=stale_before, adopted=int(adopted),
            # tensors whose scan index (chunk bounds + stats) was lifted
            # into the manifest's column-statistics section: plan-at-open
            # coverage after this compaction
            column_stats_lifted=sum(len(ns.stats) for ns in nodes.values()))
        if dry_run:
            return report
        if vc.manifest is None:
            vc.manifest = Manifest.create(vc.storage)
        seg_key = vc.manifest.replace_segments(nodes)
        # force: a freshly adopted pointer carries no version tree yet, and
        # without one the next cold open pays an extra vc_info GET
        vc.save_info(force=True)
        report.actions.append(seg_key)
        return report

    # -------------------------------------------------------- orphan-chunk GC
    def gc_orphans(self, *, dry_run: bool = True) -> MaintenanceReport:
        """Mark-and-sweep unreachable chunks / segments / node dirs.

        See the module docstring for the reachability rule.  Conservative:
        a chunk referenced by ANY node's chunk_set or encoder snapshot —
        for any node in the commit tree whose schema holds the tensor —
        survives, no matter which node directory stores it.
        """
        ds = self.ds
        ds.flush()
        vc = ds.vc
        storage = vc.storage
        report = MaintenanceReport("gc_orphans", dry_run)
        # ---- mark
        live_nodes = set(vc.commits)
        live_pairs: Set[Tuple[str, str]] = set()   # (tensor, chunk name)
        for nid in live_nodes:
            for tname in vc.schema_tensors(nid):
                for cname in vc.chunk_set(nid, tname):
                    live_pairs.add((tname, cname))
                enc_raw = vc.get_state(tname, "chunk_encoder", nid)
                if enc_raw:
                    for cname in ChunkEncoder.deserialize(enc_raw).chunk_names():
                        live_pairs.add((tname, cname))
        live_segments = set(vc.manifest.segments) if vc.manifest else set()
        # ---- sweep
        orphans: List[str] = []
        for key in storage.list_keys("versions/"):
            nm = _NODE_KEY_RE.match(key)
            if nm and nm.group("node") not in live_nodes:
                orphans.append(key)     # whole node dir fell off the tree
                continue
            cm = _CHUNK_KEY_RE.match(key)
            if cm and (cm.group("tensor"), cm.group("name")) not in live_pairs:
                orphans.append(key)
        for key in storage.list_keys(SEGMENT_PREFIX):
            if key not in live_segments:
                orphans.append(key)
        reclaimed = 0
        orphan_chunks = 0
        orphan_chunk_bytes = 0
        engine = fetch.engine_for(storage)
        for key in orphans:
            try:
                nb = storage.num_bytes(key)
            except StorageError:
                continue  # raced away already
            reclaimed += nb
            if _CHUNK_KEY_RE.match(key):
                # chunk-payload orphans specifically: the write-chaos bench
                # gates on these being ~0 after non-overlapping contention
                # (rebase grafts uploaded chunks instead of abandoning them)
                orphan_chunks += 1
                orphan_chunk_bytes += nb
            if not dry_run:
                storage.delete(key)
                engine.discard(key)
        report.actions = orphans
        report.details.update(
            chunks_live=len(live_pairs), orphans=len(orphans),
            orphan_chunks=orphan_chunks,
            orphan_chunk_bytes=orphan_chunk_bytes,
            bytes_reclaimed=reclaimed if not dry_run else 0,
            bytes_reclaimable=reclaimed)
        return report
