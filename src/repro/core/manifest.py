"""Dataset manifest: one-object catalogs for cold opens (§4.1 ACID commits).

The paper's lakehouse promise — ACID ingestion, time travel, petabyte
catalogs on object storage — needs a *consolidated* commit manifest: without
one, every cold ``Dataset`` open issues one GET per per-tensor state file
(meta, chunk encoder, sample ids, stats sidecar, chunk set, commit diff),
which is the dominant request class on small queries.  This module follows
the Delta-style consolidated-log design: a tiny *pointer* object that is
compare-and-swapped on every publication, plus immutable *segment* objects
holding complete per-node state snapshots.

Storage layout (keys relative to the dataset root)
--------------------------------------------------

::

    manifest.json                       # the POINTER (mutable, CAS-guarded)
    manifests/seg-{gen:08d}-{rand}.json # SEGMENTS (immutable, write-once)

Pointer schema::

    {"format":     "deeplake-repro-manifest-v2",
     "generation": <int, bumped by every successful CAS>,
     "segments":   [<segment key>, ...],   # newest first
     "vc":         {...} | null,           # version_control_info snapshot
     "stale":      [<node id>, ...]}       # nodes whose loose files win

Segment schema::

    {"format": "deeplake-repro-manifest-v2",
     "nodes": {<node id>: {"schema": [<tensor>, ...],
                           "tensors": {<tensor>: {<state file>: b64|null}},
                           "stats":   {<tensor>: {"last_idx": [...],
                                                  "chunks": [{...}|null]}}}}}

Each segment entry is a **complete snapshot of one commit node**: the raw
bytes of every per-tensor state file (``meta.json``, ``chunk_encoder``,
``sample_ids``, ``chunk_stats.json``, ``chunk_set.json``,
``commit_diff.json``), base64-encoded.  Folding segments newest-first with
whole-node replacement therefore reconstructs the catalog exactly; the
loose per-file layout stays on storage untouched, so legacy readers (and
the fallback path) always see a complete dataset.

Column-statistics section (format v2+, plan-at-open)
----------------------------------------------------

``"stats"`` is a decoded *scan index* per tensor: the chunk-boundary table
(``last_idx``, the encoder's inclusive last-global-index per chunk) plus
the per-chunk :class:`~repro.core.chunks.ChunkStats` records, in chunk-ord
order.  It duplicates information already inside the b64 ``chunk_encoder``
/ ``chunk_stats.json`` state bytes, but in a form
:func:`repro.core.tql.planner.plan_where` can consume directly — so a TQL
``WHERE`` on a committed dataset is planned straight from the 2-request
cold open, before any :class:`~repro.core.tensor.Tensor` binds.  The
section is optional everywhere: v1 segments (and nodes snapshotted without
decodable encoder bytes) simply lack it and readers fall back to binding
tensors.  v1 pointers/segments load unchanged; the first publication
rewrites the pointer as the current format.

Format v3 (membership sketches + top-k bounds)
----------------------------------------------

v3 extends each record of the column-statistics section with the chunk's
membership sketch — ``sketched`` / ``dom`` / ``dct`` / ``bloom``, wire
format and soundness rules in :mod:`repro.core.chunks` — which the planner
turns into ``=`` / ``IN`` / ``CONTAINS`` prune verdicts, and the executor's
``ORDER BY … LIMIT`` top-k scan reads the same records for its chunk-skip
bounds.  The node/segment/pointer *structure* is unchanged: v1 and v2
manifests still load (their records deserialize with ``sketched=False``,
so membership probes fall back to verify verdicts until ``backfill_stats``
+ ``compact_manifest`` lift the sketches), and a v3 reader folding a mixed
chain treats each record independently.

CAS protocol (optimistic concurrency)
-------------------------------------

Every pointer mutation goes through ``StorageProvider.cas`` with the last
observed pointer bytes as ``expected``:

* **commit** (`commit_update`): write the new segment object first (it is
  unreachable until published, so a crash leaves only an orphan for GC),
  then CAS the pointer with the segment prepended, the new version-tree
  snapshot, and the published nodes removed from ``stale``.  A lost CAS
  reloads the pointer; if another writer advanced *any* branch head in the
  meantime the commit raises :class:`ManifestConflict` — the paper's ACID
  ingestion semantics (exactly one concurrent committer wins).
  :meth:`VersionControl.commit <repro.core.version_control.VersionControl.commit>`
  catches the conflict and **rebases**: it reloads the pointer, grafts this
  writer's already-uploaded chunks onto the winner's head (cross-branch
  commits merge trees outright; same-branch commits relocate iff the two
  writers touched disjoint tensor sets), and re-CASes — so on
  non-overlapping contention only the pointer ever contends and no chunk
  is uploaded twice.  Overlapping writes surface a typed
  ``CommitContendedError`` after bounded attempts.
* **pointer-only updates** (`update_vc`, `mark_stale`) reload-merge-retry:
  they cannot invalidate another writer's publication, so losing the race
  just means reapplying the mutation to the fresh pointer.  ``update_vc``
  keeps the strict all-branches fence (it republishes the *whole* tree and
  would clobber unseen branches); ``mark_stale`` only fences on its own
  node having been sealed by a foreign commit, since adding a staleness
  flag can never invalidate anyone else's snapshot.

Write-path guarantees (hostile storage)
---------------------------------------

Segment objects are uploaded with
:meth:`StorageProvider.put_verified <repro.core.storage.StorageProvider.put_verified>`
(post-put length/digest verification + transient retry), so a torn upload
is detected and re-put before the pointer ever references it.  The pointer
CAS itself is wrapped in :func:`repro.core.storage.retry_transient`: an
injected 5xx on the conditional put (which dies *before* applying) is
retried with the same ``expected`` token, while a clean ``False`` return
means real contention and reloads.  Publication stays a **single CAS**, so
a writer crashing at any earlier point leaves only unreferenced objects
(segments, chunks, loose state) that the orphan GC reclaims — never a
partially-visible commit.

Staleness (write-ahead invalidation)
------------------------------------

Committed nodes never change, so their manifest snapshots are valid
forever.  The writable head *does* change between commits: before the
first loose state write to a node the manifest currently covers,
``VersionControl.put_state`` calls :meth:`Manifest.mark_stale`, which
CASes the node onto the pointer's ``stale`` list *before* the loose write
lands.  Readers treat stale nodes as uncovered and fall back to the loose
per-file layout, so a concurrently-opened ``Dataset`` can never read a
superseded snapshot.  The next commit republishes the node and clears the
flag.

Consolidation
-------------

``commit_update`` folds the whole in-memory catalog into a single
consolidated segment whenever the encoded payload stays under
``AUTO_CONSOLIDATE_BYTES`` or the delta chain exceeds
``MAX_DELTA_SEGMENTS`` (the Delta-checkpoint pattern); otherwise it
appends an incremental delta segment.  The ``compact_manifest``
maintenance job (:mod:`.maintenance`) performs the same fold on demand and
re-adopts stale/uncovered nodes from loose files.  Superseded segment
objects are left behind deliberately — they are unreachable from the
pointer and the orphan GC sweeps them.

Cold-open request budget
------------------------

Opening a manifest dataset costs ``1 (pointer GET) + len(segments)``
requests for *all* catalog state — ``ds_meta.json`` is implied by the
pointer's format marker and the version tree rides inside the pointer, so
a consolidated dataset opens in **2 requests** regardless of tensor count
(vs ``~2 + 6·n_tensors`` for the legacy layout).  Segment reads go through
:meth:`FetchEngine.fetch_many <repro.core.fetch.FetchEngine.fetch_many>`
so they are batched, observed by the engine's cost EWMA, and accounted in
``Manifest.open_stats``.
"""

from __future__ import annotations

import base64
import json
import uuid
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Union

import numpy as np

from .chunks import ChunkStats
from .storage import StorageError, StorageProvider, retry_transient

MANIFEST_KEY = "manifest.json"
SEGMENT_PREFIX = "manifests/"
FORMAT = "deeplake-repro-manifest-v3"
#: readable formats: v1 predates the column-statistics section, v2 the
#: membership sketches inside it (both degrade gracefully, never fail)
COMPAT_FORMATS = ("deeplake-repro-manifest-v1",
                  "deeplake-repro-manifest-v2", FORMAT)

#: fold to a single consolidated segment while the payload stays this small
AUTO_CONSOLIDATE_BYTES = 4 << 20
#: ... or whenever the delta chain grows past this many segments
MAX_DELTA_SEGMENTS = 8
#: pointer CAS attempts for reload-merge-retry updates before giving up
CAS_RETRIES = 8


class ManifestConflict(RuntimeError):
    """A concurrent writer won the manifest-pointer CAS race."""


def _b64e(data: Optional[bytes]) -> Optional[str]:
    return None if data is None else base64.b64encode(data).decode("ascii")


def _b64d(s: Optional[str]) -> Optional[bytes]:
    return None if s is None else base64.b64decode(s.encode("ascii"))


@dataclass
class ColumnStats:
    """Manifest-resident scan index of one tensor (format v2).

    ``last_idx[i]`` is the inclusive last global sample index of chunk
    ``i`` (the chunk-encoder boundary table) and ``chunk_stats[i]`` its
    :class:`~repro.core.chunks.ChunkStats` record (None when the chunk
    predates the stats sidecar).  Together they are everything
    ``plan_where`` needs to classify chunk groups — no tensor bind, no
    storage request.
    """

    last_idx: np.ndarray
    chunk_stats: List[Optional[ChunkStats]]

    @property
    def num_samples(self) -> int:
        return int(self.last_idx[-1]) + 1 if len(self.last_idx) else 0

    @property
    def num_chunks(self) -> int:
        return len(self.last_idx)

    def ords_of(self, indices: Union[Sequence[int], np.ndarray]) -> np.ndarray:
        """Vectorized global-index -> chunk-ord map (the same
        implementation :meth:`ChunkEncoder.ords_of` uses, so the
        manifest-served planner path can never diverge from the
        bound-tensor path)."""
        from .chunk_encoder import ords_of_boundaries
        return ords_of_boundaries(self.last_idx, indices)

    def stats_of(self, chunk_ord: int) -> Optional[ChunkStats]:
        return self.chunk_stats[int(chunk_ord)]

    def to_json(self) -> dict:
        return {"last_idx": [int(x) for x in self.last_idx],
                "chunks": [None if s is None else s.to_json()
                           for s in self.chunk_stats]}

    @classmethod
    def from_json(cls, d: dict) -> "ColumnStats":
        return cls(
            last_idx=np.asarray(d.get("last_idx", []), dtype=np.int64),
            chunk_stats=[None if s is None else ChunkStats.from_json(s)
                         for s in d.get("chunks", [])])


@dataclass
class NodeState:
    """Complete state snapshot of one commit node: schema + raw state-file
    bytes per tensor (``None`` marks a file the node never wrote), plus the
    optional decoded column-statistics section (format v2)."""

    schema: List[str] = field(default_factory=list)
    tensors: Dict[str, Dict[str, Optional[bytes]]] = field(default_factory=dict)
    #: tensor -> ColumnStats; absent for v1 segments / undecodable state
    stats: Dict[str, ColumnStats] = field(default_factory=dict)

    def to_json(self) -> dict:
        out = {"schema": list(self.schema),
               "tensors": {t: {f: _b64e(b) for f, b in files.items()}
                           for t, files in self.tensors.items()}}
        if self.stats:
            out["stats"] = {t: cs.to_json() for t, cs in self.stats.items()}
        return out

    @classmethod
    def from_json(cls, d: dict) -> "NodeState":
        return cls(schema=list(d.get("schema", [])),
                   tensors={t: {f: _b64d(s) for f, s in files.items()}
                            for t, files in d.get("tensors", {}).items()},
                   stats={t: ColumnStats.from_json(s)
                          for t, s in d.get("stats", {}).items()})


def _new_segment_key(generation: int) -> str:
    return f"{SEGMENT_PREFIX}seg-{generation:08d}-{uuid.uuid4().hex[:8]}.json"


class Manifest:
    """In-memory fold of the pointer + its segment chain for one dataset.

    Owned by :class:`~repro.core.version_control.VersionControl`; all
    catalog reads/writes route through it when present.  See the module
    docstring for the wire format and CAS protocol.
    """

    def __init__(self, storage: StorageProvider, pointer: dict,
                 pointer_raw: bytes, nodes: Dict[str, NodeState],
                 open_stats: Optional[Dict[str, int]] = None) -> None:
        self.storage = storage
        self.generation: int = int(pointer.get("generation", 0))
        self.segments: List[str] = list(pointer.get("segments", []))
        self.vc_info: Optional[dict] = pointer.get("vc")
        self.stale: Set[str] = set(pointer.get("stale", []))
        self.nodes = nodes
        self._pointer_raw = pointer_raw
        # branch heads as this writer last published or loaded them; every
        # vc-publishing update compares the persisted pointer against this
        # (NOT against the raw CAS token, which benign retries refresh) so
        # a foreign commit can never be silently clobbered
        self._observed_branches: Dict[str, str] = dict(
            (pointer.get("vc") or {}).get("branches", {}))
        #: request accounting of the open path (pointer + segment reads)
        self.open_stats: Dict[str, int] = open_stats or {"requests": 0,
                                                         "bytes": 0}

    # ------------------------------------------------------------- open path
    @classmethod
    def load(cls, storage: StorageProvider) -> Optional["Manifest"]:
        """Fold the pointer + segments into a catalog; None = no manifest.

        The segment chain is fetched as ONE :meth:`FetchEngine.fetch_many`
        batch (the manifest prefetch of the cold-open path); newer segments
        replace older ones whole-node.
        """
        raw = storage.get_or_none(MANIFEST_KEY)
        if raw is None:
            return None
        pointer = json.loads(raw.decode())
        if pointer.get("format") not in COMPAT_FORMATS:
            raise StorageError(f"unsupported manifest format: "
                               f"{pointer.get('format')!r}")
        counters = {"requests": 1, "bytes": len(raw)}
        nodes: Dict[str, NodeState] = {}
        seg_keys = list(pointer.get("segments", []))
        if seg_keys:
            from . import fetch  # lazy: keep storage-only users import-light
            blobs = fetch.engine_for(storage).fetch_many(seg_keys,
                                                         counters=counters)
            for key in reversed(seg_keys):  # oldest first; newest wins
                seg = json.loads(blobs[key].decode())
                for nid, nd in seg.get("nodes", {}).items():
                    nodes[nid] = NodeState.from_json(nd)
        return cls(storage, pointer, raw, nodes, open_stats=counters)

    @classmethod
    def create(cls, storage: StorageProvider) -> "Manifest":
        """Bootstrap an empty manifest pointer (brand-new dataset).

        Races with a concurrent creator resolve by loading theirs.
        """
        pointer = {"format": FORMAT, "generation": 0, "segments": [],
                   "vc": None, "stale": []}
        raw = json.dumps(pointer, sort_keys=True).encode()
        if retry_transient(lambda: storage.cas(MANIFEST_KEY, raw, None),
                           what=MANIFEST_KEY):
            return cls(storage, pointer, raw, {})
        existing = cls.load(storage)
        assert existing is not None
        return existing

    # ------------------------------------------------------------- coverage
    def covers(self, node_id: str) -> bool:
        """True when the manifest snapshot of ``node_id`` is authoritative
        (present and not invalidated by a loose write)."""
        return node_id in self.nodes and node_id not in self.stale

    def node_schema(self, node_id: str) -> Optional[List[str]]:
        ns = self.nodes.get(node_id)
        return None if ns is None else list(ns.schema)

    def state_bytes(self, node_id: str, tensor: str,
                    fname: str) -> Optional[bytes]:
        """Raw bytes of one state file from the covered snapshot (None when
        the node never wrote it — an authoritative miss, not a fallback)."""
        ns = self.nodes.get(node_id)
        if ns is None:
            return None
        return ns.tensors.get(tensor, {}).get(fname)

    def column_stats(self, node_id: str,
                     tensor: str) -> Optional[ColumnStats]:
        """The covered snapshot's scan index of one tensor, or None when
        the node is uncovered/stale or the segment predates format v2 —
        callers then fall back to binding the tensor."""
        if not self.covers(node_id):
            return None
        return self.nodes[node_id].stats.get(tensor)

    # ------------------------------------------------------- pointer updates
    def _pointer_dict(self) -> dict:
        return {"format": FORMAT, "generation": self.generation,
                "segments": list(self.segments), "vc": self.vc_info,
                "stale": sorted(self.stale)}

    def _apply_pointer(self, pointer: dict, raw: bytes) -> None:
        self.generation = int(pointer.get("generation", 0))
        self.segments = list(pointer.get("segments", []))
        self.vc_info = pointer.get("vc")
        self.stale = set(pointer.get("stale", []))
        self._pointer_raw = raw

    def _cas_update(self, mutate: Callable[[dict], dict],
                    what: str) -> None:
        """Reload-merge-retry pointer update: ``mutate`` receives the
        freshest pointer dict and returns the successor (it may raise
        :class:`ManifestConflict` when its preconditions broke)."""
        expected = self._pointer_raw
        pointer = json.loads(expected.decode())
        for _ in range(CAS_RETRIES):
            new_pointer = mutate(pointer)
            new_pointer["generation"] = int(pointer.get("generation", 0)) + 1
            raw = json.dumps(new_pointer, sort_keys=True).encode()
            # injected 5xx dies before applying, so retrying with the same
            # expected token is safe; False means real contention
            if retry_transient(
                    lambda: self.storage.cas(MANIFEST_KEY, raw, expected),
                    what=MANIFEST_KEY):
                self._apply_pointer(new_pointer, raw)
                return
            expected = retry_transient(  # lost: reload (transients retried)
                lambda: self.storage.get(MANIFEST_KEY), what=MANIFEST_KEY)
            pointer = json.loads(expected.decode())
        raise ManifestConflict(
            f"manifest pointer update ({what}) lost the CAS race "
            f"{CAS_RETRIES} times")

    def _check_branches(self, pointer: dict, what: str) -> None:
        """Raise :class:`ManifestConflict` when the persisted pointer shows
        branch heads this writer has never observed (a foreign commit)."""
        cur = (pointer.get("vc") or {}).get("branches", {})
        if cur and cur != self._observed_branches:
            raise ManifestConflict(
                f"{what} lost: a concurrent writer moved a branch head "
                f"(persisted {cur}, last observed {self._observed_branches})")

    def update_vc(self, vc_info: dict) -> None:
        """Publish a new version-tree snapshot (checkout, flush, ...).
        Conflicts with a concurrent committer rather than clobbering it."""
        def mutate(p: dict) -> dict:
            self._check_branches(p, "vc publish")
            out = dict(p)
            out["vc"] = vc_info
            return out
        self._cas_update(mutate, "vc snapshot")
        self._observed_branches = dict(vc_info.get("branches", {}))

    def mark_stale(self, node_id: str, *, known_committed: bool = False) -> None:
        """Write-ahead invalidation: persist ``node_id`` onto the stale
        list BEFORE its first loose state write lands, so concurrent
        opens fall back to loose files instead of the dead snapshot.

        The update doubles as the conflict fence for the loose layout —
        but a *node-scoped* one: it raises :class:`ManifestConflict` only
        when the persisted pointer shows ``node_id`` itself was sealed by
        a foreign commit (the pending write would then clobber an
        immutable node's loose files).  Foreign movement of *other*
        branches is deliberately not a conflict here — adding a staleness
        flag cannot invalidate anyone else's publication, and deferring
        the cross-branch check to commit time is what lets
        ``VersionControl.commit`` rebase without re-uploading.  Callers
        that write to nodes they already know are sealed (maintenance
        backfill) pass ``known_committed=True`` to skip the fence.
        """
        self.stale.add(node_id)
        if node_id not in self.nodes:
            return  # never covered: nothing persisted to invalidate

        def mutate(p: dict) -> dict:
            if not known_committed:
                nd = ((p.get("vc") or {}).get("commits", {})).get(node_id)
                if nd and nd.get("committed"):
                    raise ManifestConflict(
                        f"stale mark of {node_id[:8]} lost: the node was "
                        f"sealed by a concurrent commit")
            out = dict(p)
            out["stale"] = sorted(set(p.get("stale", [])) | {node_id})
            return out
        self._cas_update(mutate, f"stale({node_id[:8]})")

    # ---------------------------------------------------------- publication
    def _encode_segment(self, nodes: Dict[str, NodeState]) -> bytes:
        return json.dumps(
            {"format": FORMAT,
             "nodes": {nid: ns.to_json() for nid, ns in nodes.items()}},
            sort_keys=True).encode()

    def _catalog_size_estimate(self) -> int:
        """Approximate encoded size of a consolidated segment, from raw
        state-file lengths (b64 is 4/3) — O(#files) len() calls, so the
        consolidate-vs-delta decision never serializes a catalog it is
        about to discard."""
        total = 64
        for ns in self.nodes.values():
            total += 96 + sum(len(t) + 8 for t in ns.schema)
            for t, files in ns.tensors.items():
                total += len(t) + 32
                for f, b in files.items():
                    total += len(f) + 16 + (0 if b is None else len(b) * 4 // 3)
            for t, cs in ns.stats.items():
                # ~20 chars per boundary int, ~220 per ChunkStats record
                total += len(t) + 32 + cs.num_chunks * 240
                for s in cs.chunk_stats:  # + the membership sketch payload
                    if s is None:
                        continue
                    if s.dct is not None:
                        total += 8 + sum(
                            (len(v) + 4) if isinstance(v, str) else 16
                            for v in s.dct)
                    if s.bloom:
                        total += len(s.bloom) + 16
        return total

    def commit_update(self, node_states: Dict[str, NodeState],
                      vc_info: dict, *, branch: str) -> str:
        """Atomically publish a commit: new segment + pointer swap.

        ``node_states`` are complete snapshots of the sealed node and the
        fresh head.  Publication is optimistic: if a pointer reload shows
        any branch head moved past what this writer last observed (another
        commit landed concurrently), :class:`ManifestConflict` is raised —
        the loose layout this commit already wrote stays readable, and the
        caller re-opens the dataset to retry.  Lost races against
        pointer-only updates (staleness marks, vc refreshes) are retried
        transparently.  Returns the published segment key.
        """
        self.nodes.update(node_states)
        self.stale -= set(node_states)
        if (self._catalog_size_estimate() <= AUTO_CONSOLIDATE_BYTES
                or len(self.segments) + 1 > MAX_DELTA_SEGMENTS):
            seg_bytes, seg_nodes = self._encode_segment(self.nodes), None
        else:  # large catalog: publish only the two changed nodes
            seg_bytes = self._encode_segment(node_states)
            seg_nodes = list(node_states)
        seg_key = _new_segment_key(self.generation + 1)
        # verified: a torn segment upload must never be published by the CAS
        self.storage.put_verified(seg_key, seg_bytes)  # unreachable until CAS

        def mutate(p: dict) -> dict:
            self._check_branches(p, f"commit on {branch!r}")
            out = dict(p)
            if seg_nodes is None:
                out["segments"] = [seg_key]  # checkpoint supersedes chain
            else:
                out["segments"] = [seg_key] + list(p.get("segments", []))
            out["vc"] = vc_info
            out["stale"] = sorted(set(p.get("stale", []))
                                  - set(node_states))
            return out

        self._cas_update(mutate, f"commit({branch})")
        self._observed_branches = dict(vc_info.get("branches", {}))
        return seg_key

    def replace_segments(self, nodes: Dict[str, NodeState]) -> str:
        """Publish a consolidated segment covering ``nodes`` and collapse
        the pointer's chain to it (manifest compaction).  Stale flags of
        re-adopted nodes are cleared.  Returns the new segment key."""
        self.nodes = dict(nodes)
        seg_bytes = self._encode_segment(self.nodes)
        seg_key = _new_segment_key(self.generation + 1)
        self.storage.put_verified(seg_key, seg_bytes)

        def mutate(p: dict) -> dict:
            out = dict(p)
            out["segments"] = [seg_key]
            out["stale"] = sorted(set(p.get("stale", [])) - set(nodes))
            return out
        self._cas_update(mutate, "compaction")
        return seg_key
