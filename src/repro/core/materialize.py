"""Materialization (§4.4): turn a (possibly sparse / linked / derived) view
into a new dataset with stream-optimal chunk layout.

Doing this *late* in the ML workflow minimizes duplication while restoring
sequential chunk locality (``DatasetView.chunk_locality`` ≈ 1.0 after) and
resolving ``link[...]`` indirection, with full lineage: the destination
records the source commit + view indices.
"""

from __future__ import annotations

import json
from typing import Optional, Sequence, Union

import numpy as np

from .dataset import Dataset
from .linked import LinkRegistry, resolve_link
from .storage import StorageProvider
from .views import DatasetView


def materialize(
    view: DatasetView,
    dest: Union[Dataset, StorageProvider, str, None] = None,
    *,
    tensors: Optional[Sequence[str]] = None,
    resolve_links: bool = True,
    registry: Optional[LinkRegistry] = None,
    commit_message: str = "materialize",
) -> Dataset:
    out = dest if isinstance(dest, Dataset) else Dataset(dest)
    names = list(tensors) if tensors else list(view.tensor_names)

    # --- schema -----------------------------------------------------------
    for name in names:
        if name in out.tensor_names:
            continue
        if name in view.derived:
            vals = view.derived[name]
            dtype = str(np.asarray(vals[0]).dtype) if vals else "float32"
            out.create_tensor(name, htype="generic", dtype=dtype,
                              sample_compression="raw")
        else:
            src = view._base_tensor(name)
            meta = src.meta
            htype = meta.htype
            if resolve_links and htype.startswith("link["):
                htype = htype[len("link["):-1]  # materialized data is concrete
                out.create_tensor(name, htype=htype, dtype=None,
                                  sample_compression="raw", strict=False)
            else:
                out.create_tensor(name, htype=htype, dtype=meta.dtype,
                                  sample_compression=meta.codec,
                                  min_chunk_size=meta.min_chunk_size,
                                  max_chunk_size=meta.max_chunk_size,
                                  strict=meta.strict)

    # --- rows, in view order (sequential layout == optimal streaming) ------
    for i in range(len(view)):
        row = {}
        for name in names:
            if name in view.derived:
                row[name] = np.asarray(view.derived[name][i])
            else:
                src = view._base_tensor(name)
                val = src.read(int(view.indices[i]))
                if resolve_links and src.meta.htype.startswith("link["):
                    val = resolve_link(val, registry)
                row[name] = val
        out.append(row)

    # --- lineage ------------------------------------------------------------
    out.storage.put("lineage.json", json.dumps({
        "source_commit": view.node_id or view.dataset.vc.current_id,
        "num_rows": len(view),
        "indices_head": view.indices[:64].tolist(),
        "tensors": names,
    }).encode())
    out.commit(commit_message)
    return out
