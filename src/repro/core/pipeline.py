"""Unified scan pipeline: plan → schedule → prefetch → stream-decode.

The paper's performance story (§3.5, §4.3–4.5) is that the query planner,
the streaming loader, and the fetch layer behave as ONE pipeline that keeps
the training step — never I/O — the bottleneck.  This module is that
pipeline's spine: a :class:`ScanPlan`-shaped chunk-group schedule owned by
:class:`ScanPipeline`, consumed by every layer of the read path:

* **plan** — :func:`repro.core.tql.planner.plan_where` classifies chunk
  groups from :class:`ScanSource` statistics.  Sources resolve
  manifest-first (:meth:`DatasetView.scan_source
  <repro.core.views.DatasetView.scan_source>`): on a committed dataset the
  chunk-boundary table and per-chunk stats ride in the manifest's
  column-statistics section, so planning costs **zero tensor binds and
  zero storage requests** beyond the cold open itself.
* **schedule** — the pipeline partitions a view's row positions into
  chunk groups (TQL streaming) or fetch units (the loader's order plan),
  with ``unit_size`` / ``prefetch_units`` derived from the fetch engine's
  latency/bandwidth model via :meth:`CostModel.derive_unit_size
  <repro.core.scheduler.CostModel.derive_unit_size>` instead of fixed
  defaults.
* **prefetch** — a rolling, byte-bounded window of whole-chunk prefetches
  runs ahead of consumption, across unit boundaries: while chunk group
  ``k`` decodes, group ``k+1``'s blobs are already in flight on
  :meth:`FetchEngine.prefetch <repro.core.fetch.FetchEngine.prefetch>`.
  The window never queues more than half the destination buffer, so a
  deep scan cannot evict its own staged blobs; teardown cancels only this
  pipeline's still-queued fetches.
* **stream-decode** — :meth:`ScanPipeline.stream` yields one chunk group
  at a time; the TQL executor evaluates WHERE per group as blobs arrive
  instead of stacking whole columns first.

One pipeline instance serves one scan; engines (and their resident
stores) stay shared per provider, so concurrent pipelines dedup in-flight
chunks against each other.
"""

from __future__ import annotations

import threading
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from . import fetch as fetchlib
from . import telemetry
from .chunks import ChunkStats
from .manifest import ColumnStats
from .scheduler import CostModel


# --------------------------------------------------------------- scan sources
class ScanSource:
    """Read-only view of one tensor's chunk layout + statistics, enough
    for planning and scheduling without touching payloads."""

    name: str

    def ords_of(self, indices) -> np.ndarray:          # pragma: no cover
        raise NotImplementedError

    def stats_of(self, chunk_ord: int) -> Optional[ChunkStats]:
        raise NotImplementedError                       # pragma: no cover


class TensorScanSource(ScanSource):
    """Source backed by a bound :class:`~repro.core.tensor.Tensor`
    (sees live open-chunk state on a dirty head)."""

    def __init__(self, tensor) -> None:
        self.tensor = tensor
        self.name = tensor.name

    def ords_of(self, indices) -> np.ndarray:
        return self.tensor.encoder.ords_of(indices)

    def stats_of(self, chunk_ord: int) -> Optional[ChunkStats]:
        return self.tensor.chunk_stats_of(chunk_ord)


class ManifestScanSource(ScanSource):
    """Source served from the manifest's column-statistics section —
    no tensor bind, no storage request (plan-at-open)."""

    def __init__(self, name: str, column_stats: ColumnStats) -> None:
        self.name = name
        self.cs = column_stats

    def ords_of(self, indices) -> np.ndarray:
        return self.cs.ords_of(indices)

    def stats_of(self, chunk_ord: int) -> Optional[ChunkStats]:
        return self.cs.stats_of(chunk_ord)


# ------------------------------------------------------------ prefetch window
class _PrefetchWindow:
    """Rolling byte-bounded whole-chunk prefetch over an ordered key plan.

    ``plan[i]`` holds the ``(key, est_bytes)`` pairs first needed at step
    ``i`` (a chunk group or a fetch unit), deduplicated to their first
    step.  ``top_up`` queues steps in order while outstanding bytes stay
    under the budget (half the destination buffer — LRU tier or the
    engine's resident store), so staged-but-unconsumed blobs are never
    evicted by the window's own later prefetches; ``release`` returns a
    completed step's bytes to the budget.  One step is always admitted
    when the window is empty, so a single oversized step still streams.
    """

    def __init__(self, engine: "fetchlib.FetchEngine",
                 plan: List[List[Tuple[str, int]]], owner: object,
                 on_fetched: Optional[Callable[[int], None]] = None,
                 tenant: Optional[str] = None) -> None:
        self.engine = engine
        self.plan = plan
        self.owner = owner
        self.on_fetched = on_fetched
        self.tenant = tenant
        self.budget = (engine.cache_above or engine.resident_bytes) // 2
        self._step_bytes = [sum(b for _, b in step) for step in plan]
        self._next = 0                      # first step not yet queued
        self._released = [False] * len(plan)
        self.outstanding = 0
        #: prefetches that failed permanently (retry budget exhausted);
        #: the consumer's direct fetch covers them, but the count must be
        #: visible — a hostile store should never fail silently
        self.failed = 0
        # the loader's worker pool drives top_up/release concurrently;
        # pointer + byte accounting must move atomically
        self._lock = threading.Lock()

    def _note_result(self, fut) -> None:
        if not fut.cancelled() and fut.exception() is not None:
            with self._lock:
                self.failed += 1

    def top_up(self, upto_step: int) -> None:
        """Queue prefetches for steps ``[next, upto_step]`` while the byte
        budget allows (cross-step: the pointer runs ahead of consumption).
        Steps already consumed on demand (workers outran the window) are
        skipped, never prefetched after the fact."""
        upto = min(upto_step, len(self.plan) - 1)
        while True:
            with self._lock:
                if self._next > upto:
                    return
                step = self._next
                if self._released[step]:    # consumed on demand: skip
                    self._next += 1
                    continue
                nb = self._step_bytes[step]
                if self.outstanding and self.outstanding + nb > self.budget:
                    return  # the rest is fetched (coalesced) on demand
                self.outstanding += nb
                self._next += 1
            for key, est in self.plan[step]:
                fut = self.engine.prefetch(key, owner=self.owner,
                                           on_fetched=self.on_fetched,
                                           tenant=self.tenant,
                                           est_bytes=est)
                fut.add_done_callback(self._note_result)

    def release(self, step: int) -> None:
        """Step ``step`` was consumed: return its bytes to the budget (a
        step consumed before it was ever queued is only marked, so
        ``top_up`` skips it)."""
        with self._lock:
            if self._released[step]:
                return
            self._released[step] = True
            if step < self._next:           # was queued: bytes outstanding
                self.outstanding = max(0, self.outstanding
                                       - self._step_bytes[step])

    def cancel(self) -> int:
        return self.engine.cancel_pending(owner=self.owner)


# -------------------------------------------------------------- scan pipeline
class ScanPipeline:
    """Chunk-group schedule of one scan over a :class:`DatasetView`.

    Two entry points, one schedule currency:

    * :meth:`for_query` — chunk-group streaming for the TQL executor:
      :meth:`stream` yields ``(positions, subview)`` per group, with the
      next group's chunks prefetched while the current one decodes.
    * :meth:`for_units` — the loader's order plan: fetch units register
      here and :meth:`on_unit_start` keeps a ``prefetch_units``-deep
      window of upcoming units' chunks in flight **across unit
      boundaries** (the old per-epoch one-shot warmup only covered the
      leading units).

    Prefetch is active only against cost-bearing (remote) providers with
    coalescing enabled — on local/memory storage prefetch threads cost
    more than they save; scheduling and streaming still apply.

    **Failure semantics.**  The pipeline survives a hostile store with
    byte-identical results: the engine retries transient faults and hedges
    stragglers; a prefetch that exhausts its retry budget is counted
    (:attr:`prefetch_failures`) and the consuming read falls back to a
    direct fetch with a fresh budget.  Only a *permanent* failure of that
    direct fetch propagates to the consumer.
    """

    def __init__(self, view, tensors: Sequence[str], *,
                 owner: object = None,
                 on_fetched: Optional[Callable[[int], None]] = None,
                 tenant: Optional[str] = None) -> None:
        self.view = view
        self.names = [n for n in tensors
                      if n not in view.derived and n in view.tensor_names]
        self.owner = owner if owner is not None else self
        self.on_fetched = on_fetched
        self.tenant = tenant
        self.engine = fetchlib.engine_for(view.dataset.storage)
        self.active = (fetchlib.coalescing_enabled()
                       and fetchlib.provider_cost_params(
                           view.dataset.storage) is not None)
        self._window: Optional[_PrefetchWindow] = None
        self._groups: List[np.ndarray] = []
        self._ord_cols: List[np.ndarray] = []
        self._horizon = 0

    # ------------------------------------------------------------ query mode
    @classmethod
    def for_query(cls, view, tensors: Sequence[str],
                  owner: object = None,
                  tenant: Optional[str] = None) -> Optional["ScanPipeline"]:
        """Pipeline over the chunk groups of ``view`` (rows grouped by the
        tuple of chunks they live in across ``tensors``, in first-
        appearance order).  None when no base tensor is scannable."""
        pipe = cls(view, tensors, owner=owner, tenant=tenant)
        if not pipe.names or not len(view):
            return None
        ord_cols = []
        for n in pipe.names:
            src = view.scan_source(n)
            try:
                ord_cols.append(src.ords_of(view.indices))
            except IndexError:
                return None
        key_matrix = np.stack(ord_cols, axis=1)        # (rows, tensors)
        _uniq, inverse = np.unique(key_matrix, axis=0, return_inverse=True)
        order_rows = np.argsort(inverse, kind="stable")
        bounds = np.flatnonzero(np.diff(inverse[order_rows])) + 1
        parts = np.split(order_rows, bounds)           # parts[g]: positions
        firsts = np.full(len(parts), len(view), dtype=np.int64)
        np.minimum.at(firsts, inverse, np.arange(len(view)))
        pipe._groups = [parts[g] for g in np.argsort(firsts, kind="stable")]
        pipe._ord_cols = ord_cols
        return pipe

    @property
    def n_groups(self) -> int:
        return len(self._groups)

    def group_positions(self, g: int) -> np.ndarray:
        """View positions of chunk group ``g`` (current stream order)."""
        return self._groups[g]

    def group_ords(self, g: int) -> List[int]:
        """Chunk ord per planned tensor for group ``g`` — the key the
        planner uses to look up that group's statistics records."""
        first = int(self._groups[g][0])
        return [int(col[first]) for col in self._ord_cols]

    def reorder(self, order: Sequence[int]) -> None:
        """Permute the chunk-group schedule before :meth:`stream` — the
        top-k executor orders groups best-bound-first so the prefetch
        window (whose key plan is derived from the group order at stream
        start) carries the planner's priorities, and early termination
        cuts the stream as soon as no remaining group can matter."""
        if self._window is not None:
            raise RuntimeError("cannot reorder a streaming pipeline")
        self._groups = [self._groups[i] for i in order]

    def _query_keyplan(self) -> List[List[Tuple[str, int]]]:
        """Per-group (chunk key, est bytes), dedup'd to first need."""
        seen: set = set()
        plan: List[List[Tuple[str, int]]] = []
        tensors = [self.view._base_tensor(n) for n in self.names]
        for positions in self._groups:
            step: List[Tuple[str, int]] = []
            for t, ords in zip(tensors, self._ord_cols):
                o = int(ords[positions[0]])  # one ord tuple per group
                key, est = _chunk_key_est(t, o)
                if key is not None and key not in seen:
                    seen.add(key)
                    step.append((key, est))
            plan.append(step)
        return plan

    def stream(self) -> Iterator[Tuple[np.ndarray, Any]]:
        """Yield ``(positions, subview)`` per chunk group, prefetching the
        window of upcoming groups while the current one decodes.  The
        caller evaluates each subview and scatters results back by
        position; teardown (exhaustion or ``close``) cancels the
        pipeline's still-queued prefetches."""
        if self.active and self._window is None:
            self._window = _PrefetchWindow(self.engine, self._query_keyplan(),
                                           self.owner, self.on_fetched,
                                           self.tenant)
        try:
            for gi, positions in enumerate(self._groups):
                if self._window is not None:
                    with telemetry.gspan(gi + 1, "prefetch"):
                        self._window.top_up(gi + 1)  # k decodes, k+1 flies
                # the deliver span covers the consumer's evaluation of the
                # yielded group (decode + predicate work happen there)
                with telemetry.gspan(gi, "deliver", rows=len(positions)):
                    yield positions, self.view[positions]
                if self._window is not None:
                    self._window.release(gi)
        finally:
            self.close()

    #: sharded-stream backpressure: a worker may run at most this many
    #: groups (x shards) ahead of the consumer before parking
    _SHARD_LEAD = 4

    def stream_sharded(self, eval_fn: Callable[[np.ndarray, Any], Any], *,
                       shards: int, skip=None
                       ) -> Iterator[Tuple[int, np.ndarray, Any]]:
        """Parallel chunk-group scan: evaluate ``eval_fn(positions,
        subview)`` per group on ``shards`` worker threads, yielding
        ``(group_index, positions, result)`` **in plan order** — results
        are byte-identical to a serial :meth:`stream` + scatter because
        the group partition of the view's rows (and the consumer's
        plan-order merge) is independent of evaluation order.

        Groups are assigned worker-round-robin in plan order
        (:func:`repro.distributed.sharding.shard_groups`), so every worker
        starts near the head of the schedule and the ordered re-merge
        never waits on a worker busy with far-future groups.  ``skip(gi)``
        — checked immediately before a group is evaluated, i.e. against
        the *freshest* shared state — lets the top-k executor drop groups
        whose bound can no longer beat the shared cutoff; skipped groups
        yield ``result=None``.  Workers are dedicated threads, never the
        engine's work pool: group evaluation itself blocks on that pool
        (``read_batch`` lookahead), and nesting would deadlock it.
        Closing the generator early (top-k termination) stops workers at
        their next group boundary and cancels this pipeline's remaining
        prefetches.
        """
        from ..distributed.sharding import shard_groups

        n = self.n_groups
        shards = max(1, min(int(shards), n))
        if self.active and self._window is None:
            self._window = _PrefetchWindow(self.engine, self._query_keyplan(),
                                           self.owner, self.on_fetched,
                                           self.tenant)
        results: Dict[int, Any] = {}
        errors: List[BaseException] = []
        stop = threading.Event()
        cond = threading.Condition()
        emitted = [0]                      # groups the consumer has taken

        def worker(w: int, my_groups: List[int]) -> None:
            with telemetry.span(f"serve.shard[{w}]", groups=len(my_groups)):
                for gi in my_groups:
                    with cond:
                        # bounded run-ahead; the next-needed group's worker
                        # always passes (its gi IS the emit floor)
                        cond.wait_for(lambda: stop.is_set() or gi < emitted[0]
                                      + self._SHARD_LEAD * shards)
                    if stop.is_set():
                        return
                    if self._window is not None:
                        self._window.top_up(gi + shards)
                    positions = self._groups[gi]
                    try:
                        if skip is not None and skip(gi):
                            out = None
                        else:
                            with telemetry.gspan(gi, "deliver",
                                                 rows=len(positions)):
                                out = eval_fn(positions, self.view[positions])
                    except BaseException as e:  # noqa: BLE001 - relayed
                        with cond:
                            errors.append(e)
                            cond.notify_all()
                        return
                    if self._window is not None:
                        self._window.release(gi)
                    with cond:
                        results[gi] = out
                        cond.notify_all()

        threads = [threading.Thread(target=worker, args=(w, grp),
                                    name=f"scan-shard-{w}", daemon=True)
                   for w, grp in enumerate(shard_groups(n, shards))]
        for t in threads:
            t.start()
        try:
            for gi in range(n):
                with cond:
                    cond.wait_for(lambda: gi in results or errors)
                    if errors:
                        raise errors[0]
                    out = results.pop(gi)
                    emitted[0] = gi + 1
                    cond.notify_all()
                yield gi, self._groups[gi], out
        finally:
            stop.set()
            with cond:
                cond.notify_all()
            for t in threads:
                t.join()
            self.close()

    # ----------------------------------------------------------- loader mode
    @classmethod
    def for_units(cls, view, tensors: Sequence[str],
                  units: Sequence[Sequence[int]], *, prefetch_units: int,
                  owner: object = None,
                  on_fetched: Optional[Callable[[int], None]] = None
                  ) -> "ScanPipeline":
        """Pipeline over the loader's fetch units (``units[i]`` = view
        positions of unit ``i``, in plan order)."""
        pipe = cls(view, tensors, owner=owner, on_fetched=on_fetched)
        pipe._horizon = max(0, int(prefetch_units))
        if not pipe.active or not pipe.names or not units:
            return pipe
        bound = [view._base_tensor(n) for n in pipe.names]
        ord_cols = [t.encoder.ords_of(view.indices) for t in bound]
        seen: set = set()
        plan: List[List[Tuple[str, int]]] = []
        for unit in units:
            step: List[Tuple[str, int]] = []
            for t, ords in zip(bound, ord_cols):
                for p in unit:
                    o = int(ords[p])
                    key, est = _chunk_key_est(t, o)
                    if key is not None and key not in seen:
                        seen.add(key)
                        step.append((key, est))
            plan.append(step)
        pipe._window = _PrefetchWindow(pipe.engine, plan, pipe.owner,
                                       on_fetched)
        return pipe

    def on_unit_start(self, unit_index: int) -> None:
        """A worker began unit ``unit_index``: keep the next
        ``prefetch_units`` units' chunks in flight (cross-unit: the
        window pointer runs ahead of the worker pool)."""
        if self._window is not None:
            self._window.top_up(unit_index + self._horizon)

    def on_unit_done(self, unit_index: int) -> None:
        """Unit consumed: return its chunk bytes to the window budget and
        immediately extend the horizon with the freed headroom."""
        if self._window is not None:
            self._window.release(unit_index)
            self._window.top_up(unit_index + self._horizon)

    @property
    def prefetch_failures(self) -> int:
        """Prefetches that failed permanently (consumers fell back to
        direct fetches); 0 when prefetch is inactive."""
        return self._window.failed if self._window is not None else 0

    # -------------------------------------------------------------- teardown
    def close(self) -> int:
        """Cancel this pipeline's queued-but-not-started prefetches
        (running fetches complete and park for other consumers)."""
        if self._window is not None:
            return self._window.cancel()
        return 0


def _chunk_key_est(tensor, chunk_ord: int) -> Tuple[Optional[str], int]:
    """(storage key, estimated bytes) of one chunk; (None, 0) for the open
    chunk (never prefetched: its bytes live in the builder)."""
    name = tensor.encoder.name_of(chunk_ord)
    if tensor._builder is not None and name == tensor._open_name:
        return None, 0
    st = tensor.stats.get(name)
    est = st.nbytes if st is not None and st.nbytes \
        else tensor.meta.max_chunk_size
    return tensor._chunk_key(name), int(est)


# ------------------------------------------------------------ schedule sizing
def derive_schedule_params(engine: "fetchlib.FetchEngine",
                           cost_model: CostModel, sample_bytes: int,
                           memory_budget_bytes: int) -> Tuple[int, int]:
    """(unit_size, prefetch_units) from the engine's latency/bandwidth
    estimates (provider-seeded or EWMA-learned) + the cost model's
    observed per-unit decode times — the adaptive replacement for the old
    fixed ``unit_size=16`` / ``prefetch_units=8`` defaults."""
    est = engine.est
    unit_size = cost_model.derive_unit_size(est.latency_s, est.bandwidth_bps,
                                            sample_bytes)
    prefetch_units = cost_model.derive_prefetch_units(
        est.latency_s, est.bandwidth_bps, unit_size * max(sample_bytes, 1),
        memory_budget_bytes)
    return unit_size, prefetch_units
