"""Smart scheduler + memory estimator for the streaming loader (§4.5).

Two of the paper's three loader properties live here:

* *Smart Scheduler* — "dynamically differentiating between CPU-intensive jobs
  prioritization over less-intensive": pending fetch/decode jobs are ordered by
  (when the consumer will need them, then longest-estimated-CPU first) so long
  decode poles start early and never stall emission.  Job cost estimates are
  EWMA-updated from observed fetch/decode times, so the schedule adapts to the
  actual storage + codec behavior.

* *Efficient Resource Allocation* — "predicting memory consumption to avoid
  breaking the training process": a byte-budgeted gate sized from an EWMA of
  decoded sample sizes blocks fetch workers before RAM would overfill.
"""

from __future__ import annotations

import heapq
import math
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple


class MemoryBudget:
    """Blocking byte budget for decoded-but-unconsumed samples."""

    def __init__(self, max_bytes: int) -> None:
        self.max_bytes = int(max_bytes)
        self._used = 0
        self._cv = threading.Condition()
        self.peak = 0
        self.block_events = 0

    def acquire(self, nbytes: int, timeout: Optional[float] = None) -> bool:
        nbytes = min(int(nbytes), self.max_bytes)  # single huge item still admits
        with self._cv:
            if self._used + nbytes > self.max_bytes:
                self.block_events += 1
            ok = self._cv.wait_for(
                lambda: self._used + nbytes <= self.max_bytes, timeout=timeout)
            if not ok:
                return False
            self._used += nbytes
            self.peak = max(self.peak, self._used)
            return True

    def release(self, nbytes: int) -> None:
        with self._cv:
            self._used = max(0, self._used - min(int(nbytes), self.max_bytes))
            self._cv.notify_all()

    @property
    def used(self) -> int:
        with self._cv:
            return self._used


class CostModel:
    """EWMA per-class cost estimates (seconds) for io and cpu phases.

    Also carries free-form event counters (``note``) so upstream layers can
    record work that was *avoided* — e.g. chunks the TQL scan planner pruned
    — next to the costs of work actually done.
    """

    def __init__(self, alpha: float = 0.2) -> None:
        self.alpha = alpha
        self._io: Dict[str, float] = {}
        self._cpu: Dict[str, float] = {}
        self.counters: Dict[str, int] = {}
        self._lock = threading.Lock()

    def note(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + int(n)

    def observe(self, klass: str, io_s: float, cpu_s: float,
                clean: bool = True) -> None:
        """Fold one timing into the EWMA.  ``clean=False`` marks a timing
        polluted by injected faults / retries / hedges: it is counted
        (``tainted_<klass>``) but NEVER folded, so one straggler cannot
        distort the estimates that size fetch units and prefetch depth."""
        if not clean:
            self.note(f"tainted_{klass}")
            return
        with self._lock:
            for table, v in ((self._io, io_s), (self._cpu, cpu_s)):
                old = table.get(klass)
                table[klass] = v if old is None else (1 - self.alpha) * old + self.alpha * v

    def estimate(self, klass: str) -> Tuple[float, float]:
        with self._lock:
            return self._io.get(klass, 1e-3), self._cpu.get(klass, 1e-4)

    def has_estimate(self, klass: str) -> bool:
        with self._lock:
            return klass in self._io or klass in self._cpu

    # ------------------------------------------------- adaptive scan sizing
    #: bounds for derived fetch-unit sizes / prefetch depths (samples, units)
    UNIT_SIZE_BOUNDS = (8, 256)
    PREFETCH_UNIT_BOUNDS = (2, 32)

    def derive_unit_size(self, latency_s: float, bandwidth_bps: float,
                         sample_bytes: int) -> int:
        """Fetch-unit size (samples) from the storage cost model.

        A unit's useful payload should at least match the provider's
        latency-bandwidth product (the bytes one round-trip could have
        carried): smaller units pay proportionally more request overhead
        per sample, larger ones only add buffering.  Clamped to
        :data:`UNIT_SIZE_BOUNDS`.
        """
        target_bytes = max(1.0, latency_s * bandwidth_bps)
        lo, hi = self.UNIT_SIZE_BOUNDS
        return int(min(hi, max(lo, round(target_bytes / max(sample_bytes, 1)))))

    def derive_prefetch_units(self, latency_s: float, bandwidth_bps: float,
                              unit_bytes: int,
                              memory_budget_bytes: Optional[int] = None
                              ) -> int:
        """Prefetch depth (units in flight) from the cost model + EWMA.

        Classic pipeline sizing: depth ≈ unit fetch time over unit
        consume time, so the consumer never drains the window faster than
        fetches refill it.  Fetch time comes from the latency/bandwidth
        model; consume time from the observed ``"unit"`` CPU EWMA once
        iterations have fed it (a conservative prior before that).
        Optionally bounded so the whole window fits in half the loader's
        memory budget.  Clamped to :data:`PREFETCH_UNIT_BOUNDS`.
        """
        fetch_s = latency_s + unit_bytes / max(bandwidth_bps, 1.0)
        _io, cpu_s = self.estimate("unit")
        if not self.has_estimate("unit"):
            cpu_s = 1e-2  # prior: ~10ms of decode+transform per unit
        depth = int(math.ceil(fetch_s / max(cpu_s, 1e-4))) + 1
        lo, hi = self.PREFETCH_UNIT_BOUNDS
        if memory_budget_bytes:
            hi = max(lo, min(hi, memory_budget_bytes // (2 * max(unit_bytes, 1))))
        return int(min(hi, max(lo, depth)))


@dataclass(order=True)
class _Job:
    priority: Tuple[float, float]
    seq: int
    payload: Any = field(compare=False)


class SmartScheduler:
    """Priority queue of fetch units consumed by the loader's worker pool.

    Priority = (needed_at, -cpu_estimate): earliest-needed first; among jobs
    needed at the same time, the CPU-heaviest first (§4.5).
    """

    def __init__(self, cost_model: Optional[CostModel] = None) -> None:
        self.costs = cost_model or CostModel()
        self._heap: list = []
        self._cv = threading.Condition()
        self._seq = 0
        self._closed = False

    def submit(self, payload: Any, needed_at: float, klass: str = "default") -> None:
        _io, cpu = self.costs.estimate(klass)
        with self._cv:
            self._seq += 1
            heapq.heappush(self._heap, _Job((needed_at, -cpu), self._seq, payload))
            self._cv.notify()

    def take(self, timeout: Optional[float] = None) -> Optional[Any]:
        with self._cv:
            ok = self._cv.wait_for(lambda: self._heap or self._closed, timeout=timeout)
            if not ok or (not self._heap and self._closed):
                return None
            if not self._heap:
                return None
            return heapq.heappop(self._heap).payload

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def __len__(self) -> int:
        with self._cv:
            return len(self._heap)
