"""Smart scheduler + memory estimator for the streaming loader (§4.5).

Two of the paper's three loader properties live here:

* *Smart Scheduler* — "dynamically differentiating between CPU-intensive jobs
  prioritization over less-intensive": pending fetch/decode jobs are ordered by
  (when the consumer will need them, then longest-estimated-CPU first) so long
  decode poles start early and never stall emission.  Job cost estimates are
  EWMA-updated from observed fetch/decode times, so the schedule adapts to the
  actual storage + codec behavior.

* *Efficient Resource Allocation* — "predicting memory consumption to avoid
  breaking the training process": a byte-budgeted gate sized from an EWMA of
  decoded sample sizes blocks fetch workers before RAM would overfill.
"""

from __future__ import annotations

import heapq
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple


class MemoryBudget:
    """Blocking byte budget for decoded-but-unconsumed samples."""

    def __init__(self, max_bytes: int) -> None:
        self.max_bytes = int(max_bytes)
        self._used = 0
        self._cv = threading.Condition()
        self.peak = 0
        self.block_events = 0

    def acquire(self, nbytes: int, timeout: Optional[float] = None) -> bool:
        nbytes = min(int(nbytes), self.max_bytes)  # single huge item still admits
        with self._cv:
            if self._used + nbytes > self.max_bytes:
                self.block_events += 1
            ok = self._cv.wait_for(
                lambda: self._used + nbytes <= self.max_bytes, timeout=timeout)
            if not ok:
                return False
            self._used += nbytes
            self.peak = max(self.peak, self._used)
            return True

    def release(self, nbytes: int) -> None:
        with self._cv:
            self._used = max(0, self._used - min(int(nbytes), self.max_bytes))
            self._cv.notify_all()

    @property
    def used(self) -> int:
        with self._cv:
            return self._used


class CostModel:
    """EWMA per-class cost estimates (seconds) for io and cpu phases.

    Also carries free-form event counters (``note``) so upstream layers can
    record work that was *avoided* — e.g. chunks the TQL scan planner pruned
    — next to the costs of work actually done.
    """

    def __init__(self, alpha: float = 0.2) -> None:
        self.alpha = alpha
        self._io: Dict[str, float] = {}
        self._cpu: Dict[str, float] = {}
        self.counters: Dict[str, int] = {}
        self._lock = threading.Lock()

    def note(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + int(n)

    def observe(self, klass: str, io_s: float, cpu_s: float) -> None:
        with self._lock:
            for table, v in ((self._io, io_s), (self._cpu, cpu_s)):
                old = table.get(klass)
                table[klass] = v if old is None else (1 - self.alpha) * old + self.alpha * v

    def estimate(self, klass: str) -> Tuple[float, float]:
        with self._lock:
            return self._io.get(klass, 1e-3), self._cpu.get(klass, 1e-4)


@dataclass(order=True)
class _Job:
    priority: Tuple[float, float]
    seq: int
    payload: Any = field(compare=False)


class SmartScheduler:
    """Priority queue of fetch units consumed by the loader's worker pool.

    Priority = (needed_at, -cpu_estimate): earliest-needed first; among jobs
    needed at the same time, the CPU-heaviest first (§4.5).
    """

    def __init__(self, cost_model: Optional[CostModel] = None) -> None:
        self.costs = cost_model or CostModel()
        self._heap: list = []
        self._cv = threading.Condition()
        self._seq = 0
        self._closed = False

    def submit(self, payload: Any, needed_at: float, klass: str = "default") -> None:
        _io, cpu = self.costs.estimate(klass)
        with self._cv:
            self._seq += 1
            heapq.heappush(self._heap, _Job((needed_at, -cpu), self._seq, payload))
            self._cv.notify()

    def take(self, timeout: Optional[float] = None) -> Optional[Any]:
        with self._cv:
            ok = self._cv.wait_for(lambda: self._heap or self._closed, timeout=timeout)
            if not ok or (not self._heap and self._closed):
                return None
            if not self._heap:
                return None
            return heapq.heappop(self._heap).payload

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def __len__(self) -> int:
        with self._cv:
            return len(self._heap)
