"""Sharded query serving: admission control, multi-tenant fairness, and a
versioned result/plan cache over one shared :class:`~.fetch.FetchEngine`.

:class:`QueryService` is the concurrent front door for TQL: N clients
submit queries against one dataset and one fetch engine, and the service
keeps them from trampling each other without changing any result bytes.

Admission / fairness contract
-----------------------------
* At most ``max_concurrent`` queries execute at once; excess callers
  block on the admission semaphore.  The whole handling of a query runs
  under a ``serve.admit`` span; time spent blocked on admission is
  measured separately by a ``serve.queue`` span (and a
  ``serve.queue_wait_s`` histogram), so a trace distinguishes "slow
  query" from "queued behind other tenants".
* Each query is tagged with a ``tenant``.  Tenants registered via
  :meth:`QueryService.register_tenant` get a byte budget on the engine's
  staging buffer; the engine schedules tenant prefetches with
  deficit-round-robin (see ``fetch.FetchEngine.register_tenant``), so one
  tenant's scan cannot monopolise staging memory or the prefetch queue.
  Per-tenant throttle/stall counters surface in :meth:`stats`.
* When ``shards`` > 1, WHERE and top-k scans run shard-parallel on the
  executor (``Executor(shards=...)``) — results stay byte-identical to
  the serial scan (see the executor docstring for the parity argument).

Cache-key contract
------------------
Plans and small results are cached under the key::

    (version token, node token, repr(parse(text)), seed, engine, use_stats)

* **version token** — ``(manifest.generation, newest segment key)`` when
  a manifest is published; otherwise the head commit node id.  Every
  commit publishes a new segment at ``segments[0]`` (or reopens a fresh
  head node), so *any* commit naturally rolls the key: no explicit
  invalidation, stale entries simply stop being reachable and age out of
  the LRU.
* **node token** — the resolved ``VERSION`` ref, else ``"HEAD"``.
* **normalized query** — ``repr(parse(text))``: whitespace, keyword case
  and comment differences normalise away; two spellings of the same
  query share one entry.  ``seed`` is the executor's deterministic
  sampling seed derived from the same normal form, so ``SAMPLE BY``
  results are reproducible and therefore cacheable.
* Queries against a **dirty head** (uncommitted changes, no pinned
  ``VERSION``) are never cached — correctness first.

A cache hit reconstructs the result view from stored indices with zero
planner work and zero storage requests (asserted by
``benchmarks/bench_serving.py`` via the ``tql.plans`` counter and
provider request deltas).  Identical concurrent misses are collapsed by
single-flight: one leader executes, followers wait and serve the freshly
cached result, so an N-client storm of one query costs ~one execution.
Oversized results only cache their :class:`~.tql.planner.ScanPlan`
(``serve.plan_cache`` counters), which still removes replanning cost.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from . import telemetry
from .fetch import engine_for
from .views import DatasetView

__all__ = ["QueryService", "CachedResult"]


class CachedResult:
    """Frozen materialisation of a small query result (row indices plus
    any SELECT-derived columns); enough to rebuild the result view
    without touching the planner, the executor, or storage."""

    __slots__ = ("indices", "node_id", "tensors", "derived",
                 "scan_report", "topk_report", "nbytes")

    def __init__(self, view: DatasetView) -> None:
        self.indices = np.array(view.indices, dtype=np.int64, copy=True)
        self.node_id = view.node_id
        tn = view._tensor_names
        self.tensors = list(tn) if tn is not None else None
        self.derived = {k: list(v) for k, v in view.derived.items()}
        self.scan_report = dict(view.scan_plan) if view.scan_plan else None
        self.topk_report = dict(view.topk_plan) if view.topk_plan else None
        self.nbytes = int(self.indices.nbytes) + _derived_nbytes(self.derived)

    def rebuild(self, dataset) -> DatasetView:
        v = DatasetView(dataset, self.indices.copy(), self.node_id,
                        tensors=self.tensors,
                        derived={k: list(vs)
                                 for k, vs in self.derived.items()})
        if self.scan_report is not None:
            v.scan_plan = dict(self.scan_report)
        if self.topk_report is not None:
            v.topk_plan = dict(self.topk_report)
        return v


def _derived_nbytes(derived: Dict[str, List[Any]]) -> int:
    total = 0
    for vals in derived.values():
        for v in vals:
            if isinstance(v, np.ndarray):
                total += int(v.nbytes)
            elif isinstance(v, (bytes, str)):
                total += len(v)
            else:
                total += 16
    return total


class QueryService:
    """Concurrent TQL query front end over one dataset + fetch engine.

    See the module docstring for the admission / fairness / cache-key
    contract.  Thread-safe; one instance serves many client threads.
    """

    #: per-entry byte ceiling for caching a materialised result; larger
    #: results cache only their scan plan
    RESULT_BYTES_MAX = 4 << 20

    def __init__(self, dataset, *, max_concurrent: int = 8,
                 shards: Optional[int] = None,
                 cache_entries: int = 256,
                 result_bytes_max: Optional[int] = None) -> None:
        if max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1")
        self.dataset = dataset
        self.engine = engine_for(dataset.storage)
        self.shards = shards
        self.cache_entries = int(cache_entries)
        self.result_bytes_max = (self.RESULT_BYTES_MAX
                                 if result_bytes_max is None
                                 else int(result_bytes_max))
        self._admit = threading.Semaphore(max_concurrent)
        self._lock = threading.Lock()
        # LRU caches: cache key -> CachedResult / ScanPlan
        self._results: "OrderedDict[Tuple, CachedResult]" = OrderedDict()
        self._plans: "OrderedDict[Tuple, Any]" = OrderedDict()
        # single-flight: cache key -> Event set when the leader finishes
        self._flights: Dict[Tuple, threading.Event] = {}
        self._counts = {"queries": 0, "cache_hits": 0, "cache_misses": 0,
                        "flight_waits": 0, "plan_hits": 0, "queue_waits": 0,
                        "uncacheable": 0}

    # ------------------------------------------------------------ tenants
    def register_tenant(self, tenant: str,
                        byte_budget: Optional[int] = None) -> None:
        """Give ``tenant`` a staging-byte budget on the shared engine."""
        self.engine.register_tenant(tenant, byte_budget)

    # ------------------------------------------------------------ serving
    def query(self, text: str, *, tenant: str = "default",
              engine: str = "auto", use_stats: bool = True,
              stream: Optional[bool] = None) -> DatasetView:
        """Run ``text`` on behalf of ``tenant`` and return the result view
        (byte-identical to ``dataset.query(text)``)."""
        from .tql.parser import parse

        reg = telemetry.registry()
        with telemetry.span("serve.admit", tenant=tenant) as sp:
            with self._lock:
                self._counts["queries"] += 1
            reg.counter(f"serve.tenant.{tenant}.queries").inc()
            q = parse(text)
            norm = repr(q)
            key = self._cache_key(q, norm, engine, use_stats)
            if key is None:
                with self._lock:
                    self._counts["uncacheable"] += 1
                sp.set(cache="uncacheable")
                return self._execute(q, key, tenant, engine, use_stats,
                                     stream)
            hit = self._result_get(key)
            if hit is not None:
                self._count_hit(reg, tenant, sp)
                return hit.rebuild(self.dataset)
            # single-flight: collapse identical concurrent misses
            leader, ev = self._flight_join(key)
            if not leader:
                with self._lock:
                    self._counts["flight_waits"] += 1
                with telemetry.span("serve.flight_wait", tenant=tenant):
                    ev.wait()
                hit = self._result_get(key)
                if hit is not None:
                    self._count_hit(reg, tenant, sp)
                    return hit.rebuild(self.dataset)
                # leader failed or result was too big to cache: run it
                return self._execute(q, key, tenant, engine, use_stats,
                                     stream)
            with self._lock:
                self._counts["cache_misses"] += 1
            reg.counter("serve.cache.misses").inc()
            sp.set(cache="miss")
            try:
                out = self._execute(q, key, tenant, engine, use_stats,
                                    stream)
                ent = CachedResult(out)
                if ent.nbytes <= self.result_bytes_max:
                    self._lru_put(self._results, key, ent)
                return out
            finally:
                self._flight_done(key, ev)

    # ------------------------------------------------------------ internals
    def _execute(self, q, key, tenant: str, engine: str, use_stats: bool,
                 stream: Optional[bool]) -> DatasetView:
        from .tql.executor import Executor

        reg = telemetry.registry()
        if not self._admit.acquire(blocking=False):
            with self._lock:
                self._counts["queue_waits"] += 1
            reg.counter(f"serve.tenant.{tenant}.queue_waits").inc()
            with telemetry.span("serve.queue", tenant=tenant) as qs:
                t0 = time.perf_counter()
                self._admit.acquire()
                wait = time.perf_counter() - t0
                qs.set(wait_s=wait)
            reg.histogram("serve.queue_wait_s").observe(wait)
        try:
            node_id = (self.dataset.vc.resolve_ref(q.version)
                       if q.version else None)
            base = DatasetView.full(self.dataset, node_id=node_id)
            aliases = {it.alias for it in q.items if it.alias}
            missing = [t for t in q.referenced_tensors()
                       if t not in base.tensor_names and t not in aliases]
            if missing:
                raise KeyError(
                    f"query references unknown tensors: {missing}")
            hint = self._plan_get(key) if use_stats else None
            if hint is not None:
                with self._lock:
                    self._counts["plan_hits"] += 1
                reg.counter("serve.plan_cache.hits").inc()
            ex = Executor(q, engine=engine, use_stats=use_stats,
                          stream=stream, shards=self.shards, tenant=tenant,
                          scan_plan_hint=hint)
            out = ex.run(base)
            if (key is not None and hint is None
                    and ex.scan_plan is not None):
                self._lru_put(self._plans, key, ex.scan_plan)
            return out
        finally:
            self._admit.release()

    def _cache_key(self, q, norm: str, engine: str,
                   use_stats: bool) -> Optional[Tuple]:
        """Versioned cache key, or None when the query is uncacheable
        (dirty head with no pinned VERSION)."""
        from .tql.executor import _query_seed

        vc = self.dataset.vc
        if q.version:
            node = vc.resolve_ref(q.version)
        elif vc.has_uncommitted_changes():
            return None
        else:
            node = "HEAD"
        m = self.dataset.manifest
        if m is not None and m.segments:
            version_token: Tuple = (int(m.generation), m.segments[0])
        else:
            version_token = ("node", vc.current.id)
        return (version_token, node, norm, _query_seed(norm),
                engine, bool(use_stats))

    def _count_hit(self, reg, tenant: str, sp) -> None:
        with self._lock:
            self._counts["cache_hits"] += 1
        reg.counter("serve.cache.hits").inc()
        reg.counter(f"serve.tenant.{tenant}.cache_hits").inc()
        sp.set(cache="hit")

    def _result_get(self, key) -> Optional[CachedResult]:
        with self._lock:
            ent = self._results.get(key)
            if ent is not None:
                self._results.move_to_end(key)
            return ent

    def _plan_get(self, key) -> Optional[Any]:
        if key is None:
            return None
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self._plans.move_to_end(key)
            return plan

    def _lru_put(self, cache: OrderedDict, key, value) -> None:
        with self._lock:
            cache[key] = value
            cache.move_to_end(key)
            while len(cache) > self.cache_entries:
                cache.popitem(last=False)

    def _flight_join(self, key) -> Tuple[bool, threading.Event]:
        with self._lock:
            ev = self._flights.get(key)
            if ev is not None:
                return False, ev
            ev = threading.Event()
            self._flights[key] = ev
            return True, ev

    def _flight_done(self, key, ev: threading.Event) -> None:
        with self._lock:
            self._flights.pop(key, None)
        ev.set()

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, Any]:
        """Service counters plus the per-tenant engine fairness split."""
        with self._lock:
            out: Dict[str, Any] = dict(self._counts)
            out["result_entries"] = len(self._results)
            out["plan_entries"] = len(self._plans)
        out["tenants"] = self.engine.tenants_snapshot()
        return out

    def clear_cache(self) -> None:
        with self._lock:
            self._results.clear()
            self._plans.clear()
