"""Storage providers for the Deep Lake format.

The paper (§3.6) requires pluggable storage: object stores (S3/GCS), POSIX file
systems, and in-memory stores, composable behind an LRU cache chain.  In this
container there is no network, so remote object storage is modeled by
:class:`SimulatedS3Provider`, which wraps any base provider with a calibrated
latency + bandwidth cost model (per-request latency, per-byte transfer time,
bounded connection concurrency).  Benchmarks use it to reproduce the paper's
Fig 5d / Fig 6 remote-vs-local experiments.

All providers speak the same byte-level protocol:

    get(key) -> bytes                  full object read
    get_range(key, start, end)         ranged read (the format's streaming
                                       primitive; §3.5 "range-based requests")
    get_ranges(key, ranges)            batched ranged read: one payload per
                                       requested range, issued as few physical
                                       requests as the provider can manage
    get_many(keys) -> {key: bytes}     batched full reads
    put(key, data)                     atomic object write
    cas(key, data, expected) -> bool   compare-and-swap (optimistic concurrency)
    delete(key), exists(key), list_keys(prefix), num_bytes(key)

``cas`` is the primitive behind the dataset manifest pointer (§4.1 ACID
ingestion): the write succeeds only when the object's current bytes equal
``expected`` (``None`` = the key must not exist yet), so concurrent
committers race on the pointer and exactly one wins — losers reload and
retry or surface a conflict.

Keys are '/'-separated strings (object-store semantics, no directories).

Failure model
-------------

Real object stores time out, throttle (503 SlowDown), straggle, and tear
reads; the contract below is what every consumer of this module may assume:

* **Error taxonomy.**  :class:`StorageError` (a ``KeyError``) means the key
  is missing or the operation permanently failed.  :class:`TransientStorageError`
  — deliberately *not* a ``StorageError`` subclass — means the request failed
  but a retry may succeed (timeout, 5xx, short read); ``except StorageError``
  handlers therefore can never mistake a throttled request for a missing key.
  :class:`RetryExhausted` *is* a ``StorageError``: it is raised once the retry
  budget is spent, at which point the failure is permanent for the caller.
* **Retry semantics.**  Data-plane reads routed through
  :class:`~repro.core.fetch.FetchEngine` retry transients with capped
  exponential backoff + jitter (see ``RetryPolicy``); control-plane reads
  (manifest pointer, version-control state) go through
  :func:`retry_transient` / :meth:`StorageProvider.get_or_none`.  Prefetches
  additionally *hedge*: a request straggling past a multiple of the latency
  EWMA gets a duplicate request, first responder wins.
* **Write semantics.**  ``put`` is *not* assumed atomic on the simulated
  object store: an upload may fail with a 5xx (nothing durable) or **tear**
  — the call reports success but only a prefix of the object landed
  (interrupted multipart upload, lost trailing packets).  Durable writers
  therefore go through :meth:`StorageProvider.put_verified`, which re-reads
  the object's length after the upload (modeling the ETag/Content-MD5 check
  that rides a real PUT response), raises :class:`TornWriteError` on a
  mismatch, and retries transients via :func:`retry_transient`.  Providers
  whose ``put`` is genuinely atomic (memory, POSIX tmp+rename) inherit a
  ``put_verified`` that only adds the transient retry.  ``cas`` may raise a
  transient 5xx *before* applying (the conditional put never became
  durable); callers wrap it in :func:`retry_transient` and treat a
  ``False`` return as contention, never as a fault.
* **Fault injection.**  :class:`SimulatedS3Provider` takes an optional
  seeded :class:`FaultPolicy` that injects timeouts / 5xx transients /
  stragglers / torn reads on data-plane reads (``get``/``get_range``/
  ``get_ranges``), and — via the write-plane rates — 5xx / torn uploads on
  ``put`` plus 5xx on ``cas``.  Metadata probes (``exists``/``num_bytes``/
  ``list_keys``) are never faulted.  Injected faults charge realistic
  latency (wasted upload bytes are tallied in
  ``stats["wasted_upload_bytes"]``) and are capped per key
  (``max_consecutive_per_key``, write plane capped independently of reads)
  so a bounded retry budget always converges; every fault is counted in
  ``stats["faults_*"]``.
"""

from __future__ import annotations

import hashlib
import os
import random
import threading
import time
from dataclasses import dataclass
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

from . import telemetry

try:  # POSIX-only; LocalProvider.cas falls back to a process lock without it
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX platforms
    fcntl = None  # type: ignore[assignment]

Range = Tuple[int, int]


def coalesce_ranges(ranges: Sequence[Range], gap: int
                    ) -> Tuple[List[Range], List[int]]:
    """Merge byte ranges whose inter-range gap is at most ``gap`` bytes.

    Returns ``(spans, assign)``: ``spans`` is the sorted list of merged
    ``[start, end)`` spans and ``assign[i]`` is the span index serving
    ``ranges[i]``.  Inverted ranges (``end < start``) are treated as
    zero-length at ``start``; overlapping and adjacent ranges always merge.
    The caller picks ``gap`` from its cost model: a gap is worth downloading
    when ``gap_bytes / bandwidth < per_request_latency``.
    """
    norm = [(int(s), max(int(s), int(e))) for s, e in ranges]
    order = sorted(range(len(norm)), key=lambda i: norm[i])
    spans: List[List[int]] = []
    assign = [0] * len(norm)
    for i in order:
        s, e = norm[i]
        if spans and s - spans[-1][1] <= gap:
            spans[-1][1] = max(spans[-1][1], e)
        else:
            spans.append([s, e])
        assign[i] = len(spans) - 1
    return [(s, e) for s, e in spans], assign


def slice_spans(ranges: Sequence[Range], spans: Sequence[Range],
                assign: Sequence[int],
                payloads: Sequence[bytes]) -> List[bytes]:
    """Reassemble per-range payloads from fetched coalesced spans.

    Inverse of :func:`coalesce_ranges`: ``payloads[j]`` holds the bytes of
    ``spans[j]`` (possibly tail-clamped by the object length); the result
    is byte-identical to fetching each of ``ranges`` individually.
    """
    out: List[bytes] = []
    for i, (s, e) in enumerate(ranges):
        span_start = spans[assign[i]][0]
        data = payloads[assign[i]]
        out.append(data[s - span_start: max(s, e) - span_start])
    return out


class StorageError(KeyError):
    """Raised when a key is missing or a provider operation fails."""


class TransientStorageError(Exception):
    """A request failed in a way a retry may fix (timeout, 5xx, short read).

    Deliberately NOT a :class:`StorageError` subclass: ``except
    StorageError`` handlers (``get_or_none`` and friends) must never treat
    a throttled or timed-out request as a missing key.
    """


class StorageTimeout(TransientStorageError):
    """The request exceeded its deadline (connect or read timeout)."""


class TornReadError(TransientStorageError):
    """The payload came back shorter than the object/range length claimed
    (interrupted transfer); detected client-side, always retriable."""


class TornWriteError(TransientStorageError):
    """An upload "succeeded" but the durable object is shorter than what was
    sent (interrupted multipart upload).  Detected by the post-put
    verification in :meth:`StorageProvider.put_verified`; always retriable —
    re-putting the same bytes is idempotent."""


class RetryExhausted(StorageError):
    """Transient faults persisted past the retry budget — permanent for the
    caller.  A :class:`StorageError` on purpose: exhaustion is surfaced, not
    retried again."""


#: module-level jitter source for backoff sleeps; retry *correctness* never
#: depends on it, so a shared unseeded stream is fine
_backoff_rng = random.Random(0x5EED)


def retry_transient(fn: Callable[[], "bytes"], *, attempts: int = 4,
                    base_s: float = 0.01, cap_s: float = 0.25,
                    jitter: float = 0.5, what: str = ""):
    """Call ``fn()``, retrying :class:`TransientStorageError` with capped
    exponential backoff + jitter.  Permanent errors propagate untouched;
    exhaustion raises :class:`RetryExhausted` chained on the last transient.

    This is the control-plane retry helper (manifest pointer, VC state);
    the data plane retries inside :class:`~repro.core.fetch.FetchEngine`
    where attempts also feed the engine's stats counters.
    """
    delay = base_s
    last: Optional[TransientStorageError] = None
    for i in range(max(1, attempts)):
        try:
            return fn()
        except TransientStorageError as e:
            last = e
            if i + 1 >= max(1, attempts):
                break
            time.sleep(delay * (1.0 + jitter * _backoff_rng.random()))
            delay = min(delay * 2.0, cap_s)
    raise RetryExhausted(
        f"storage retries exhausted after {max(1, attempts)} attempts"
        f"{': ' + what if what else ''}") from last


class StorageProvider:
    """Abstract provider. Subclasses implement the five byte-level primitives."""

    #: human-readable provider kind, used by the scheduler's cost model
    kind: str = "abstract"

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def get_range(self, key: str, start: int, end: int) -> bytes:
        """Return ``obj[start:end]``.

        Contract (all providers, asserted by tests/test_storage_range.py):
        ``end`` is exclusive and may exceed the object length (the read
        clamps to the tail); ``start`` at or past the object length, or
        ``end <= start``, yields ``b""`` — zero-length reads are legal and
        must not raise on an existing key.
        """
        raise NotImplementedError

    def get_ranges(self, key: str, ranges: Sequence[Range]) -> List[bytes]:
        """Batched :meth:`get_range`: one payload per requested range.

        Contract: payload ``i`` is byte-identical to
        ``get_range(key, *ranges[i])``; a missing key raises
        :class:`StorageError` whenever ``ranges`` is non-empty (even if
        every range is zero-length); an empty ``ranges`` returns ``[]``
        without touching storage.  Providers override the default per-range
        loop to batch the physical I/O (single open + ordered seeks on
        POSIX, coalesced ranged requests on object storage).
        """
        return [self.get_range(key, s, e) for s, e in ranges]

    def get_many(self, keys: Sequence[str]) -> Dict[str, bytes]:
        """Batched :meth:`get`: ``{key: bytes}`` with duplicates deduped.

        Any missing key raises :class:`StorageError`.
        """
        return {k: self.get(k) for k in keys}

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def put_verified(self, key: str, data: bytes) -> None:
        """Durable upload: ``put`` + integrity verification + transient retry.

        Every write whose loss or truncation would corrupt committed state
        (chunks, manifest segments, version-control state) goes through this
        instead of raw ``put``.  The default adds only the
        :func:`retry_transient` loop — correct for providers whose ``put``
        is atomic (memory dict swap, POSIX tmp+rename).  Providers that can
        tear an upload override it to verify the durable object (length /
        digest, modeling the ETag check on a real PUT response) and raise
        :class:`TornWriteError` so the retry loop re-puts.
        """
        data = bytes(data)
        retry_transient(lambda: self.put(key, data), what=key)

    def cas(self, key: str, data: bytes, expected: Optional[bytes]) -> bool:
        """Atomic compare-and-swap: write ``data`` only if the object's
        current bytes equal ``expected`` (``None`` = key must not exist).
        Returns True on success, False when the comparison failed — the
        caller then reloads and retries or raises a conflict error.
        """
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def list_keys(self, prefix: str = "") -> List[str]:
        raise NotImplementedError

    def num_bytes(self, key: str) -> int:
        return len(self.get(key))

    def clear(self) -> None:
        for key in list(self.list_keys()):
            self.delete(key)

    # -- convenience -------------------------------------------------------
    def get_or_none(self, key: str) -> Optional[bytes]:
        """``get`` that maps a *missing key* to None.  Transient faults are
        retried, and exhaustion raises — a flaky store must never read as
        an empty one (that is how control-plane state silently vanishes)."""
        try:
            return retry_transient(lambda: self.get(key), what=key)
        except RetryExhausted:
            raise
        except StorageError:
            return None

    def __contains__(self, key: str) -> bool:
        return self.exists(key)


class MemoryProvider(StorageProvider):
    """Dict-backed provider; thread-safe. Used for tests and as cache tier."""

    kind = "memory"

    def __init__(self) -> None:
        self._store: Dict[str, bytes] = {}
        self._lock = threading.RLock()

    def get(self, key: str) -> bytes:
        with self._lock:
            try:
                return self._store[key]
            except KeyError:
                raise StorageError(key) from None

    def get_range(self, key: str, start: int, end: int) -> bytes:
        return self.get(key)[start:end]

    def get_ranges(self, key: str, ranges: Sequence[Range]) -> List[bytes]:
        if not ranges:
            return []
        data = self.get(key)  # one lookup serves every range
        return [data[s:max(s, e)] for s, e in ranges]

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._store[key] = bytes(data)

    def cas(self, key: str, data: bytes, expected: Optional[bytes]) -> bool:
        with self._lock:
            if self._store.get(key) != expected:
                return False
            self._store[key] = bytes(data)
            return True

    def delete(self, key: str) -> None:
        with self._lock:
            self._store.pop(key, None)

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._store

    def list_keys(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(k for k in self._store if k.startswith(prefix))

    def num_bytes(self, key: str) -> int:
        with self._lock:
            try:
                return len(self._store[key])
            except KeyError:
                raise StorageError(key) from None

    def total_bytes(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._store.values())


class LocalProvider(StorageProvider):
    """POSIX filesystem provider. Keys map to paths under ``root``.

    :meth:`cas` serializes committers across *processes* with an
    ``fcntl.flock`` on a per-key sidecar lockfile under ``.cas-locks/``
    (a reserved prefix, hidden from :meth:`list_keys`); flock also contends
    between distinct opens within one process, so in-process threads
    serialize through the same lock.  Platforms without ``fcntl`` fall back
    to a process-local lock (the pre-flock behavior).
    """

    kind = "local"

    #: reserved sidecar directory for cas lockfiles (never listed as keys)
    _LOCK_DIR = ".cas-locks"

    #: fallback when fcntl is unavailable: process-local serialization only
    _cas_fallback_lock = threading.Lock()

    def __init__(self, root: str) -> None:
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    def _path(self, key: str) -> str:
        path = os.path.normpath(os.path.join(self.root, key))
        if not path.startswith(self.root):
            raise StorageError(f"key escapes root: {key}")
        return path

    def get(self, key: str) -> bytes:
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise StorageError(key) from None

    def get_range(self, key: str, start: int, end: int) -> bytes:
        try:
            with open(self._path(key), "rb") as f:
                f.seek(start)
                return f.read(max(0, end - start))
        except FileNotFoundError:
            raise StorageError(key) from None

    def get_ranges(self, key: str, ranges: Sequence[Range]) -> List[bytes]:
        """Single open + seeks in ascending byte order (one disk pass)."""
        if not ranges:
            return []
        try:
            with open(self._path(key), "rb") as f:
                out: List[bytes] = [b""] * len(ranges)
                order = sorted(range(len(ranges)), key=lambda i: ranges[i][0])
                for i in order:
                    s, e = ranges[i]
                    f.seek(s)
                    out[i] = f.read(max(0, e - s))
                return out
        except FileNotFoundError:
            raise StorageError(key) from None

    def put(self, key: str, data: bytes) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)  # atomic on POSIX

    def _lockfile(self, key: str) -> str:
        lock_dir = os.path.join(self.root, self._LOCK_DIR)
        os.makedirs(lock_dir, exist_ok=True)
        digest = hashlib.sha1(key.encode("utf-8")).hexdigest()
        return os.path.join(lock_dir, digest + ".lock")

    def _cas_under_lock(self, key: str, data: bytes,
                        expected: Optional[bytes]) -> bool:
        try:
            with open(self._path(key), "rb") as f:
                current: Optional[bytes] = f.read()
        except FileNotFoundError:
            current = None
        if current != expected:
            return False
        self.put(key, data)
        return True

    def cas(self, key: str, data: bytes, expected: Optional[bytes]) -> bool:
        if fcntl is None:  # pragma: no cover - non-POSIX platforms
            with self._cas_fallback_lock:
                return self._cas_under_lock(key, data, expected)
        with open(self._lockfile(key), "ab") as lf:
            fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
            try:
                return self._cas_under_lock(key, data, expected)
            finally:
                fcntl.flock(lf.fileno(), fcntl.LOCK_UN)

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def exists(self, key: str) -> bool:
        return os.path.isfile(self._path(key))

    def list_keys(self, prefix: str = "") -> List[str]:
        keys = []
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for name in filenames:
                rel = os.path.relpath(os.path.join(dirpath, name), self.root)
                rel = rel.replace(os.sep, "/")
                if rel.startswith(self._LOCK_DIR + "/"):
                    continue  # cas lockfile sidecars are not objects
                if rel.startswith(prefix):
                    keys.append(rel)
        return sorted(keys)

    def num_bytes(self, key: str) -> int:
        try:
            return os.path.getsize(self._path(key))
        except FileNotFoundError:
            raise StorageError(key) from None


@dataclass
class FaultPolicy:
    """Seeded, deterministic fault injection for :class:`SimulatedS3Provider`.

    Each data-plane read (one ``get``/``get_range`` call, or one physical
    span inside ``get_ranges``) draws once from a seeded stream; at most one
    fault is injected per draw, picked by cumulative rate:

    * ``timeout``  — request aborts after ``timeout_factor ×`` latency
      (:class:`StorageTimeout`);
    * ``5xx``      — throttle/SlowDown after one latency round-trip
      (:class:`TransientStorageError`);
    * ``torn``     — transfer truncates; the client detects the short
      payload and raises :class:`TornReadError` after one round-trip;
    * ``straggle`` — the request *succeeds* but is charged
      ``straggle_factor ×`` latency in simulated time and stalls
      ``straggle_sleep_s`` real seconds (drives hedging even at
      ``time_scale=0``).

    The write plane draws from the same stream with its own rates:

    * ``put_error_rate`` — the upload 5xx-fails after charging the bytes;
      nothing becomes durable (:class:`TransientStorageError`);
    * ``put_torn_rate`` — the upload *reports success* but only a prefix of
      the object lands; only post-put verification
      (:meth:`StorageProvider.put_verified`) can catch it;
    * ``cas_error_rate`` — the conditional put 5xx-fails *before* applying
      (nothing durable, retriable); a clean ``cas`` that loses the
      compare is contention, not a fault, and is counted separately in
      ``stats["cas_conflicts"]``.

    Hard faults (timeout/5xx/torn) are capped at ``max_consecutive_per_key``
    in a row for any one key — mirroring real stores, where per-key
    brown-outs are short — so any retry budget of more than
    ``max_consecutive_per_key`` attempts deterministically converges.  Write
    faults keep their own per-key streaks (``"w:"``-prefixed), so a read
    brown-out never masks a write one or vice versa.

    Determinism: one provider, one stream.  A single-threaded op sequence
    replays exactly under the same seed; multi-threaded request order may
    permute which op draws which fault, but results must be byte-identical
    regardless (the chaos bench's parity gate).
    """

    seed: int = 0
    timeout_rate: float = 0.0
    error_rate: float = 0.0      # 5xx / throttle
    straggle_rate: float = 0.0
    torn_rate: float = 0.0
    put_error_rate: float = 0.0  # upload 5xx: nothing durable
    put_torn_rate: float = 0.0   # upload "succeeds", only a prefix lands
    cas_error_rate: float = 0.0  # conditional put 5xx before applying
    timeout_factor: float = 10.0   # sim latency multiple burned by a timeout
    straggle_factor: float = 8.0   # sim latency multiple charged by a straggle
    straggle_sleep_s: float = 0.0  # REAL stall of a straggling request
    max_consecutive_per_key: int = 2

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)
        self._streak: Dict[str, int] = {}
        self._lock = threading.Lock()

    def draw(self, key: str) -> Optional[str]:
        """Fault kind for the next read of ``key`` (None = healthy)."""
        with self._lock:
            u = self._rng.random()
            kind: Optional[str] = None
            edge = self.timeout_rate
            if u < edge:
                kind = "timeout"
            elif u < (edge := edge + self.error_rate):
                kind = "5xx"
            elif u < (edge := edge + self.torn_rate):
                kind = "torn"
            elif u < edge + self.straggle_rate:
                kind = "straggle"
            hard = kind in ("timeout", "5xx", "torn")
            if hard:
                streak = self._streak.get(key, 0)
                if streak >= self.max_consecutive_per_key:
                    kind = None  # liveness cap: this key has suffered enough
                    hard = False
                else:
                    self._streak[key] = streak + 1
            if not hard:
                self._streak.pop(key, None)
            return kind

    def _draw_write(self, streak_key: str,
                    rates: Sequence[Tuple[str, float]]) -> Optional[str]:
        """One seeded draw over the write-plane ``(kind, rate)`` ladder.
        All write faults are hard, so every pick is subject to the per-key
        liveness cap; a clean draw clears the streak."""
        with self._lock:
            u = self._rng.random()
            kind: Optional[str] = None
            edge = 0.0
            for k, r in rates:
                edge += r
                if u < edge:
                    kind = k
                    break
            if kind is not None:
                streak = self._streak.get(streak_key, 0)
                if streak >= self.max_consecutive_per_key:
                    kind = None
                else:
                    self._streak[streak_key] = streak + 1
            if kind is None:
                self._streak.pop(streak_key, None)
            return kind

    def draw_put(self, key: str) -> Optional[str]:
        """Fault kind for the next upload of ``key``: ``"5xx"`` (nothing
        durable), ``"torn"`` (prefix lands, call reports success), or None."""
        return self._draw_write(
            "w:" + key,
            (("5xx", self.put_error_rate), ("torn", self.put_torn_rate)))

    def draw_cas(self, key: str) -> Optional[str]:
        """Fault kind for the next conditional put of ``key``: ``"5xx"``
        (fails before applying) or None."""
        return self._draw_write("w:" + key,
                                (("5xx", self.cas_error_rate),))


class SimulatedS3Provider(StorageProvider):
    """Object-storage cost model over a base provider.

    Models the three effects that matter for the paper's experiments:

    * per-request latency (TTFB): ``latency_s`` seconds per GET/PUT, i.e. why
      iterating many small files is slow (§2.3);
    * bandwidth: ``bandwidth_bps`` bytes/sec per connection for the payload;
    * bounded concurrency: at most ``max_connections`` in-flight requests —
      more threads than connections queue up.

    ``time_scale`` compresses simulated seconds into real sleep so benchmarks
    finish quickly while preserving ratios; accounting (``stats``) always
    records *unscaled* simulated seconds.  With ``time_scale=0`` no real sleep
    happens at all (pure accounting), which is what unit tests use.
    """

    kind = "s3"

    def __init__(
        self,
        base: Optional[StorageProvider] = None,
        *,
        latency_s: float = 0.015,
        bandwidth_bps: float = 95e6,
        max_connections: int = 64,
        time_scale: float = 1.0,
        clock: Optional[Callable[[], float]] = None,
        fault_policy: Optional[FaultPolicy] = None,
    ) -> None:
        self.base = base if base is not None else MemoryProvider()
        self.latency_s = float(latency_s)
        self.bandwidth_bps = float(bandwidth_bps)
        self.time_scale = float(time_scale)
        self.fault_policy = fault_policy
        self._sem = threading.BoundedSemaphore(max_connections)
        self._lock = threading.Lock()
        self._clock = clock or time.monotonic
        self.stats = {
            "requests": 0,            # every charged round-trip (incl. meta)
            "ranged_requests": 0,     # round-trips that carried a byte range
            "coalesced_requests": 0,  # physical spans issued by get_ranges
            "batched_ranges": 0,      # logical ranges served by get_ranges
            "batched_objects": 0,     # whole objects served by get_many
            "meta_requests": 0,       # exists/num_bytes/list_keys round-trips
            "put_requests": 0,        # upload round-trips (incl. faulted)
            "cas_requests": 0,        # conditional-put round-trips (manifest)
            "cas_conflicts": 0,       # clean cas that lost the compare
            "bytes_down": 0,
            "bytes_up": 0,
            "wasted_upload_bytes": 0,  # bytes charged by faulted uploads
            "sim_seconds": 0.0,
            # per-cause partition of sim_seconds (stall attribution):
            # invariant sum(sim_s_*) == sim_seconds.  The read cause comes
            # from the issuing thread's telemetry.io_cause() tag; uploads
            # default to "write", metadata probes to "meta", and injected-
            # fault surcharges (wasted round-trips, straggle overtime) land
            # in "fault" regardless of the ambient cause.
            "sim_s_demand": 0.0,
            "sim_s_prefetch": 0.0,
            "sim_s_retry": 0.0,
            "sim_s_hedge": 0.0,
            "sim_s_fault": 0.0,
            "sim_s_write": 0.0,
            "sim_s_meta": 0.0,
            "faults_injected": 0,     # total injected faults (all kinds)
            "faults_timeout": 0,
            "faults_5xx": 0,
            "faults_straggle": 0,
            "faults_torn": 0,
            "faults_put_5xx": 0,      # upload failed, nothing durable
            "faults_put_torn": 0,     # upload "succeeded", prefix landed
            "faults_cas_5xx": 0,      # conditional put failed before applying
        }

    # -- cost model --------------------------------------------------------
    def _charge(self, nbytes: int, *, upload: bool = False,
                extra_sim: float = 0.0, fault_sim: float = 0.0,
                cause: Optional[str] = None) -> None:
        """Charge one round-trip.  ``extra_sim`` rides the main cause bucket;
        ``fault_sim`` (straggle overtime) is booked to the ``fault`` bucket
        so sum(sim_s_*) stays an exact partition of sim_seconds."""
        sim = self.latency_s + nbytes / self.bandwidth_bps + extra_sim \
            + fault_sim
        if cause is None:
            cause = "write" if upload else telemetry.current_io_cause()
        bucket = "sim_s_" + cause
        with self._lock:
            self.stats["requests"] += 1
            self.stats["bytes_up" if upload else "bytes_down"] += nbytes
            self.stats["sim_seconds"] += sim
            self.stats[bucket] = self.stats.get(bucket, 0.0) + (sim - fault_sim)
            if fault_sim:
                self.stats["sim_s_fault"] += fault_sim
        if self.time_scale > 0:
            time.sleep(sim * self.time_scale)

    def _maybe_fault(self, key: str) -> float:
        """Fault-injection gate ahead of one data-plane read.  Returns
        extra simulated seconds to charge (straggle); raises the typed
        transient on hard faults, after charging the wasted round-trip."""
        fp = self.fault_policy
        if fp is None:
            return 0.0
        kind = fp.draw(key)
        if kind is None:
            return 0.0
        with self._lock:
            self.stats["faults_injected"] += 1
            self.stats["faults_" + kind] += 1
        if kind == "straggle":
            if fp.straggle_sleep_s > 0:
                time.sleep(fp.straggle_sleep_s)
            return self.latency_s * max(0.0, fp.straggle_factor - 1.0)
        # hard fault: the aborted round-trip is still a charged request
        wasted = self.latency_s * (fp.timeout_factor if kind == "timeout"
                                   else 1.0)
        self._charge(0, extra_sim=wasted - self.latency_s, cause="fault")
        if kind == "timeout":
            raise StorageTimeout(f"injected timeout reading {key!r}")
        if kind == "torn":
            raise TornReadError(f"injected short read of {key!r}")
        raise TransientStorageError(f"injected 503 SlowDown for {key!r}")

    def reset_stats(self) -> None:
        with self._lock:
            for k in self.stats:
                self.stats[k] = 0.0 if k.startswith("sim_") else 0

    # -- protocol ----------------------------------------------------------
    def get(self, key: str) -> bytes:
        with self._sem:
            extra = self._maybe_fault(key)
            data = self.base.get(key)
            self._charge(len(data), fault_sim=extra)
            return data

    def get_range(self, key: str, start: int, end: int) -> bytes:
        with self._sem:
            extra = self._maybe_fault(key)
            data = self.base.get_range(key, start, end)
            self._charge(len(data), fault_sim=extra)
            with self._lock:
                self.stats["ranged_requests"] += 1
            return data

    def gap_threshold(self) -> int:
        """Gap (bytes) worth downloading to avoid one extra round-trip:
        ``gap / bandwidth < latency  <=>  gap < latency * bandwidth``."""
        return int(self.latency_s * self.bandwidth_bps)

    def get_ranges(self, key: str, ranges: Sequence[Range]) -> List[bytes]:
        """Coalescing ranged read: requested ranges are merged whenever the
        gap between them costs less than a request round-trip, and ONE
        latency charge is paid per merged span — the batched counterpart of
        the paper's "range-based requests" (§3.5)."""
        if not ranges:
            return []
        spans, assign = coalesce_ranges(ranges, self.gap_threshold())
        payloads: List[bytes] = []
        with self._sem:
            for s, e in spans:
                extra = self._maybe_fault(key)  # per physical span
                data = self.base.get_range(key, s, e)
                self._charge(len(data), fault_sim=extra)
                with self._lock:
                    self.stats["ranged_requests"] += 1
                    self.stats["coalesced_requests"] += 1
                payloads.append(data)
        with self._lock:
            self.stats["batched_ranges"] += len(ranges)
        return slice_spans(ranges, spans, assign, payloads)

    def get_many(self, keys: Sequence[str]) -> Dict[str, bytes]:
        """Multi-object batch read: ONE latency charge for the whole
        fan-out plus the summed transfer bytes — the batched counterpart
        of :meth:`get_ranges` for whole objects (tile fan-outs, manifest
        segment prefetch).  Faults draw per key; the first hard fault
        aborts the round after charging the wasted round-trip (partial
        results are discarded — the caller retries per key), and straggle
        overtime accumulates into the fault bucket."""
        out: Dict[str, bytes] = {}
        with self._sem:
            fault_extra = 0.0
            for k in keys:
                if k in out:
                    continue
                fault_extra += self._maybe_fault(k)
                out[k] = self.base.get(k)
            self._charge(sum(len(v) for v in out.values()),
                         fault_sim=fault_extra)
            with self._lock:
                self.stats["batched_objects"] += len(out)
        return out

    def put(self, key: str, data: bytes) -> None:
        with self._sem:
            fp = self.fault_policy
            # a tear needs at least 2 bytes to lose anything
            kind = fp.draw_put(key) if fp is not None and len(data) >= 2 \
                else None
            self._charge(len(data), upload=True)
            with self._lock:
                self.stats["put_requests"] += 1
            if kind is None:
                self.base.put(key, data)
                return
            with self._lock:
                self.stats["faults_injected"] += 1
                self.stats["faults_put_" + kind] += 1
                self.stats["wasted_upload_bytes"] += len(data)
            telemetry.registry().counter(
                "storage.wasted_upload_bytes").inc(len(data))
            if kind == "5xx":
                raise TransientStorageError(
                    f"injected 503 SlowDown uploading {key!r}")
            # torn: a prefix becomes durable and the call reports success —
            # only post-put verification (put_verified) can catch this
            self.base.put(key, bytes(data)[: len(data) // 2])

    def put_verified(self, key: str, data: bytes) -> None:
        data = bytes(data)

        def attempt() -> None:
            self.put(key, data)
            # the length check models the ETag/Content-MD5 riding the PUT
            # response: it probes the backing store directly and charges no
            # extra round-trip
            if self.base.num_bytes(key) != len(data):
                raise TornWriteError(
                    f"verification failed: {key!r} is shorter than the "
                    f"{len(data)} bytes uploaded")

        retry_transient(attempt, what=key)

    def cas(self, key: str, data: bytes, expected: Optional[bytes]) -> bool:
        # conditional PUT (If-Match): one round-trip whether it wins or loses
        with self._sem:
            fp = self.fault_policy
            kind = fp.draw_cas(key) if fp is not None else None
            self._charge(len(data), upload=True)
            with self._lock:
                self.stats["cas_requests"] += 1
            if kind is not None:
                # the conditional put dies before applying: nothing durable,
                # the caller's retry re-issues the same compare
                with self._lock:
                    self.stats["faults_injected"] += 1
                    self.stats["faults_cas_5xx"] += 1
                    self.stats["wasted_upload_bytes"] += len(data)
                telemetry.registry().counter(
                    "storage.wasted_upload_bytes").inc(len(data))
                raise TransientStorageError(
                    f"injected 503 on conditional put of {key!r}")
            ok = self.base.cas(key, data, expected)
            if not ok:
                with self._lock:
                    self.stats["cas_conflicts"] += 1
            return ok

    def delete(self, key: str) -> None:
        with self._sem:
            self._charge(0, cause="meta")
            self.base.delete(key)

    def exists(self, key: str) -> bool:
        # HEAD-style metadata probe: zero payload, full round-trip latency
        with self._sem:
            self._charge(0, cause="meta")
            with self._lock:
                self.stats["meta_requests"] += 1
            return self.base.exists(key)

    def list_keys(self, prefix: str = "") -> List[str]:
        with self._sem:
            self._charge(0, cause="meta")
            with self._lock:
                self.stats["meta_requests"] += 1
            return self.base.list_keys(prefix)

    def num_bytes(self, key: str) -> int:
        with self._sem:
            self._charge(0, cause="meta")
            with self._lock:
                self.stats["meta_requests"] += 1
            return self.base.num_bytes(key)


class LRUCacheProvider(StorageProvider):
    """LRU cache chained in front of a slower provider (§3.6).

    Reads fill the cache; writes go through to the base (write-through) so the
    base is always authoritative.  ``capacity_bytes`` bounds resident bytes.
    Range reads are served from a cached full object when present; otherwise
    they pass through *without* filling (streaming reads should not evict the
    working set — matches the paper's "buffer of fetched and unutilized data"
    being managed by the loader, not the cache).
    """

    kind = "lru"

    def __init__(self, base: StorageProvider, capacity_bytes: int = 256 << 20,
                 cache: Optional[StorageProvider] = None) -> None:
        self.base = base
        self.capacity_bytes = int(capacity_bytes)
        self._cache: Dict[str, bytes] = {}
        self._order: Dict[str, int] = {}  # key -> tick (monotone)
        self._tick = 0
        self._size = 0
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    # -- cache mechanics ----------------------------------------------------
    def _touch(self, key: str) -> None:
        self._tick += 1
        self._order[key] = self._tick

    def _admit(self, key: str, data: bytes) -> None:
        if len(data) > self.capacity_bytes:
            return
        with self._lock:
            if key in self._cache:
                self._size -= len(self._cache[key])
            self._cache[key] = data
            self._size += len(data)
            self._touch(key)
            while self._size > self.capacity_bytes and self._cache:
                victim = min(self._order, key=self._order.get)
                self._size -= len(self._cache.pop(victim))
                del self._order[victim]

    def _evict(self, key: str) -> None:
        with self._lock:
            if key in self._cache:
                self._size -= len(self._cache.pop(key))
                self._order.pop(key, None)

    # -- protocol ----------------------------------------------------------
    def get(self, key: str) -> bytes:
        with self._lock:
            if key in self._cache:
                self.hits += 1
                self._touch(key)
                return self._cache[key]
            self.misses += 1
        data = self.base.get(key)
        self._admit(key, data)
        return data

    def get_range(self, key: str, start: int, end: int) -> bytes:
        with self._lock:
            if key in self._cache:
                self.hits += 1
                self._touch(key)
                return self._cache[key][start:end]
            self.misses += 1
        return self.base.get_range(key, start, end)

    def get_ranges(self, key: str, ranges: Sequence[Range]) -> List[bytes]:
        """Every range served from a cached full object (one hit); misses
        pass through batched without filling, like :meth:`get_range`."""
        if not ranges:
            return []
        with self._lock:
            if key in self._cache:
                self.hits += 1
                self._touch(key)
                data = self._cache[key]
                return [data[s:max(s, e)] for s, e in ranges]
            self.misses += 1
        return self.base.get_ranges(key, ranges)

    def get_many(self, keys: Sequence[str]) -> Dict[str, bytes]:
        out: Dict[str, bytes] = {}
        missing: List[str] = []
        with self._lock:
            for k in keys:
                if k in self._cache:
                    self.hits += 1
                    self._touch(k)
                    out[k] = self._cache[k]
                elif k not in out and k not in missing:
                    self.misses += 1
                    missing.append(k)
        if missing:
            fetched = self.base.get_many(missing)
            for k, v in fetched.items():
                self._admit(k, v)
            out.update(fetched)
        return out

    def put(self, key: str, data: bytes) -> None:
        self.base.put(key, data)
        self._admit(key, bytes(data))

    def put_verified(self, key: str, data: bytes) -> None:
        # the base owns verification + retry; admit only the verified bytes
        data = bytes(data)
        self.base.put_verified(key, data)
        self._admit(key, data)

    def cas(self, key: str, data: bytes, expected: Optional[bytes]) -> bool:
        ok = self.base.cas(key, data, expected)
        if ok:
            self._admit(key, bytes(data))
        else:
            self._evict(key)  # the cached copy lost the race: drop it
        return ok

    def delete(self, key: str) -> None:
        self._evict(key)
        self.base.delete(key)

    def exists(self, key: str) -> bool:
        with self._lock:
            if key in self._cache:
                return True
        return self.base.exists(key)

    def list_keys(self, prefix: str = "") -> List[str]:
        return self.base.list_keys(prefix)

    def num_bytes(self, key: str) -> int:
        with self._lock:
            if key in self._cache:
                return len(self._cache[key])
        return self.base.num_bytes(key)


def chain(*providers: StorageProvider, capacity_bytes: int = 256 << 20) -> StorageProvider:
    """Chain providers into a cache hierarchy, fastest first.

    ``chain(mem, s3)`` returns an LRU over ``s3``; mirrors the paper's
    "LRU cache of remote S3 storage with local in-memory data".
    """
    if not providers:
        raise ValueError("need at least one provider")
    if len(providers) == 1:
        return providers[0]
    out = providers[-1]
    for _faster in reversed(providers[:-1]):
        out = LRUCacheProvider(out, capacity_bytes=capacity_bytes)
    return out


def storage_from_path(path: str, **kwargs) -> StorageProvider:
    """URL-ish constructor: ``mem://``, ``s3sim://``, or a filesystem path."""
    if path.startswith("mem://"):
        return MemoryProvider()
    if path.startswith("s3sim://"):
        return SimulatedS3Provider(MemoryProvider(), **kwargs)
    if path.startswith("file://"):
        path = path[len("file://"):]
    return LocalProvider(path)
