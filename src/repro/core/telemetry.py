"""End-to-end telemetry: span tracing, unified metrics, stall attribution.

This module is the single observability layer for the lakehouse. It has
three parts, all designed to be compiled out by default: when tracing is
disabled (the default) a span call returns a shared no-op context manager
and allocates nothing, and metric counters are plain lock-guarded adds.

Span naming scheme
------------------
Spans are dot-separated, lowercase, with the subsystem first. Per-group
spans embed the group/unit index in brackets. The wired-in names:

    query.plan                      TQL stats planning (plan_where)
    query.where                     streamed WHERE mask evaluation
    query.topk                      ORDER BY + LIMIT top-k stream
                                    (args include ``terminated_early``)
    scan.group[k].prefetch          ScanPipeline window top-up for group k
    scan.group[k].deliver           consumer processing of group k's rows
    scan.group[k].fetch             loader worker blob wait/read for unit k
    scan.group[k].decode            loader worker transform/collate for unit k
    fetch.retry                     one retry attempt inside FetchEngine._issue
                                    (args: key, attempt)
    fetch.hedge                     hedged duplicate in flight inside
                                    FetchEngine._hedged (args: key)
    commit.publish                  one CAS publish attempt in VersionControl
    commit.rebase                   rebase-and-retry after a lost CAS race
                                    (args: shape=adopt|relocate)
    loader.stall                    consumer blocked waiting for a ready unit
                                    (args: cause=fetch|decode|buffer_full)
    serve.admit                     whole handling of one QueryService query
                                    (args: tenant, cache=hit|miss|uncacheable)
    serve.queue                     time blocked on the admission semaphore
                                    (args: tenant, wait_s)
    serve.flight_wait               follower waiting on a single-flight leader
    serve.shard[k]                  one shard worker of a parallel chunk-group
                                    scan (args: groups)

``Tracer.report()`` aggregates by name with bracketed indices normalised
to ``[*]`` so per-query/per-epoch reports stay compact.

Chrome trace JSON schema
------------------------
``Tracer.export_chrome()`` returns (and ``write_chrome(path)`` dumps) the
standard Chrome ``trace_event`` envelope, loadable in chrome://tracing or
Perfetto:

    {"traceEvents": [
        {"ph": "M", "pid": 1, "name": "process_name",
         "args": {"name": "repro-lakehouse"}},
        {"ph": "X", "pid": 1, "tid": <thread-id>, "name": "scan.group[3].fetch",
         "cat": "scan", "ts": <start, microseconds>, "dur": <microseconds>,
         "args": {..., "depth": <nesting depth>, "parent": <parent span name>}},
        ...
    ]}

All complete spans use phase ``"X"`` (duration events); ``cat`` is the
name's first dot-component; ``ts`` is relative to the tracer epoch.

Metrics registry
----------------
``registry()`` returns the process-wide :class:`MetricsRegistry`. Metric
names are dot-separated (``commit.rebases``, ``storage.wasted_upload_bytes``,
``tql.plans``, ``serve.cache.hits`` / ``serve.cache.misses`` /
``serve.plan_cache.hits``, per-tenant ``serve.tenant.<t>.*``);
``snapshot()`` flattens them to underscore keys (``commit_rebases``) so they
can be recorded as ``BENCH_io.json`` leaves. ``provider_snapshot(provider)``
is the one snapshot API the benches share: numeric provider stats merged
with ``engine_*``-prefixed :func:`repro.core.fetch.engine_stats_for` stats
(old key names preserved).

Stall attribution
-----------------
Storage charges are bucketed by the issuing thread's *IO cause*
(``io_cause()`` / ``current_io_cause()``): ``demand`` (default),
``prefetch``, ``retry``, ``hedge``, ``fault`` (injected-fault surcharge),
``write``, ``meta``. ``SimulatedS3Provider`` keeps one ``sim_s_<cause>``
stats key per bucket with the partition invariant
``sum(sim_s_*) == sim_seconds``. :func:`attribute_stall` folds those
buckets into the fig6 stall decomposition — ``retry_hedge_s``,
``demand_fetch_s``, ``decode_s``, ``prefetch_eviction_s``,
``unattributed_s`` — which by construction sums exactly to ``total_s``.
"""

from __future__ import annotations

import json
import re
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

__all__ = [
    "Tracer",
    "SpanRecord",
    "get_tracer",
    "enabled",
    "span",
    "gspan",
    "null_span",
    "tracing",
    "io_cause",
    "current_io_cause",
    "IO_CAUSES",
    "MetricsRegistry",
    "registry",
    "provider_snapshot",
    "sim_cause_partition",
    "attribute_stall",
    "SIM_CAUSE_PREFIX",
    "STALL_CAUSE_KEYS",
]

# --------------------------------------------------------------------------
# Span tracing
# --------------------------------------------------------------------------

_INDEX_RE = re.compile(r"\[\d+\]")


class SpanRecord:
    """One finished span: immutable record appended to the tracer buffer."""

    __slots__ = ("name", "cat", "ts", "dur", "tid", "depth", "parent", "args")

    def __init__(self, name: str, cat: str, ts: float, dur: float, tid: int,
                 depth: int, parent: Optional[str], args: Dict[str, Any]):
        self.name = name
        self.cat = cat
        self.ts = ts          # seconds since tracer epoch
        self.dur = dur        # seconds
        self.tid = tid
        self.depth = depth
        self.parent = parent
        self.args = args

    def to_chrome(self, pid: int = 1) -> Dict[str, Any]:
        args = dict(self.args)
        args["depth"] = self.depth
        if self.parent is not None:
            args["parent"] = self.parent
        return {
            "ph": "X",
            "pid": pid,
            "tid": self.tid,
            "name": self.name,
            "cat": self.cat,
            "ts": self.ts * 1e6,
            "dur": self.dur * 1e6,
            "args": args,
        }

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SpanRecord({self.name!r}, ts={self.ts:.6f}, dur={self.dur:.6f})"


class _NullSpan:
    """Shared no-op context manager returned whenever tracing is disabled.

    A single module-level instance is reused for every call so the disabled
    path allocates no span objects at all.
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **args: Any) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """A live span; records itself into the tracer buffer on exit."""

    __slots__ = ("tracer", "name", "args", "t0", "depth", "parent")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.depth = 0
        self.parent: Optional[str] = None

    def set(self, **args: Any) -> "_Span":
        """Attach extra args; must be called before the span exits."""
        self.args.update(args)
        return self

    def __enter__(self) -> "_Span":
        tls = self.tracer._tls
        stack = getattr(tls, "stack", None)
        if stack is None:
            stack = tls.stack = []
        self.parent = stack[-1].name if stack else None
        self.depth = len(stack)
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, etype: Any, evalue: Any, tb: Any) -> bool:
        dur = time.perf_counter() - self.t0
        stack = getattr(self.tracer._tls, "stack", None)
        if stack and stack[-1] is self:
            stack.pop()
        if etype is not None:
            self.args.setdefault("error", getattr(etype, "__name__", str(etype)))
        self.tracer._record(self, dur)
        return False


class Tracer:
    """Thread-safe span collector. Disabled by default; ~zero cost when off."""

    MAX_EVENTS = 1_000_000

    def __init__(self) -> None:
        self.enabled = False
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._events: List[SpanRecord] = []
        self.dropped = 0
        self._epoch = time.perf_counter()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        self.enabled = True

    def stop(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events = []
            self.dropped = 0
            self._epoch = time.perf_counter()

    # -- recording ---------------------------------------------------------

    def span(self, name: str, **args: Any):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args)

    def _record(self, sp: _Span, dur: float) -> None:
        name = sp.name
        dot = name.find(".")
        rec = SpanRecord(
            name=name,
            cat=name[:dot] if dot > 0 else name,
            ts=sp.t0 - self._epoch,
            dur=dur,
            tid=threading.get_ident(),
            depth=sp.depth,
            parent=sp.parent,
            args=sp.args,
        )
        with self._lock:
            if len(self._events) >= self.MAX_EVENTS:
                self.dropped += 1
                return
            self._events.append(rec)

    # -- inspection --------------------------------------------------------

    def events(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._events)

    def find(self, prefix: str) -> List[SpanRecord]:
        return [e for e in self.events() if e.name.startswith(prefix)]

    def count(self, prefix: str) -> int:
        return len(self.find(prefix))

    def report(self) -> Dict[str, Dict[str, float]]:
        """Compact per-name aggregate; bracketed indices collapse to ``[*]``."""
        out: Dict[str, Dict[str, float]] = {}
        for e in self.events():
            key = _INDEX_RE.sub("[*]", e.name)
            agg = out.setdefault(key, {"count": 0, "total_s": 0.0, "max_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += e.dur
            agg["max_s"] = max(agg["max_s"], e.dur)
        return out

    # -- export ------------------------------------------------------------

    def export_chrome(self, pid: int = 1) -> Dict[str, Any]:
        events: List[Dict[str, Any]] = [
            {"ph": "M", "pid": pid, "name": "process_name",
             "args": {"name": "repro-lakehouse"}},
        ]
        events.extend(e.to_chrome(pid) for e in self.events())
        return {"traceEvents": events}

    def write_chrome(self, path: str, pid: int = 1) -> None:
        doc = self.export_chrome(pid)
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(doc, fh)


_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def enabled() -> bool:
    return _TRACER.enabled


def span(name: str, **args: Any):
    """Open a span on the global tracer; no-op (shared object) when disabled."""
    if not _TRACER.enabled:
        return _NULL_SPAN
    return _Span(_TRACER, name, args)


def gspan(index: int, phase: str, **args: Any):
    """``scan.group[<index>].<phase>`` span; the name string is only built
    when tracing is enabled, keeping the disabled hot path allocation-free."""
    if not _TRACER.enabled:
        return _NULL_SPAN
    return _Span(_TRACER, f"scan.group[{index}].{phase}", args)


def null_span() -> _NullSpan:
    """The shared no-op span, for call sites that conditionally trace."""
    return _NULL_SPAN


@contextmanager
def tracing(clear: bool = True) -> Iterator[Tracer]:
    """Enable the global tracer for the duration of the block."""
    prev = _TRACER.enabled
    if clear and not prev:
        _TRACER.clear()
    _TRACER.enabled = True
    try:
        yield _TRACER
    finally:
        _TRACER.enabled = prev


# --------------------------------------------------------------------------
# IO cause tagging (always on; feeds the sim_s_* stall buckets)
# --------------------------------------------------------------------------

IO_CAUSES = ("demand", "prefetch", "retry", "hedge", "fault", "write", "meta")

_cause_tls = threading.local()


def current_io_cause() -> str:
    """The active IO cause for this thread; ``demand`` if untagged.

    Thread-local: a cause does NOT propagate into threads spawned inside
    the tagged block (hedge/primary arms must re-tag explicitly).
    """
    return getattr(_cause_tls, "cause", "demand")


@contextmanager
def io_cause(cause: str) -> Iterator[None]:
    """Tag storage charges issued by this thread with ``cause``."""
    prev = getattr(_cause_tls, "cause", "demand")
    _cause_tls.cause = cause
    try:
        yield
    finally:
        _cause_tls.cause = prev


# --------------------------------------------------------------------------
# Metrics registry
# --------------------------------------------------------------------------


class Counter:
    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class Gauge:
    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Cheap streaming histogram: count/sum/min/max (no buckets)."""

    __slots__ = ("_lock", "count", "total", "min", "max")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        with self._lock:
            self.count += 1
            self.total += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    def summary(self) -> Dict[str, float]:
        with self._lock:
            if self.count == 0:
                return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0}
            return {"count": self.count, "sum": self.total,
                    "min": self.min, "max": self.max}


class MetricsRegistry:
    """One process-wide registry of named counters/gauges/histograms.

    Names are dot-separated; ``snapshot()`` flattens to underscore keys so
    values drop straight into ``BENCH_io.json`` leaves.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls: type) -> Any:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls()
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {type(m).__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, float] = {}
        for name, m in items:
            key = name.replace(".", "_")
            if isinstance(m, Histogram):
                for k, v in m.summary().items():
                    out[f"{key}_{k}"] = v
            else:
                out[key] = m.value
        return out

    def delta(self, base: Dict[str, float]) -> Dict[str, float]:
        """Snapshot minus an earlier snapshot (missing base keys read as 0).

        Gauges and histogram min/max are point-in-time, so a delta is only
        meaningful for counter-backed keys; use accordingly.
        """
        now = self.snapshot()
        return {k: v - base.get(k, 0) for k, v in now.items()}

    def reset(self) -> None:
        with self._lock:
            self._metrics = {}


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _REGISTRY


def provider_snapshot(provider: Any) -> Dict[str, float]:
    """Unified numeric snapshot: provider stats + ``engine_*`` engine stats.

    This is the single snapshot API the benches share (it replaced the
    ad-hoc provider/engine dict-merging that each bench used to do by
    hand). Key names match the historical ``BENCH_io.json`` layout:
    provider keys verbatim (including ``faults_*`` and ``sim_s_*``),
    engine keys prefixed ``engine_``.
    """
    out: Dict[str, float] = {}
    for k, v in getattr(provider, "stats", {}).items():
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            out[k] = v
    from .fetch import engine_stats_for  # local import: fetch imports telemetry

    for k, v in engine_stats_for(provider).items():
        out[f"engine_{k}"] = v
    return out


# --------------------------------------------------------------------------
# Stall attribution
# --------------------------------------------------------------------------

SIM_CAUSE_PREFIX = "sim_s_"

# Output keys of attribute_stall, in allocation priority order. Pure
# overhead (injected faults, retries, hedges) is charged to the stall
# first; prefetch traffic is the most compute-overlappable so it absorbs
# stall last.
STALL_CAUSE_KEYS = (
    "retry_hedge_s",
    "demand_fetch_s",
    "decode_s",
    "prefetch_eviction_s",
    "unattributed_s",
)

_CAUSE_TO_KEY = {
    "fault": "retry_hedge_s",
    "retry": "retry_hedge_s",
    "hedge": "retry_hedge_s",
    "demand": "demand_fetch_s",
    "write": "demand_fetch_s",
    "meta": "demand_fetch_s",
    "decode": "decode_s",
    "prefetch": "prefetch_eviction_s",
}


def sim_cause_partition(stats: Dict[str, Any]) -> Dict[str, float]:
    """Extract the per-cause simulated-seconds buckets from provider stats.

    The provider maintains the partition invariant
    ``sum(sim_cause_partition(stats).values()) == stats["sim_seconds"]``.
    """
    n = len(SIM_CAUSE_PREFIX)
    return {k[n:]: float(v) for k, v in stats.items()
            if k.startswith(SIM_CAUSE_PREFIX)}


def attribute_stall(sim_by_cause: Dict[str, float], compute_s: float,
                    parallelism: float = 1.0,
                    decode_s: float = 0.0) -> Dict[str, float]:
    """Decompose stall-seconds into exhaustive, non-overlapping causes.

    ``sim_by_cause`` is the provider's cause partition (raw simulated
    seconds; divided by ``parallelism`` to model concurrent connections).
    ``decode_s`` is effective (already per-worker) decode time to fold in.
    Stall is ``max(0, effective_io - compute_s)`` and is allocated across
    :data:`STALL_CAUSE_KEYS` in priority order, so the returned causes sum
    to ``total_s`` exactly; anything the named buckets cannot absorb lands
    in ``unattributed_s``.
    """
    par = max(float(parallelism), 1e-9)
    grouped: Dict[str, float] = {k: 0.0 for k in STALL_CAUSE_KEYS}
    for cause, sec in sim_by_cause.items():
        key = _CAUSE_TO_KEY.get(cause, "unattributed_s")
        grouped[key] += float(sec) / par
    grouped["decode_s"] += float(decode_s)

    total_io = sum(grouped.values())
    stall = max(0.0, total_io - float(compute_s))
    out: Dict[str, float] = {}
    remaining = stall
    for key in STALL_CAUSE_KEYS[:-1]:
        take = min(grouped[key], remaining)
        out[key] = take
        remaining -= take
    out["unattributed_s"] = remaining  # exact remainder: causes sum to total
    out["total_s"] = stall
    return out
