"""Tensor column API (§3.2): typed, append-only + in-place-editable, ragged.

A Tensor owns:
  * a :class:`ChunkEncoder` (index map) snapshot for the current version,
  * an open in-memory :class:`ChunkBuilder` absorbing appends,
  * per-sample ids (u64) for merge identity,
  * meta (htype, dtype, codec, chunk-size bounds, min/max shapes).

Chunking policy (§3.4): appends accumulate in the open chunk until its
*serialized* size would exceed ``max_chunk_size``; a chunk smaller than
``min_chunk_size`` left behind by an earlier version is reopened copy-on-write.
Samples whose encoded payload alone exceeds ``max_chunk_size`` are tiled
(:mod:`.tiling`).  All mutation is routed through the version-control layer so
time travel stays correct.
"""

from __future__ import annotations

import json
import time
import uuid
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import chunks as chunklib
from . import fetch
from .chunk_encoder import ChunkEncoder, ChunkStatsTable
from .chunks import FLAG_TILED, ChunkBuilder, ChunkHeader, ChunkStats
from .codecs import get_codec
from .htypes import get_htype
from .storage import StorageError, coalesce_ranges
from .tiling import (TileDescriptor, assemble_from_tiles, assemble_region,
                     plan_tile_shape, split_into_tiles, tiles_for_region)
from .version_control import VersionControl

DEFAULT_MIN_CHUNK = 8 << 20
DEFAULT_MAX_CHUNK = 16 << 20

#: speculative header read size: one ranged request covers the full header
#: of any chunk up to ~150 samples (48 + ~26 B/sample); larger headers pay
#: exactly one follow-up request for the remainder
HEADER_PROBE_BYTES = 4096


def _new_chunk_name(prefix: str = "c") -> str:
    return f"{prefix}{uuid.uuid4().hex[:12]}"


@dataclass
class TensorMeta:
    htype: str = "generic"
    dtype: Optional[str] = None
    codec: str = "raw"
    min_chunk_size: int = DEFAULT_MIN_CHUNK
    max_chunk_size: int = DEFAULT_MAX_CHUNK
    strict: bool = True
    min_shape: Optional[List[int]] = None
    max_shape: Optional[List[int]] = None
    links: List[str] = field(default_factory=list)  # storage providers for link[...]

    def to_json(self) -> dict:
        return self.__dict__.copy()

    @classmethod
    def from_json(cls, d: dict) -> "TensorMeta":
        m = cls()
        for k, v in d.items():
            setattr(m, k, v)
        return m

    def update_shape_bounds(self, shape: Tuple[int, ...]) -> None:
        s = list(shape)
        if self.min_shape is None:
            self.min_shape, self.max_shape = list(s), list(s)
            return
        if len(s) != len(self.min_shape):
            # ragged ndim: collapse to unconstrained
            n = max(len(s), len(self.min_shape))
            self.min_shape = [0] * n
            self.max_shape = [max(max(self.max_shape, default=0),
                                  max(s, default=0))] * n
            return
        self.min_shape = [min(a, b) for a, b in zip(self.min_shape, s)]
        self.max_shape = [max(a, b) for a, b in zip(self.max_shape, s)]


class Tensor:
    """One column of a dataset, bound to a version-control node."""

    def __init__(self, name: str, vc: VersionControl, meta: Optional[TensorMeta] = None,
                 node_id: Optional[str] = None) -> None:
        self.name = name
        self.vc = vc
        self.node_id = node_id          # None => follow vc.current (writable)
        self._header_cache: dict = {}
        self._fetch_engine: Optional["fetch.FetchEngine"] = None
        self._builder: Optional[ChunkBuilder] = None
        self._open_name: Optional[str] = None
        self._dirty = False
        # True while the open builder holds bytes newer than its last
        # upload — flush/commit retries skip re-putting an unchanged chunk
        self._builder_dirty = False
        if meta is not None:
            self.meta = meta
            self.encoder = ChunkEncoder()
            self.stats = ChunkStatsTable()
            self.sample_ids: List[int] = []
            self._dirty = True
        else:
            self._load_state()

    # ------------------------------------------------------------ state I/O
    def _load_state(self) -> None:
        """Load per-tensor state via the version-control state layer:
        manifest-covered nodes resolve every file from the consolidated
        snapshot (zero storage requests on a cold open)."""
        raw = self.vc.get_state(self.name, "meta.json", self.node_id)
        if raw is None:
            raise StorageError(f"tensor {self.name!r} has no state at this version")
        self.meta = TensorMeta.from_json(json.loads(raw.decode()))
        enc = self.vc.get_state(self.name, "chunk_encoder", self.node_id)
        self.encoder = ChunkEncoder.deserialize(enc) if enc else ChunkEncoder()
        st = self.vc.get_state(self.name, "chunk_stats.json", self.node_id)
        self.stats = ChunkStatsTable.deserialize(st) if st else ChunkStatsTable()
        ids = self.vc.get_state(self.name, "sample_ids", self.node_id)
        self.sample_ids = (
            [int(x) for x in np.frombuffer(zlib.decompress(ids), dtype="<u8")]
            if ids else [])

    def flush(self) -> None:
        """Persist open chunk + encoder + stats + ids + meta + chunk_set + diff."""
        if self.node_id is not None:
            return  # read-only binding
        if self._builder is not None and self._builder.num_samples \
                and self._builder_dirty:
            self.vc.register_new_chunk(self.name, self._open_name)
            key = self.vc.put_chunk(self.name, self._open_name,
                                    self._builder.serialize())
            self._builder_dirty = False
            self._discard_cached(key)  # the key's bytes just changed
            self.stats.set(self._open_name, self._builder.stats_snapshot())
        if not self._dirty:
            return
        self.stats.prune_to(self.encoder.chunk_names())
        self.vc.put_state(self.name, "chunk_stats.json", self.stats.serialize())
        self.vc.put_state(self.name, "chunk_encoder", self.encoder.serialize())
        self.vc.put_state(
            self.name, "sample_ids",
            zlib.compress(np.asarray(self.sample_ids, dtype="<u8").tobytes(), 1))
        self.vc.put_state(self.name, "meta.json",
                          json.dumps(self.meta.to_json()).encode())
        self.vc.flush_chunk_set(self.name)
        self.vc.flush_diff(self.name)
        self._dirty = False

    # --------------------------------------------------------------- basics
    def __len__(self) -> int:
        return self.encoder.num_samples

    @property
    def num_chunks(self) -> int:
        return self.encoder.num_chunks

    @property
    def dtype(self) -> Optional[np.dtype]:
        return np.dtype(self.meta.dtype) if self.meta.dtype else None

    @property
    def htype(self) -> str:
        return self.meta.htype

    @property
    def shape(self) -> Tuple[Optional[int], ...]:
        """(len, *dims) with None for ragged dims."""
        if self.meta.min_shape is None:
            return (len(self),)
        dims = tuple(a if a == b else None
                     for a, b in zip(self.meta.min_shape, self.meta.max_shape))
        return (len(self),) + dims

    @property
    def is_link(self) -> bool:
        return self.meta.htype.startswith("link[")

    @property
    def is_sequence(self) -> bool:
        return self.meta.htype.startswith("sequence[")

    # -------------------------------------------------------------- writing
    def _coerce(self, sample: Any) -> np.ndarray:
        if self.is_link and isinstance(sample, str):
            sample = np.frombuffer(sample.encode(), dtype=np.uint8).copy()
        arr = np.asarray(sample)
        if self.meta.dtype is None:
            # first sample locks the dtype (schema inference)
            spec = get_htype(self.meta.htype)
            self.meta.dtype = spec.default_dtype or arr.dtype.str
            self._dirty = True
        want = np.dtype(self.meta.dtype)
        if arr.dtype != want:
            if self.meta.strict and arr.dtype.kind != want.kind and arr.size:
                # allow int->float style promotion only when not strict
                if not np.can_cast(arr.dtype, want, casting="same_kind"):
                    raise TypeError(
                        f"tensor {self.name!r} ({want}) got {arr.dtype} sample")
            arr = arr.astype(want)
        if self.meta.strict:
            get_htype(self.meta.htype).validate(arr, self.meta.dtype)
        return arr

    def _fresh_builder(self) -> ChunkBuilder:
        return ChunkBuilder(self.meta.dtype, self.meta.codec)

    def _ensure_open(self, incoming_bytes: int) -> ChunkBuilder:
        """Return a builder with room for ``incoming_bytes`` more payload."""
        if self._builder is not None:
            if (self._builder.num_samples
                    and self._builder.nbytes_serialized() + incoming_bytes
                    > self.meta.max_chunk_size):
                self._finalize_open()
            else:
                return self._builder
        if self._builder is None:
            # copy-on-write reopen of an undersized trailing chunk (§3.4)
            if (self.encoder.num_chunks
                    and incoming_bytes < self.meta.max_chunk_size):
                last_ord = self.encoder.num_chunks - 1
                last_name = self.encoder.name_of(last_ord)
                key = self.vc.resolve_chunk_key(self.name, last_name, self.node_id)
                size = self.vc.storage.num_bytes(key) if self.vc.storage.exists(key) else 0
                if 0 < size < self.meta.min_chunk_size \
                        and size + incoming_bytes <= self.meta.max_chunk_size:
                    raw = self._engine().fetch_full(key)  # retries transients
                    header = chunklib.parse_header(raw)
                    b = self._fresh_builder()
                    for i in range(header.num_samples):
                        s, e = header.byte_range(i)
                        b.append_raw(raw[s:e], header.shapes[i], int(header.flags[i]))
                    n = self.encoder.samples_in(last_ord)
                    self.encoder.pop_last()
                    self.stats.drop(last_name)
                    self._builder = b
                    self._builder_dirty = True
                    self._open_name = _new_chunk_name()
                    self.encoder.register_chunk(self._open_name, n)
                    # drop the superseded chunk if it was born in this version
                    if last_name in self.vc.chunk_set(self.vc.current_id, self.name):
                        self.vc.forget_chunk(self.name, last_name)
                        self.vc.storage.delete(key)
                    self._discard_cached(key)
                    return self._builder
            self._builder = self._fresh_builder()
            self._open_name = _new_chunk_name()
        return self._builder

    def _finalize_open(self) -> None:
        if self._builder is None or not self._builder.num_samples:
            self._builder, self._open_name = None, None
            return
        self.vc.register_new_chunk(self.name, self._open_name)
        key = self.vc.put_chunk(self.name, self._open_name,
                                self._builder.serialize())
        self._builder_dirty = False
        self._discard_cached(key)  # the key's bytes just changed
        self.stats.set(self._open_name, self._builder.stats_snapshot())
        self._builder, self._open_name = None, None

    def _append_encoded(self, payload: bytes, shape: Tuple[int, ...], flags: int,
                        sample_id: Optional[int],
                        source: Optional[np.ndarray] = None) -> int:
        b = self._ensure_open(len(payload))
        was_empty = b.num_samples == 0
        b.append_raw(payload, shape, flags, source=source)
        self._builder_dirty = True
        if was_empty and (self.encoder.num_chunks == 0
                          or self.encoder.name_of(self.encoder.num_chunks - 1)
                          != self._open_name):
            self.encoder.register_chunk(self._open_name, 1)
        else:
            self.encoder.extend_last(1)
        idx = self.encoder.num_samples - 1
        self.sample_ids.append(sample_id if sample_id is not None
                               else int(uuid.uuid4().int & ((1 << 64) - 1)))
        self.meta.update_shape_bounds(shape)
        self.vc.record_append(self.name, idx, 1)
        self._dirty = True
        return idx

    def append(self, sample: Any, sample_id: Optional[int] = None) -> int:
        """Append one sample; returns its global index."""
        if self.node_id is not None:
            raise PermissionError("tensor bound to a sealed version is read-only")
        self.vc.require_writable()
        arr = self._coerce(sample)
        codec = get_codec(self.meta.codec)
        payload = codec.encode(arr)
        if len(payload) > self.meta.max_chunk_size:
            desc, effective = self._write_tiled(arr)
            # exact stats for tiled samples: the builder observes the array
            # a reader would reassemble, so the planner never degrades the
            # whole chunk to 'verify' just because one sample was tiled
            return self._append_encoded(desc.to_bytes(), tuple(arr.shape),
                                        FLAG_TILED, sample_id,
                                        source=effective)
        return self._append_encoded(payload, tuple(arr.shape), 0, sample_id,
                                    source=arr)

    def extend(self, samples: Sequence[Any]) -> None:
        for s in samples:
            self.append(s)

    def _write_tiled(self, arr: np.ndarray
                     ) -> Tuple[TileDescriptor, np.ndarray]:
        """Split + store tiles; returns the descriptor and the *effective*
        array (what a reader reassembles: ``arr`` itself for lossless
        codecs, the decoded round-trip for lossy ones) so stats computed
        at flush bound exactly what queries will read."""
        tile_shape = plan_tile_shape(
            arr.shape, arr.dtype.itemsize,
            max(1, int(self.meta.max_chunk_size * 0.8)))
        grid, tiles = split_into_tiles(arr, tile_shape)
        codec = get_codec(self.meta.codec)
        names = []
        payloads = []
        for t in tiles:
            name = _new_chunk_name("t")
            self.vc.register_new_chunk(self.name, name)
            payload = codec.encode(t)
            self.vc.put_chunk(self.name, name, payload)
            names.append(name)
            payloads.append(payload)
        desc = TileDescriptor(tuple(arr.shape), tile_shape, grid, names,
                              self.meta.dtype, self.meta.codec)
        effective = arr if not codec.lossy \
            else assemble_from_tiles(desc, payloads)
        return desc, effective

    # ------------------------------------------------------------- updating
    def __setitem__(self, idx: int, sample: Any) -> None:
        if self.node_id is not None:
            raise PermissionError("tensor bound to a sealed version is read-only")
        self.vc.require_writable()
        n = len(self)
        if idx < 0:
            idx += n
        if idx >= n:
            if self.meta.strict:
                raise IndexError(
                    f"index {idx} out of bounds for strict tensor of length {n}; "
                    f"create with strict=False for sparse assignment (§3.5)")
            empty = np.zeros((0,), dtype=self.meta.dtype or np.asarray(sample).dtype)
            while len(self) < idx:
                self.append(empty)
            self.append(sample)
            return
        arr = self._coerce(sample)
        codec = get_codec(self.meta.codec)
        payload = codec.encode(arr)
        flags = 0
        if len(payload) > self.meta.max_chunk_size:
            desc, _effective = self._write_tiled(arr)
            payload, flags = desc.to_bytes(), FLAG_TILED
        chunk_name, local = self.encoder.lookup(idx)
        if self._builder is not None and chunk_name == self._open_name:
            self._builder.replace_payload(local, payload, tuple(arr.shape), flags)
            self._builder_dirty = True
        else:
            self._rewrite_chunk(idx, chunk_name, local, payload,
                                tuple(arr.shape), flags)
        self.meta.update_shape_bounds(tuple(arr.shape))
        self.vc.record_update(self.name, idx)
        self._dirty = True

    def _rewrite_chunk(self, idx: int, chunk_name: str, local: int,
                       payload: bytes, shape: Tuple[int, ...], flags: int) -> None:
        """Copy-on-write a sealed/persisted chunk with one sample replaced."""
        key = self.vc.resolve_chunk_key(self.name, chunk_name, self.node_id)
        raw = self._engine().fetch_full(key)  # retries transients
        header = chunklib.parse_header(raw)
        b = self._fresh_builder()
        for i in range(header.num_samples):
            if i == local:
                b.append_raw(payload, shape, flags)
            else:
                s, e = header.byte_range(i)
                b.append_raw(raw[s:e], header.shapes[i], int(header.flags[i]))
        new_name = _new_chunk_name()
        self.vc.register_new_chunk(self.name, new_name)
        self.vc.put_chunk(self.name, new_name, b.serialize())
        ord_ = self.encoder.chunk_ord_of(idx)
        self.encoder.replace(ord_, new_name)
        self.stats.set(new_name, b.stats_snapshot())
        self.stats.drop(chunk_name)
        if chunk_name in self.vc.chunk_set(self.vc.current_id, self.name):
            self.vc.forget_chunk(self.name, chunk_name)
            self.vc.storage.delete(key)
        self._discard_cached(key)

    # --------------------------------------------------------------- reading
    def _engine(self) -> "fetch.FetchEngine":
        """The storage's shared fetch engine, cached per tensor so the
        per-sample read path skips the global registry lookup."""
        eng = self._fetch_engine
        if eng is None:
            eng = self._fetch_engine = fetch.engine_for(self.vc.storage)
        return eng

    def _discard_cached(self, key: str) -> None:
        """Invalidate every read-side cache of a chunk key whose bytes
        changed or vanished (open-chunk reflush, copy-on-write delete):
        the parsed-header memo and the shared engine's resident blob."""
        self._header_cache.pop(key, None)
        self._engine().discard(key)

    def prefetch_chunks(self, chunk_ords: Sequence[int], *,
                        owner: object = None, on_fetched=None,
                        budget: Optional[int] = None,
                        queued_bytes: int = 0) -> int:
        """Queue whole-chunk prefetches on the fetch engine, in the given
        order, skipping the open chunk.  Queued bytes are bounded by
        ``budget`` (default: half the destination buffer — LRU tier or
        resident store) with chunk sizes estimated from the stats sidecar;
        returns the accumulated queued bytes so callers can thread one
        budget across several tensors.  ``owner``/``on_fetched`` pass
        through to :meth:`FetchEngine.prefetch`.
        """
        engine = self._engine()
        if budget is None:
            budget = (engine.cache_above or engine.resident_bytes) // 2
        for o in chunk_ords:
            cname = self.encoder.name_of(int(o))
            if self._builder is not None and cname == self._open_name:
                continue
            st = self.stats.get(cname)
            est = st.nbytes if st is not None and st.nbytes \
                else self.meta.max_chunk_size
            if queued_bytes and queued_bytes + est > budget:
                break  # the rest is fetched (coalesced) on demand
            queued_bytes += est
            engine.prefetch(self._chunk_key(cname), owner=owner,
                            on_fetched=on_fetched)
        return queued_bytes

    def _chunk_key(self, chunk_name: str) -> str:
        return self.vc.resolve_chunk_key(self.name, chunk_name, self.node_id)

    def _header_of(self, key: str, ranged: bool,
                   counters: Optional[Dict[str, int]] = None) -> ChunkHeader:
        h = self._header_cache.get(key)
        if h is not None:
            return h
        engine = self._engine()
        blob = engine.resident(key)
        if blob is not None:
            h = chunklib.parse_header(blob)
        elif ranged:
            # speculative probe via the engine (observed by its stats and
            # cost EWMA): the whole header in one ranged request for
            # typical chunks, two for very wide ones (was always two)
            prefix = engine.fetch_ranges(key, [(0, HEADER_PROBE_BYTES)],
                                         counters=counters)[0]
            hs = chunklib.header_size_of(prefix)
            if hs > len(prefix):
                prefix += engine.fetch_ranges(key, [(len(prefix), hs)],
                                              counters=counters)[0]
            h = chunklib.parse_header(prefix)
        else:
            h = chunklib.parse_header(self._engine().fetch_full(key))
        self._header_cache[key] = h
        return h

    def _payload_of(self, idx: int, *, ranged: Optional[bool] = None
                    ) -> Tuple[bytes, Tuple[int, ...], bool]:
        """(payload bytes, shape, is_tiled) for a sample, via open chunk or storage."""
        chunk_name, local = self.encoder.lookup(idx)
        if self._builder is not None and chunk_name == self._open_name:
            b = self._builder
            return (b.payloads[local], tuple(b.shapes[local]),
                    bool(b.flags[local] & FLAG_TILED))
        key = self._chunk_key(chunk_name)
        blob = self._engine().resident(key)
        if blob is not None:  # prefetched chunk: slice locally, no I/O
            header = self._header_of(key, True)
            s, e = header.byte_range(local)
            return blob[s:e], header.shapes[local], header.is_tiled(local)
        if ranged is None:
            ranged = self.vc.storage.kind in ("s3", "lru")
        header = self._header_of(key, ranged)
        s, e = header.byte_range(local)
        # both paths ride the engine: retry policy + request accounting
        payload = (self._engine().fetch_ranges(key, [(s, e)])[0] if ranged
                   else self._engine().fetch_full(key)[s:e])
        return payload, header.shapes[local], header.is_tiled(local)

    def read(self, idx: int, *, ranged: Optional[bool] = None) -> np.ndarray:
        n = len(self)
        if idx < 0:
            idx += n
        if not 0 <= idx < n:
            raise IndexError(f"{idx} out of range [0, {n})")
        payload, shape, tiled = self._payload_of(idx, ranged=ranged)
        if tiled:
            return self._assemble_tiled(payload)
        codec = get_codec(self.meta.codec)
        return codec.decode(payload, shape, np.dtype(self.meta.dtype))

    def _assemble_tiled(self, payload: bytes) -> np.ndarray:
        """Reassemble a tiled sample; tile chunks fetched as one batch."""
        desc = TileDescriptor.from_bytes(payload)
        keys = [self._chunk_key(nm) for nm in desc.chunk_names]
        blobs = self._engine().fetch_many(keys)
        return assemble_from_tiles(desc, [blobs[k] for k in keys])

    # ------------------------------------------------------------ batch read
    def read_batch(self, indices: Union[Sequence[int], np.ndarray], *,
                   ranged: Optional[bool] = None,
                   io_stats: Optional[Dict[str, Any]] = None
                   ) -> List[np.ndarray]:
        """Read many samples with at most one coalesced request per chunk.

        The per-sample hot paths (TQL column stacking, the loader's fetch
        units) route through here: indices are grouped by chunk, each
        chunk's sample byte-ranges are fetched as one full GET or one
        coalesced ranged request — whichever the engine's cost model says
        is cheaper — and chunk ``k+1``'s fetch overlaps chunk ``k``'s
        decode on the engine pool.  Output order matches input order;
        duplicate and unsorted indices are fine.

        ``ranged``: None → cost-model decision per chunk; True → always
        ranged reads; False → always whole-chunk GETs.
        ``io_stats``: optional dict accumulating ``io_s``, ``cpu_s``,
        ``bytes``, ``requests`` (the loader feeds these into LoaderStats).
        """
        arr = np.asarray(indices, dtype=np.int64).ravel()
        if arr.size == 0:
            return []
        n = len(self)
        arr = np.where(arr < 0, arr + n, arr)
        ords = self.encoder.ords_of(arr)  # bounds-checks, raises IndexError
        out: List[Optional[np.ndarray]] = [None] * int(arr.size)
        codec = get_codec(self.meta.codec)
        dt = np.dtype(self.meta.dtype)
        engine = self._engine()
        groups: Dict[int, List[int]] = {}
        for j, o in enumerate(ords.tolist()):
            groups.setdefault(int(o), []).append(j)
        tasks = []
        for o in sorted(groups):
            name = self.encoder.name_of(o)
            first, _last = self.encoder.chunk_span(o)
            slots = groups[o]
            if self._builder is not None and name == self._open_name:
                b = self._builder
                for j in slots:
                    local = int(arr[j]) - first
                    if b.flags[local] & FLAG_TILED:
                        out[j] = self._assemble_tiled(b.payloads[local])
                    else:
                        out[j] = codec.decode(b.payloads[local],
                                              tuple(b.shapes[local]), dt)
                continue
            tasks.append((o, name, first, slots))

        def fetch_task(task):
            o, name, first, slots = task
            key = self._chunk_key(name)
            locals_ = sorted({int(arr[j]) - first for j in slots})
            t0 = time.perf_counter()
            header, payloads, nbytes, nreq = self._fetch_chunk_payloads(
                key, name, o, locals_, engine, ranged)
            return header, payloads, nbytes, nreq, time.perf_counter() - t0

        lookahead: Optional[Any] = None
        pipeline = len(tasks) > 1
        try:
            for i, task in enumerate(tasks):
                if lookahead is not None:
                    header, payloads, nbytes, nreq, dt_io = lookahead.result()
                    lookahead = None
                else:
                    header, payloads, nbytes, nreq, dt_io = fetch_task(task)
                if pipeline and i + 1 < len(tasks):
                    # overlap the next chunk's fetch with this chunk's decode
                    lookahead = engine.submit(fetch_task, tasks[i + 1])
                t1 = time.perf_counter()
                _o, _name, first, slots = task
                for j in slots:
                    local = int(arr[j]) - first
                    payload = payloads[local]
                    if header.is_tiled(local):
                        out[j] = self._assemble_tiled(payload)
                    else:
                        out[j] = codec.decode(payload, header.shapes[local],
                                              dt)
                if io_stats is not None:
                    io_stats["io_s"] = io_stats.get("io_s", 0.0) + dt_io
                    io_stats["cpu_s"] = (io_stats.get("cpu_s", 0.0)
                                         + time.perf_counter() - t1)
                    io_stats["bytes"] = io_stats.get("bytes", 0) + nbytes
                    io_stats["requests"] = io_stats.get("requests", 0) + nreq
        finally:
            if lookahead is not None:
                lookahead.cancel()
        return out  # type: ignore[return-value]

    def _fetch_chunk_payloads(self, key: str, cname: str, chunk_ord: int,
                              locals_: List[int], engine: "fetch.FetchEngine",
                              ranged: Optional[bool]):
        """(header, {local: payload}, new_bytes, n_requests) for one chunk."""
        blob = engine.resident(key)
        if blob is None:
            # a deliberate prefetch is coming: wait rather than duplicate it
            blob = engine.wait_inflight(key)
        if blob is not None:
            header = self._header_cache.get(key)
            if header is None:
                header = chunklib.parse_header(blob)
                self._header_cache[key] = header
            return (header,
                    {l: blob[slice(*header.byte_range(l))] for l in locals_},
                    0, 0)
        header = self._header_cache.get(key)
        if ranged is None:
            full = self._full_get_cheaper(key, cname, chunk_ord, locals_,
                                          header, engine)
        else:
            full = not ranged
        if full:
            blob = engine.fetch_full(key)
            header = chunklib.parse_header(blob)
            self._header_cache[key] = header
            return (header,
                    {l: blob[slice(*header.byte_range(l))] for l in locals_},
                    len(blob), 1)
        counters: Dict[str, int] = {"requests": 0, "bytes": 0}
        header = self._header_of(key, True, counters=counters)
        ranges = [header.byte_range(l) for l in locals_]
        payloads = engine.fetch_ranges(key, ranges, counters=counters)
        return (header, dict(zip(locals_, payloads)),
                counters["bytes"], counters["requests"])

    def _full_get_cheaper(self, key: str, cname: str, chunk_ord: int,
                          locals_: List[int], header: Optional[ChunkHeader],
                          engine: "fetch.FetchEngine") -> bool:
        """Cost-model choice between one whole-chunk GET and coalesced
        ranged reads for the ``locals_`` samples of one chunk."""
        if header is not None:
            object_bytes = header.header_size + header.nbytes_data()
            ranges = [header.byte_range(l) for l in locals_]
            spans, _ = coalesce_ranges(ranges, engine.est.gap_threshold())
            needed = sum(e - s for s, e in spans)
            return engine.plan_full_get(
                n_spans=len(spans), needed_bytes=needed,
                object_bytes=object_bytes, header_cached=True)
        st = self.stats.get(cname)
        n_in_chunk = self.encoder.samples_in(chunk_ord)
        if st is not None and st.count:
            # size from the stats sidecar; header estimated at ~26 B/sample
            object_bytes = st.nbytes + 56 + 26 * n_in_chunk
            needed = int(object_bytes * len(locals_) / max(n_in_chunk, 1))
            runs = 1 + sum(b - a > 1
                           for a, b in zip(locals_, locals_[1:]))
            return engine.plan_full_get(
                n_spans=runs, needed_bytes=needed,
                object_bytes=object_bytes, header_cached=False)
        # size unknown (pre-stats dataset): legacy sparse-read heuristic
        return len(locals_) > 2

    def read_region(self, idx: int, region: Sequence[slice],
                    *, ranged: Optional[bool] = None) -> np.ndarray:
        """Partial sample read (§3.5): tiled samples fetch only needed tiles."""
        payload, shape, tiled = self._payload_of(idx, ranged=ranged)
        if tiled:
            desc = TileDescriptor.from_bytes(payload)
            need = tiles_for_region(desc, region)
            blobs = self._engine().fetch_many(
                [self._chunk_key(desc.chunk_names[f]) for f in need])
            payloads = {f: blobs[self._chunk_key(desc.chunk_names[f])]
                        for f in need}
            return assemble_region(desc, region, payloads)
        codec = get_codec(self.meta.codec)
        arr = codec.decode(payload, shape, np.dtype(self.meta.dtype))
        return arr[tuple(region)]

    def chunk_stats_of(self, chunk_ord: int) -> Optional[ChunkStats]:
        """Stats of chunk ``chunk_ord`` (live from the open builder when the
        chunk is still being written), or None when unknown — e.g. datasets
        created before the sidecar existed.  Never touches chunk payloads."""
        name = self.encoder.name_of(chunk_ord)
        if self._builder is not None and name == self._open_name:
            return self._builder.stats_snapshot()
        return self.stats.get(name)

    def shape_of(self, idx: int) -> Tuple[int, ...]:
        """Sample shape without decoding payload (header-only metadata read)."""
        chunk_name, local = self.encoder.lookup(idx)
        if self._builder is not None and chunk_name == self._open_name:
            return tuple(self._builder.shapes[local])
        key = self._chunk_key(chunk_name)
        return tuple(self._header_of(key, self.vc.storage.kind == "s3").shapes[local])

    def __getitem__(self, item):
        if isinstance(item, (int, np.integer)):
            return self.read(int(item))
        if isinstance(item, slice):
            return self.read_batch(range(*item.indices(len(self))))
        if isinstance(item, (list, np.ndarray)):
            return self.read_batch([int(i) for i in item])
        raise TypeError(f"bad index {item!r}")

    def numpy(self) -> np.ndarray:
        """Stack into one ndarray (requires fixed shape)."""
        if any(d is None for d in self.shape[1:]):
            raise ValueError(f"tensor {self.name!r} is ragged; use [] access")
        if len(self) == 0:
            return np.zeros((0,), dtype=self.meta.dtype)
        return np.stack(self.read_batch(np.arange(len(self))))

    def text(self, idx: int) -> str:
        return self.read(idx).tobytes().decode()

    # ---------------------------------------------------------- maintenance
    def rechunk(self) -> int:
        """Rewrite all chunks at optimal sizes (§3.5 layout fix); returns #chunks."""
        self.vc.require_writable()
        samples = [(self._payload_of(i), self.sample_ids[i]) for i in range(len(self))]
        # drop current-version chunks we own
        for name in self.encoder.chunk_names():
            if name in self.vc.chunk_set(self.vc.current_id, self.name):
                try:
                    key = self.vc.resolve_chunk_key(self.name, name, None)
                    self.vc.storage.delete(key)
                    self._discard_cached(key)
                except StorageError:
                    pass
                self.vc.forget_chunk(self.name, name)
        self.encoder = ChunkEncoder()
        self._builder, self._open_name = None, None
        self._header_cache.clear()
        ids = []
        for (payload, shape, tiled), sid in samples:
            b = self._ensure_open(len(payload))
            was_empty = b.num_samples == 0
            b.append_raw(payload, shape, FLAG_TILED if tiled else 0)
            if was_empty:
                self.encoder.register_chunk(self._open_name, 1)
            else:
                self.encoder.extend_last(1)
            ids.append(sid)
        self.sample_ids = ids
        self._dirty = True
        self.flush()
        return self.encoder.num_chunks
