"""Tiling of over-sized samples (§3.4).

If a single encoded sample exceeds the max chunk size (large aerial /
microscopy images), the sample is split into a grid of tiles across its
spatial dimensions; each tile becomes its own chunk.  The sample's slot in
the parent chunk then holds a JSON *tile descriptor* (FLAG_TILED) instead of
payload bytes.  Partial reads (TQL crops, §3.5 range access) fetch only the
intersecting tiles.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from .codecs import get_codec


@dataclass
class TileDescriptor:
    sample_shape: Tuple[int, ...]
    tile_shape: Tuple[int, ...]
    grid_shape: Tuple[int, ...]
    chunk_names: List[str]          # row-major over the grid
    dtype: str
    codec: str

    def to_bytes(self) -> bytes:
        return json.dumps({
            "sample_shape": self.sample_shape, "tile_shape": self.tile_shape,
            "grid_shape": self.grid_shape, "chunk_names": self.chunk_names,
            "dtype": self.dtype, "codec": self.codec,
        }).encode()

    @classmethod
    def from_bytes(cls, data: bytes) -> "TileDescriptor":
        d = json.loads(data.decode())
        return cls(tuple(d["sample_shape"]), tuple(d["tile_shape"]),
                   tuple(d["grid_shape"]), list(d["chunk_names"]),
                   d["dtype"], d["codec"])

    def num_tiles(self) -> int:
        return int(np.prod(self.grid_shape)) if self.grid_shape else 1

    def tile_slices(self, flat_idx: int) -> Tuple[slice, ...]:
        coords = np.unravel_index(flat_idx, self.grid_shape)
        return tuple(
            slice(c * t, min((c + 1) * t, s))
            for c, t, s in zip(coords, self.tile_shape, self.sample_shape))


def plan_tile_shape(shape: Sequence[int], itemsize: int, max_bytes: int) -> Tuple[int, ...]:
    """Choose a tile shape whose raw size fits ``max_bytes``.

    Halve the largest dims first (keeps tiles near-square across spatial
    dims — good locality for crops), until the tile fits.
    """
    tile = [max(1, int(s)) for s in shape]
    budget = max(1, max_bytes)
    while int(np.prod(tile)) * itemsize > budget:
        j = int(np.argmax(tile))
        if tile[j] == 1:
            break
        tile[j] = (tile[j] + 1) // 2
    return tuple(tile)


def split_into_tiles(arr: np.ndarray, tile_shape: Sequence[int]) -> Tuple[Tuple[int, ...], List[np.ndarray]]:
    grid = tuple(math.ceil(s / t) for s, t in zip(arr.shape, tile_shape))
    tiles: List[np.ndarray] = []
    for flat in range(int(np.prod(grid)) if grid else 1):
        coords = np.unravel_index(flat, grid) if grid else ()
        sl = tuple(slice(c * t, min((c + 1) * t, s))
                   for c, t, s in zip(coords, tile_shape, arr.shape))
        tiles.append(np.ascontiguousarray(arr[sl]))
    return grid, tiles


def assemble_from_tiles(desc: TileDescriptor, tile_payloads: Sequence[bytes]) -> np.ndarray:
    """Full-sample reassembly from per-tile codec payloads (row-major)."""
    codec = get_codec(desc.codec)
    out = np.zeros(desc.sample_shape, dtype=np.dtype(desc.dtype))
    for flat, payload in enumerate(tile_payloads):
        sl = desc.tile_slices(flat)
        tshape = tuple(s.stop - s.start for s in sl)
        out[sl] = codec.decode(payload, tshape, np.dtype(desc.dtype))
    return out


def tiles_for_region(desc: TileDescriptor, region: Sequence[slice]) -> List[int]:
    """Flat tile indices intersecting ``region`` (per-dim slices, step=1)."""
    lo = []
    hi = []
    for d, (t, s) in enumerate(zip(desc.tile_shape, desc.sample_shape)):
        sl = region[d] if d < len(region) else slice(None)
        start, stop, step = sl.indices(s)
        if step != 1:
            # conservative: cover the full extent for strided access
            start, stop = min(start, stop), max(start, stop)
        if stop <= start:
            return []
        lo.append(start // t)
        hi.append((stop - 1) // t)
    idxs: List[int] = []
    ranges = [range(a, b + 1) for a, b in zip(lo, hi)]

    def rec(dim: int, coords: List[int]) -> None:
        if dim == len(ranges):
            idxs.append(int(np.ravel_multi_index(coords, desc.grid_shape)))
            return
        for c in ranges[dim]:
            rec(dim + 1, coords + [c])

    rec(0, [])
    return idxs


def assemble_region(desc: TileDescriptor, region: Sequence[slice],
                    tile_payloads: dict) -> np.ndarray:
    """Assemble only ``region`` from the given {flat_tile_idx: payload} map."""
    codec = get_codec(desc.codec)
    starts = [region[d].indices(s)[0] if d < len(region) else 0
              for d, s in enumerate(desc.sample_shape)]
    stops = [region[d].indices(s)[1] if d < len(region) else s
             for d, s in enumerate(desc.sample_shape)]
    out_shape = tuple(max(0, b - a) for a, b in zip(starts, stops))
    out = np.zeros(out_shape, dtype=np.dtype(desc.dtype))
    for flat, payload in tile_payloads.items():
        tsl = desc.tile_slices(flat)
        tshape = tuple(s.stop - s.start for s in tsl)
        tile = codec.decode(payload, tshape, np.dtype(desc.dtype))
        src = []
        dst = []
        for d in range(len(out_shape)):
            a = max(starts[d], tsl[d].start)
            b = min(stops[d], tsl[d].stop)
            if b <= a:
                break
            src.append(slice(a - tsl[d].start, b - tsl[d].start))
            dst.append(slice(a - starts[d], b - starts[d]))
        else:
            out[tuple(dst)] = tile[tuple(src)]
    return out
