"""Tensor Query Language (§4.3): SQL + NumPy-style tensor ops, compiled to a
computational graph executed on numpy or delegated to XLA via jax."""

from .ast_nodes import Query
from .executor import Executor, execute_query
from .functions import register_function
from .lexer import TQLSyntaxError
from .parser import parse, parse_expression

__all__ = ["Executor", "Query", "TQLSyntaxError", "execute_query", "parse",
           "parse_expression", "register_function"]
