"""Tensor Query Language (§4.3): SQL + NumPy-style tensor ops, compiled to a
computational graph executed on numpy or delegated to XLA via jax."""

from .ast_nodes import Aggregate, Query
from .executor import Executor, execute_query
from .functions import register_function
from .lexer import TQLSyntaxError
from .parser import parse, parse_expression
from .planner import Interval, ScanPlan, interval_from_stats, plan_where

__all__ = ["Aggregate", "Executor", "Interval", "Query", "ScanPlan",
           "TQLSyntaxError", "execute_query", "interval_from_stats", "parse",
           "parse_expression", "plan_where", "register_function"]
