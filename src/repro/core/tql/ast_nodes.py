"""TQL abstract syntax tree (§4.3)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple, Union


class Node:
    def walk(self):
        yield self
        for f in self.__dataclass_fields__:  # type: ignore[attr-defined]
            v = getattr(self, f)
            for item in (v if isinstance(v, (list, tuple)) else [v]):
                if isinstance(item, Node):
                    yield from item.walk()

    def find(self, cls):
        """Yield every descendant (including self) of the given node type."""
        for n in self.walk():
            if isinstance(n, cls):
                yield n

    def calls(self, name: str) -> bool:
        """True if any Call node in the subtree invokes ``name`` (upper-cased
        match; used by the scan planner to detect RANDOM() and friends)."""
        return any(c.name.upper() == name.upper() for c in self.find(Call))


@dataclass
class Literal(Node):
    value: Any  # int | float | str | bool | None


@dataclass
class TensorRef(Node):
    name: str


@dataclass
class ListExpr(Node):
    items: List[Node]


@dataclass
class UnaryOp(Node):
    op: str  # '-' | 'not'
    operand: Node


@dataclass
class BinOp(Node):
    op: str  # + - * / % == != > >= < <= and or
    left: Node
    right: Node


@dataclass
class Call(Node):
    name: str
    args: List[Node]


@dataclass
class SliceSpec(Node):
    start: Optional[Node]
    stop: Optional[Node]
    step: Optional[Node]
    is_slice: bool  # False => single-index subscript


@dataclass
class Index(Node):
    base: Node
    parts: List[SliceSpec]


#: aggregate functions recognised in SELECT items (GROUP BY queries and
#: all-aggregate ungrouped selects).  These are *query-level* folds over
#: every element of every row in a group -- distinct from the per-row
#: element reductions of the same name in :mod:`.functions` (``SUM(x)``
#: in a WHERE clause still reduces one sample; ``MEAN`` stays per-row,
#: the aggregate spelling of the arithmetic mean is ``AVG``).
AGGREGATE_FUNCS = ("COUNT", "SUM", "MIN", "MAX", "AVG")


@dataclass
class Aggregate(Node):
    """A resolved aggregate SELECT item: COUNT() / SUM(x) / MIN(x) /
    MAX(x) / AVG(x).  ``arg`` is None only for COUNT."""
    func: str
    arg: Optional[Node] = None


@dataclass
class SelectItem(Node):
    expr: Node           # may be Literal('*') for star
    alias: Optional[str]

    @property
    def is_star(self) -> bool:
        return isinstance(self.expr, Literal) and self.expr.value == "*"


@dataclass
class Query(Node):
    items: List[SelectItem]
    source: str = "dataset"
    version: Optional[str] = None
    where: Optional[Node] = None
    group_by: Optional[List[Node]] = None
    order_by: Optional[Node] = None
    order_desc: bool = False
    arrange_by: Optional[Node] = None
    sample_by: Optional[Node] = None
    sample_replace: bool = True
    limit: Optional[int] = None
    offset: int = 0

    @property
    def is_aggregate(self) -> bool:
        """True when the query runs the aggregation path: it has a GROUP BY
        clause, or every SELECT item is a bare aggregate call (ungrouped
        scalar aggregation, e.g. ``SELECT COUNT(), MAX(x) FROM ds``)."""
        return self.group_by is not None or any(
            isinstance(it.expr, Aggregate) for it in self.items)

    def referenced_tensors(self) -> List[str]:
        names = []
        for n in self.find(TensorRef):
            if n.name not in names:
                names.append(n.name)
        return names
