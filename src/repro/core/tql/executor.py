"""TQL execution (§4.3): streaming chunk-group evaluation on the scan
pipeline.

The parsed query becomes a computational graph of tensor operations
evaluated over a dataset view, in the unified pipeline order **plan →
schedule → prefetch → stream-decode** (:mod:`repro.core.pipeline`):

1. **plan** — :func:`~.planner.plan_where` classifies chunk groups
   prune/sure/verify from scan statistics (manifest-first: on a committed
   dataset this costs zero tensor binds and zero storage requests);
2. **schedule** — the verify tail becomes a :class:`ScanPipeline` chunk-
   group schedule in verdict order;
3. **prefetch** — while group ``k`` decodes, the pipeline hands group
   ``k+1``'s chunks to :meth:`FetchEngine.prefetch`, byte-bounded so the
   scan never evicts its own staged blobs;
4. **stream-decode** — the WHERE predicate evaluates per chunk group as
   blobs arrive, instead of stacking whole columns first: peak memory is
   one chunk group, not one column set, and fetch overlaps evaluation.

Two evaluation engines per group:

* **vectorized** — when every referenced tensor is fixed-shape, the
  group's columns are stacked and the whole expression evaluates as array
  math.  With ``engine="jax"`` the expression graph is jitted through XLA —
  the paper's "execution of the query can be delegated to external tensor
  computation frameworks" (§4.3).
* **row-wise** — always-correct fallback (ragged tensors, UDFs without a
  batched form, CONTAINS over text, ...).

Both paths, and the streaming vs. whole-view execution modes, produce
byte-identical result sets (predicates are row-local; ``RANDOM()``
disables streaming because it draws from a view-wide stream).

``ORDER BY key LIMIT k [OFFSET m]`` runs as a **top-k scan** on the same
pipeline (:meth:`Executor._order_limit_topk`): chunk groups are ordered by
their best achievable key bound (planner intervals over the chunk
statistics), streamed best-bound-first with the prefetch window following
that priority, and the stream terminates as soon as no remaining group's
bound can beat or tie the running (m+k)-th-element cutoff — the last
whole-column stacking in the read path is gone.  Skipped groups are never
fetched; results stay byte-identical to the legacy sort (``stream=False``).

``GROUP BY`` (and ungrouped all-aggregate selects) run as a **streaming
aggregation** on the same pipeline (:meth:`Executor._aggregate`): each
chunk group folds per-group *partial* aggregates (count / sum / min /
max / mean-as-sum+count, NaN-skipping) into a bounded hash of group
states, so peak memory is one chunk group plus the group-state table —
never a whole column.  Chunk groups that fully cover their chunks and
have exact statistics are answered straight from :class:`ChunkStats`
(the ``sum``/``lo``/``hi``/element-count fields) with **zero payload
fetches** — the soundness gates live in :mod:`repro.core.chunks`; the
rest fall back to fetch+fold.  ``stream=False`` keeps a whole-view fold
for A/B equivalence (float sums may differ in the last ulp from the
streamed fold's per-group accumulation order; COUNT/MIN/MAX are exact
either way).

Clause order matches the paper's example: WHERE → GROUP BY aggregation →
ORDER BY → ARRANGE BY (stable regroup) → SAMPLE BY → LIMIT/OFFSET →
SELECT projections.
"""

from __future__ import annotations

import math
import threading
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .. import telemetry
from ..chunks import _hi_bound, _lo_bound
from ..pipeline import ScanPipeline
from ..views import DatasetView
from .ast_nodes import (Aggregate, BinOp, Call, Index, ListExpr, Literal,
                        Node, Query, SelectItem, SliceSpec, TensorRef,
                        UnaryOp)
from .functions import get_function
from .parser import parse
from .planner import (ScanPlan, _referenced, group_key_intervals, plan_where)


class Unvectorizable(Exception):
    pass


class _NonScalarKeys(Exception):
    """Sharded top-k found non-scalar sort keys mid-stream: abort the
    pushdown and let the legacy whole-view sort run."""


def _truthy(x: Any) -> bool:
    a = np.asarray(x)
    if a.size == 0:
        return False
    return bool(np.all(a))


def _query_seed(text: str) -> int:
    return zlib.crc32(text.encode()) & 0xFFFFFFFF


# --------------------------------------------------------------------- row
class RowContext:
    def __init__(self, view: DatasetView, executor: "Executor") -> None:
        self.view = view
        self.executor = executor
        self.i = -1
        self._cache: Dict[str, Any] = {}

    def bind(self, i: int) -> "RowContext":
        self.i = i
        self._cache.clear()
        return self

    def get(self, name: str) -> Any:
        if name not in self._cache:
            if name in self.view.derived:
                self._cache[name] = self.view.derived[name][self.i]
            else:
                self._cache[name] = self.view._base_tensor(name).read(
                    int(self.view.indices[self.i]))
        return self._cache[name]

    def has_tensor(self, name: str) -> bool:
        return name in self.view.derived or name in self.view.tensor_names


def eval_row(node: Node, ctx: RowContext) -> Any:
    if isinstance(node, Literal):
        return node.value
    if isinstance(node, TensorRef):
        return ctx.get(node.name)
    if isinstance(node, ListExpr):
        return np.asarray([eval_row(e, ctx) for e in node.items])
    if isinstance(node, UnaryOp):
        v = eval_row(node.operand, ctx)
        return (not _truthy(v)) if node.op == "not" else -np.asarray(v)
    if isinstance(node, BinOp):
        if node.op == "and":
            return _truthy(eval_row(node.left, ctx)) and _truthy(eval_row(node.right, ctx))
        if node.op == "or":
            return _truthy(eval_row(node.left, ctx)) or _truthy(eval_row(node.right, ctx))
        l, r = eval_row(node.left, ctx), eval_row(node.right, ctx)
        if node.op == "in":
            return bool(np.isin(np.asarray(l), np.asarray(r)).all())
        return _APPLY[node.op](np.asarray(l), np.asarray(r))
    if isinstance(node, Index):
        base = np.asarray(eval_row(node.base, ctx))
        return base[tuple(_subscript(p, ctx) for p in node.parts)]
    if isinstance(node, Call):
        if node.name == "RANDOM":
            return float(ctx.executor.rng.random())
        spec = get_function(node.name)
        args = []
        for a in node.args:
            v = eval_row(a, ctx)
            # the paper's Fig-4 passes tensor paths as string literals:
            # IOU(boxes, "training/boxes") — resolve to the row's value.
            if isinstance(v, str) and isinstance(a, Literal) and ctx.has_tensor(v):
                v = ctx.get(v)
            args.append(v)
        return spec.row(*args)
    raise TypeError(f"cannot evaluate {node!r}")


def _subscript(p: SliceSpec, ctx: RowContext):
    if p.is_slice:
        f = lambda e: None if e is None else int(np.asarray(eval_row(e, ctx)))
        return slice(f(p.start), f(p.stop), f(p.step))
    return int(np.asarray(eval_row(p.start, ctx)))


_APPLY = {
    "+": lambda a, b: a + b, "-": lambda a, b: a - b, "*": lambda a, b: a * b,
    "/": lambda a, b: a / b, "%": lambda a, b: a % b,
    "==": lambda a, b: a == b, "!=": lambda a, b: a != b,
    ">": lambda a, b: a > b, ">=": lambda a, b: a >= b,
    "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
}


# ---------------------------------------------------------------- vectorized
class VectorEval:
    """Batched evaluation over stacked columns; raises Unvectorizable to
    signal fallback.  ``xp`` is numpy or jax.numpy."""

    def __init__(self, view: DatasetView, seed: int, engine: str = "numpy") -> None:
        self.view = view
        self.engine = engine
        self.seed = seed
        self._cols: Dict[str, np.ndarray] = {}
        if engine == "jax":
            import jax.numpy as jnp  # deferred; numpy engine has no jax dep
            self.xp = jnp
        else:
            self.xp = np

    def column(self, name: str) -> np.ndarray:
        if name not in self._cols:
            if name in self.view.derived:
                vals = self.view.derived[name]
                shapes = {np.asarray(v).shape for v in vals}
                if len(shapes) > 1:
                    raise Unvectorizable(name)
                self._cols[name] = np.stack([np.asarray(v) for v in vals]) \
                    if vals else np.zeros((0,))
            else:
                t = self.view._base_tensor(name)
                if any(d is None for d in t.shape[1:]):
                    raise Unvectorizable(f"ragged tensor {name}")
                # batched fetch: one coalesced request per chunk (§3.5)
                vals = t.read_batch(self.view.indices)
                self._cols[name] = (np.stack(vals) if vals
                                    else np.zeros((0,) + tuple(t.shape[1:]),
                                                  dtype=t.meta.dtype))
        return self._cols[name]

    def eval(self, node: Node) -> np.ndarray:
        cols = {r.name: self.column(r.name) for r in node.walk()
                if isinstance(r, TensorRef)}
        if self.engine == "jax":
            import jax

            @jax.jit
            def run(cs):
                return self._eval(node, cs, self.xp)

            return np.asarray(run({k: self.xp.asarray(v) for k, v in cols.items()}))
        return np.asarray(self._eval(node, cols, np))

    def _eval(self, node: Node, cols: Dict[str, Any], xp) -> Any:
        if isinstance(node, Literal):
            if isinstance(node.value, str):
                raise Unvectorizable("string literal")
            return node.value
        if isinstance(node, TensorRef):
            return cols[node.name]
        if isinstance(node, ListExpr):
            vals = [self._eval(e, cols, xp) for e in node.items]
            if any(hasattr(v, "ndim") and getattr(v, "ndim", 0) > 0 for v in vals):
                raise Unvectorizable("list of arrays")
            return xp.asarray(vals)
        if isinstance(node, UnaryOp):
            v = self._eval(node.operand, cols, xp)
            return xp.logical_not(v) if node.op == "not" else -v
        if isinstance(node, BinOp):
            l = self._eval(node.left, cols, xp)
            r = self._eval(node.right, cols, xp)
            if node.op == "and":
                return xp.logical_and(l, r)
            if node.op == "or":
                return xp.logical_or(l, r)
            if node.op == "in":
                raise Unvectorizable("IN")
            return _APPLY[node.op](l, r)
        if isinstance(node, Index):
            base = self._eval(node.base, cols, xp)
            has_batch = isinstance(node.base, (TensorRef, Index, Call))
            subs: List[Any] = [slice(None)] if has_batch else []
            for p in node.parts:
                subs.append(self._subscript(p, cols, xp))
            return base[tuple(subs)]
        if isinstance(node, Call):
            if node.name == "RANDOM":
                n = len(self.view.indices)
                return xp.asarray(np.random.default_rng(self.seed).random(n))
            spec = get_function(node.name)
            if spec.batched is None:
                raise Unvectorizable(node.name)
            args = [self._eval(a, cols, xp) for a in node.args]
            return spec.batched(*args, xp=xp)
        raise Unvectorizable(str(node))

    def _subscript(self, p: SliceSpec, cols, xp):
        def const(e):
            if e is None:
                return None
            v = self._eval(e, cols, xp)
            if hasattr(v, "ndim") and getattr(v, "ndim", 0) > 0:
                raise Unvectorizable("non-scalar subscript")
            return int(v)
        if p.is_slice:
            return slice(const(p.start), const(p.stop), const(p.step))
        return const(p.start)


# ----------------------------------------------------------------- top-k plan
class _GroupBound:
    """Best achievable ORDER BY rank of one chunk group, from the planner's
    key interval.  The legacy comparator sorts ascending by (key, position)
    with NaN last, then fully reverses for DESC — so NaN-capable (or
    unknown) groups rank *first* under DESC, and 'beats or ties the cutoff'
    reduces to a one-sided bound test against the interval edge, widened by
    :func:`_lo_bound`/:func:`_hi_bound` so float rounding of an int64
    cutoff can never skip a group that could still tie."""

    __slots__ = ("desc", "nan_best", "val")

    def __init__(self, iv, desc: bool) -> None:
        self.desc = desc
        known_vals = iv.known and iv.has_values
        if desc:
            self.nan_best = (not iv.known) or iv.has_nan
            self.val = float(iv.hi) if known_vals else (
                -math.inf if iv.known else math.inf)
        else:
            self.nan_best = False
            self.val = float(iv.lo) if known_vals else (
                math.inf if iv.known else -math.inf)

    @property
    def sort_key(self) -> Tuple[int, float]:
        if self.desc:
            return (0 if self.nan_best else 1, -self.val)
        return (0, self.val)

    def can_beat(self, cutoff) -> bool:
        """May some row of this group rank at or above the k-th candidate?
        Ties count: an equal key at another position can displace it."""
        try:
            cut_nan = math.isnan(float(cutoff))
        except (TypeError, OverflowError):
            cut_nan = False
        if self.desc:
            if self.nan_best:
                return True     # NaN keys rank first under DESC
            if cut_nan:
                return False    # ...and numeric keys never reach them
            return self.val >= _lo_bound(cutoff)
        if cut_nan:
            return True         # any numeric key beats a NaN cutoff (ASC)
        return self.val <= _hi_bound(cutoff)


def _topk_select(keys: np.ndarray, pos: np.ndarray, k: int,
                 desc: bool) -> Tuple[np.ndarray, np.ndarray]:
    """First ``k`` (key, position) pairs under the legacy ORDER BY
    comparator, returned in final result order.  Restricting the comparator
    to any candidate subset preserves relative order, so merging per-group
    winners is exact: positions are re-sorted ascending first, making the
    stable argsort's tiebreak identical to the whole-view sort's."""
    po = np.argsort(pos, kind="stable")
    keys, pos = keys[po], pos[po]
    o = np.argsort(keys, kind="stable")
    if desc:
        o = o[::-1]
    o = o[:k]
    return keys[o], pos[o]


# ------------------------------------------------------------------ executor
def _substitute(node: Node, aliases: Dict[str, Node]) -> Node:
    """SQL alias support: replace TensorRef(alias) with its SELECT expr."""
    if isinstance(node, TensorRef) and node.name in aliases:
        return aliases[node.name]
    for f in getattr(node, "__dataclass_fields__", {}):
        v = getattr(node, f)
        if isinstance(v, Node):
            setattr(node, f, _substitute(v, aliases))
        elif isinstance(v, list):
            setattr(node, f, [_substitute(x, aliases) if isinstance(x, Node)
                              else x for x in v])
    return node


# -------------------------------------------------------------- aggregation
#: canonical grouping key for a NaN key value: one shared float object so
#: every NaN row lands in the same hash bucket (dict lookups hit on
#: identity before equality, and NaN != NaN would otherwise split groups)
_NAN_KEY = float("nan")

#: |lo|/|hi| bounds beyond this are unusable as MIN/MAX *values*: the
#: outward widening of ``_lo_bound``/``_hi_bound`` (sound for pruning)
#: may make them unequal to any element (see chunks.py soundness rules)
_EXACT_FLOAT_INT = float(2 ** 53)


def _canon_key(v) -> Any:
    """Hashable canonical form of one row's grouping-key value: 1-D uint8
    samples decode to the text htype's string (matching the str sketch
    domain), scalars become Python scalars (NaN canonicalized), anything
    larger becomes a tuple of its elements."""
    a = np.asarray(v)
    if a.dtype == np.uint8 and a.ndim == 1:
        return a.tobytes().decode(errors="replace")
    if a.size == 1:
        x = a.reshape(()).item()
        if isinstance(x, float) and math.isnan(x):
            return _NAN_KEY
        return x
    return tuple(a.ravel().tolist())


def _new_agg_state() -> dict:
    """Partial-aggregate state of one (group, aggregate) pair: mergeable
    across chunk groups and with stats-answered contributions.  ``sum``
    stays a Python number (exact int accumulation for integer tensors,
    float64 for floats); ``n`` counts non-NaN elements (AVG denominator);
    ``min``/``max`` are float64, None until a value is seen."""
    return {"rows": 0, "sum": 0, "n": 0, "min": None, "max": None}


def _flat_elements(vals, sel: np.ndarray) -> np.ndarray:
    """All elements of rows ``sel`` of a per-row value column, flattened
    (object columns hold ragged samples)."""
    if isinstance(vals, np.ndarray) and vals.dtype != object:
        return np.asarray(vals)[sel].reshape(-1)
    parts = [np.asarray(vals[int(i)]).ravel() for i in sel]
    if not parts:
        return np.empty(0)
    return parts[0] if len(parts) == 1 else np.concatenate(parts)


def _fold_flat(st: dict, flat: np.ndarray) -> None:
    """Fold a flat element array into a partial-aggregate state
    (NaN-skipping, like the stats accumulator)."""
    if flat.size == 0:
        return
    kind = flat.dtype.kind
    if kind == "f":
        flat = flat[~np.isnan(flat)]
        if flat.size == 0:
            return
        st["sum"] += float(np.sum(flat, dtype=np.float64))
    elif kind in "biu":
        st["sum"] += int(flat.sum(dtype=np.uint64 if kind == "u"
                                  else np.int64))
    else:
        raise TypeError(f"cannot aggregate values of dtype {flat.dtype}")
    st["n"] += int(flat.size)
    mn, mx = float(flat.min()), float(flat.max())
    st["min"] = mn if st["min"] is None else min(st["min"], mn)
    st["max"] = mx if st["max"] is None else max(st["max"], mx)


def _agg_result(func: str, st: dict):
    """Final value of one aggregate from its merged partial state, with
    the empty-input identities of :mod:`.functions`: COUNT/SUM of nothing
    are 0, MIN/MAX/AVG of nothing are NaN."""
    if func == "COUNT":
        return int(st["rows"])
    if func == "SUM":
        return st["sum"]
    if func == "AVG":
        return st["sum"] / st["n"] if st["n"] else float("nan")
    v = st["min"] if func == "MIN" else st["max"]
    return float("nan") if v is None else v


class Executor:
    """One query execution.

    **Sharded scan mode** (``shards`` > 1): the per-chunk-group WHERE and
    top-k loops are pure maps over chunk groups, so they run on
    :meth:`ScanPipeline.stream_sharded` — a worker-thread pool with
    groups assigned round-robin in plan order and results re-merged *in
    plan order*, which keeps masks and top-k selections byte-identical
    to the serial scan (scattering a mask is order-independent; the
    top-k merge applies the exact legacy comparator to a candidate set
    that only ever gains strictly-worse extras).  Top-k shards share one
    cutoff: each worker consults the freshest merged cutoff right before
    evaluating a group and skips it when its bound strictly cannot beat
    the cutoff — the shared cutoff only tightens as the merge advances,
    so a sharded skip is always a group the serial scan would also have
    skipped, and early termination still fires at the exact group the
    serial scan terminates on.  ``tenant`` tags the pipeline's
    prefetches for the engine's fair multi-tenant scheduler;
    ``scan_plan_hint`` (the serving tier's plan cache) skips
    ``plan_where`` entirely on a repeat query of an immutable version.
    """

    def __init__(self, query: Query, engine: str = "auto",
                 use_stats: bool = True,
                 stream: Optional[bool] = None,
                 shards: Optional[int] = None,
                 tenant: Optional[str] = None,
                 scan_plan_hint: Optional[ScanPlan] = None) -> None:
        self.query = query
        self.engine = engine
        self.use_stats = use_stats
        #: WHERE execution mode: None = auto (stream when the view spans
        #: multiple chunk groups), False = whole-view column stack (the
        #: pre-pipeline path, kept for A/B equivalence), True = force
        self.stream = stream
        self.shards = shards
        self.tenant = tenant
        self.scan_plan_hint = scan_plan_hint
        self.scan_plan: Optional[ScanPlan] = None  # set by run() when planned
        self.topk_plan: Optional[dict] = None      # set when top-k pushed down
        self.agg_plan: Optional[dict] = None       # set when aggregation ran
        self.seed = _query_seed(repr(query))
        self.rng = np.random.default_rng(self.seed)
        # Aggregate-valued aliases never substitute: an aggregate has no
        # per-row value, so referencing one from WHERE/ORDER/... is an
        # unknown-tensor error, not a silent HAVING.
        aliases = {it.alias: it.expr for it in query.items
                   if it.alias and not it.is_star
                   and not isinstance(it.expr, Aggregate)}
        if aliases:
            for attr in ("where", "order_by", "arrange_by", "sample_by"):
                node = getattr(query, attr)
                if node is not None:
                    setattr(query, attr, _substitute(node, aliases))
            if query.group_by is not None:
                query.group_by = [_substitute(k, aliases)
                                  for k in query.group_by]

    # evaluate an expression for every row of `view`, preferring vector path
    def eval_all(self, view: DatasetView, node: Node) -> np.ndarray:
        if self.engine in ("auto", "numpy", "jax"):
            try:
                ve = VectorEval(view, self.seed,
                                "jax" if self.engine == "jax" else "numpy")
                out = ve.eval(node)
                if out.ndim == 0:
                    out = np.broadcast_to(out, (len(view),))
                if len(out) == len(view):
                    return out
            except Unvectorizable:
                pass
            except Exception:
                if self.engine == "jax":
                    raise
        ctx = RowContext(view, self)
        vals = [eval_row(node, ctx.bind(i)) for i in range(len(view))]
        try:
            return np.asarray(vals)
        except ValueError:  # ragged per-row results (e.g. WHERE rag > 0)
            out = np.empty(len(vals), dtype=object)
            out[:] = vals
            return out

    def _where_mask(self, view: DatasetView, node: Node) -> np.ndarray:
        """Per-row WHERE mask, streamed per chunk group on the scan
        pipeline: group ``k+1``'s chunks prefetch while group ``k``
        evaluates, and only one group's columns are resident at a time.
        Falls back to the whole-view evaluation (:meth:`_mask_of`) when
        streaming is disabled, meaningless (single group, no base
        tensors) or unsound (``RANDOM()`` draws from a view-wide
        stream).  Both modes return byte-identical masks."""
        if self.stream is False or node.calls("RANDOM") or not len(view):
            return self._mask_of(view, node)
        names = [n for n in _referenced(node)
                 if n not in view.derived and n in view.tensor_names]
        if not names:
            return self._mask_of(view, node)
        pipe = ScanPipeline.for_query(view, names, owner=self,
                                      tenant=self.tenant)
        if pipe is None or (self.stream is None and pipe.n_groups <= 1):
            if pipe is not None:
                pipe.close()
            return self._mask_of(view, node)
        mask = np.zeros(len(view), dtype=bool)
        if self.shards is not None and self.shards > 1 and pipe.n_groups > 1:
            # sharded map: each group's sub-mask scatters into disjoint
            # positions, so evaluation order cannot change the result
            for _gi, positions, res in pipe.stream_sharded(
                    lambda pos, sub: self._mask_of(sub, node),
                    shards=self.shards):
                mask[positions] = res
        else:
            for positions, sub in pipe.stream():
                mask[positions] = self._mask_of(sub, node)
        return mask

    def _mask_of(self, view: DatasetView, node: Node) -> np.ndarray:
        """Per-row boolean mask under `_truthy` semantics (all elements true,
        empty is False) — the vectorized path must agree with the row path."""
        mask = self.eval_all(view, node)
        if mask.dtype == object:
            return np.asarray([_truthy(m)
                               for m in np.asarray(mask, dtype=object)])
        mask = mask.astype(bool)
        if mask.ndim > 1:
            if 0 in mask.shape[1:]:
                return np.zeros(len(mask), dtype=bool)
            mask = mask.all(axis=tuple(range(1, mask.ndim)))
        return mask

    # ------------------------------------------------------- ORDER BY / top-k
    def _order_keys(self, view: DatasetView, node: Node) -> np.ndarray:
        """Sort keys of ``view`` under ``node``.  Integer (and bool/float)
        keys keep their native dtype — casting to float64 mis-orders int64
        values above 2**53; only non-numeric results fall back to the
        legacy float64 coercion."""
        keys = np.asarray(self.eval_all(view, node))
        if keys.dtype == object or keys.dtype.kind not in "biuf":
            keys = keys.astype(np.float64)
        return keys

    def _order_limit_topk(self, view: DatasetView,
                          q: Query) -> Optional[DatasetView]:
        """``ORDER BY key LIMIT k [OFFSET m]`` as a top-k scan: chunk groups
        stream best-bound-first (bounds from :func:`group_key_intervals`)
        while a running (offset+limit)-th-element cutoff terminates the
        stream as soon as no remaining group's bound can beat or tie it.

        Returns the fully ordered-and-sliced view, or None when the legacy
        whole-column sort must run instead (no LIMIT, ARRANGE/SAMPLE BY
        downstream, ``stream=False``/``use_stats=False``, RANDOM() anywhere
        in the query — its stream is order-dependent — derived-only keys,
        or a single chunk group).  Selection is byte-identical to the
        legacy path: candidates merge under the exact comparator the legacy
        sort applies — stable ascending argsort by (key, position), fully
        reversed for DESC, NaN keys last ascending — and a group is skipped
        only when its bound is *strictly* worse than the cutoff, so ties
        (which can displace by position) are always streamed."""
        if (q.limit is None or q.arrange_by is not None
                or q.sample_by is not None or self.stream is False
                or not self.use_stats):
            return None
        k = int(q.limit) + int(q.offset)
        if k <= 0:
            return view[np.empty(0, dtype=np.int64)]
        if k >= len(view):
            return None  # every row ranks: nothing to skip
        if any(c.name.upper() == "RANDOM" for c in self.query.find(Call)):
            return None
        names = [n for n in _referenced(q.order_by)
                 if n not in view.derived and n in view.tensor_names]
        if not names:
            return None
        pipe = ScanPipeline.for_query(view, names, owner=self,
                                      tenant=self.tenant)
        if pipe is None or pipe.n_groups <= 1:
            if pipe is not None:
                pipe.close()
            return None
        desc = bool(q.order_desc)
        bounds = [_GroupBound(iv, desc)
                  for iv in group_key_intervals(view, pipe, q.order_by)]
        order = sorted(range(len(bounds)), key=lambda g: bounds[g].sort_key)
        pipe.reorder(order)  # prefetch window now follows bound priority
        bounds = [bounds[g] for g in order]
        if self.shards is not None and self.shards > 1:
            return self._topk_sharded(view, q, pipe, bounds, k, desc, names)
        k_keys: Optional[np.ndarray] = None
        k_pos = np.empty(0, dtype=np.int64)
        cutoff = None
        scanned = 0
        terminated = False
        it = pipe.stream()
        try:
            for gi, (positions, sub) in enumerate(it):
                if cutoff is not None and not bounds[gi].can_beat(cutoff):
                    terminated = True
                    break
                keys_g = self._order_keys(sub, q.order_by)
                if keys_g.ndim != 1 or len(keys_g) != len(positions):
                    return None  # non-scalar keys: legacy whole-view sort
                scanned += 1
                ck = keys_g if k_keys is None \
                    else np.concatenate([k_keys, keys_g])
                cp = np.concatenate([k_pos, positions])
                k_keys, k_pos = _topk_select(ck, cp, k, desc)
                if len(k_pos) >= k:
                    cutoff = k_keys[-1]
        finally:
            it.close()
        self.topk_plan = {
            "groups": pipe.n_groups, "groups_scanned": scanned,
            "groups_skipped": pipe.n_groups - scanned,
            "terminated_early": int(terminated),
            "k": k, "order_desc": int(desc), "tensors": list(names)}
        return view[k_pos[q.offset:]]

    def _topk_sharded(self, view: DatasetView, q: Query, pipe: ScanPipeline,
                      bounds: List[_GroupBound], k: int, desc: bool,
                      names: List[str]) -> Optional[DatasetView]:
        """Shard-parallel tail of :meth:`_order_limit_topk`: workers
        evaluate group sort keys concurrently under one shared cutoff
        (checked freshest-first via ``skip``), while this thread merges
        candidates in plan order with the exact serial comparator —
        see the class docstring for the byte-parity argument."""
        lock = threading.Lock()
        shared = {"cutoff": None}

        def skip(gi: int) -> bool:
            with lock:
                c = shared["cutoff"]
            return c is not None and not bounds[gi].can_beat(c)

        def eval_keys(positions: np.ndarray, sub: DatasetView) -> np.ndarray:
            keys_g = self._order_keys(sub, q.order_by)
            if keys_g.ndim != 1 or len(keys_g) != len(positions):
                raise _NonScalarKeys()  # legacy whole-view sort takes over
            return keys_g

        k_keys: Optional[np.ndarray] = None
        k_pos = np.empty(0, dtype=np.int64)
        cutoff = None
        scanned = 0
        terminated = False
        it = pipe.stream_sharded(eval_keys, shards=self.shards, skip=skip)
        try:
            for gi, positions, keys_g in it:
                # a worker-side skip means the group's bound could not beat
                # an *earlier* (looser) cutoff — the serial scan, whose
                # cutoff here is at least as tight, terminates too
                if keys_g is None or (cutoff is not None
                                      and not bounds[gi].can_beat(cutoff)):
                    terminated = True
                    break
                scanned += 1
                ck = keys_g if k_keys is None \
                    else np.concatenate([k_keys, keys_g])
                cp = np.concatenate([k_pos, positions])
                k_keys, k_pos = _topk_select(ck, cp, k, desc)
                if len(k_pos) >= k:
                    cutoff = k_keys[-1]
                    with lock:
                        shared["cutoff"] = cutoff
        except _NonScalarKeys:
            return None
        finally:
            it.close()
        self.topk_plan = {
            "groups": pipe.n_groups, "groups_scanned": scanned,
            "groups_skipped": pipe.n_groups - scanned,
            "terminated_early": int(terminated), "shards": int(self.shards),
            "k": k, "order_desc": int(desc), "tensors": list(names)}
        return view[k_pos[q.offset:]]

    # --------------------------------------------------------- aggregation
    def _agg_output_items(self, q: Query) -> Tuple[
            List[Tuple[str, Tuple[str, int]]], List[Aggregate]]:
        """Resolve SELECT items of an aggregation query into output specs:
        ``(column_name, ("key", key_index) | ("agg", agg_index))`` plus the
        ordered aggregate list.  The parser validated shapes already; key
        matching mirrors its rules (structural repr, or alias/name against
        a TensorRef key)."""
        keys = q.group_by or []
        key_reprs = [repr(k) for k in keys]
        aggs: List[Aggregate] = []
        specs: List[Tuple[str, Tuple[str, int]]] = []
        used: set = set()
        for k, it in enumerate(q.items):
            if isinstance(it.expr, Aggregate):
                name = it.alias or it.expr.func.lower()
                spec = ("agg", len(aggs))
                aggs.append(it.expr)
            else:
                j = None
                r = repr(it.expr)
                if r in key_reprs:
                    j = key_reprs.index(r)
                else:
                    for kj, kn in enumerate(keys):
                        if isinstance(kn, TensorRef) and kn.name in (
                                it.alias, getattr(it.expr, "name", None)):
                            j = kj
                            break
                if j is None:  # unreachable post-parse; stay defensive
                    raise ValueError(
                        f"SELECT item {it!r} matches no GROUP BY key")
                name = it.alias or (it.expr.name
                                    if isinstance(it.expr, TensorRef)
                                    else f"col_{k}")
                spec = ("key", j)
            if name in used:
                name = f"col_{k}"
            used.add(name)
            specs.append((name, spec))
        return specs, aggs

    def _agg_group_from_stats(self, keys: List[Node], aggs: List[Aggregate],
                              recs: Dict[str, Any]) -> Optional[tuple]:
        """Key tuple of a chunk group answerable from statistics alone, or
        None when any gate fails (see the soundness rules in chunks.py).
        The caller already checked every record exists, is exact, and is
        fully covered by the group's rows."""
        for a in aggs:
            if a.func == "COUNT":
                continue
            if not isinstance(a.arg, TensorRef):
                return None
            rec = recs.get(a.arg.name)
            if rec is None:
                return None
            if a.func in ("SUM", "AVG") and rec.sum is None:
                return None
            if a.func in ("MIN", "MAX") and rec.lo is not None and (
                    abs(rec.lo) >= _EXACT_FLOAT_INT
                    or abs(rec.hi) >= _EXACT_FLOAT_INT):
                return None
        if not keys:
            return ()
        if len(keys) != 1 or not isinstance(keys[0], TensorRef):
            return None
        kr = recs.get(keys[0].name)
        if kr is None or not (kr.sketched and kr.dct is not None
                              and len(kr.dct) == 1 and kr.min_elems >= 1):
            return None  # key chunk not provably single-valued
        if kr.dom == "int":
            # scalar samples only: a multi-element sample would make the
            # row key a tuple, not the dictionary's one value
            if not (kr.min_elems == 1 and kr.n_elements == kr.count
                    and kr.nan_count == 0):
                return None
            return (int(kr.dct[0]),)
        if kr.dom == "str":  # text htype: one whole-sample string per row
            return (str(kr.dct[0]),)
        return None

    def _agg_apply_stats(self, states: List[dict], aggs: List[Aggregate],
                         recs: Dict[str, Any], nrows: int) -> None:
        """Merge one stats-answered chunk group into the group states."""
        for a, st in zip(aggs, states):
            st["rows"] += nrows
            if a.func == "COUNT":
                continue
            rec = recs[a.arg.name]
            if rec.lo is not None:
                st["min"] = rec.lo if st["min"] is None \
                    else min(st["min"], rec.lo)
                st["max"] = rec.hi if st["max"] is None \
                    else max(st["max"], rec.hi)
            nvalid = rec.n_elements - rec.nan_count
            if rec.sum is not None and nvalid > 0:
                st["sum"] += rec.sum
                st["n"] += nvalid

    def _agg_fold(self, sub: DatasetView, orig_positions: np.ndarray,
                  keys: List[Node], aggs: List[Aggregate],
                  states: Dict[tuple, List[dict]],
                  firsts: Dict[tuple, int]) -> None:
        """Fetch+fold one chunk group (or the whole view in legacy mode)
        into the group states.  Only ``sub``'s columns are resident."""
        n = len(sub)
        if not n:
            return
        if keys:
            cols = [self.eval_all(sub, kx) for kx in keys]
            bykey: Dict[tuple, List[int]] = {}
            for i in range(n):
                kt = tuple(_canon_key(c[i]) for c in cols)
                bykey.setdefault(kt, []).append(i)
        else:
            bykey = {(): list(range(n))}
        argcols: Dict[str, Any] = {}
        for a in aggs:
            if a.arg is not None and repr(a.arg) not in argcols:
                argcols[repr(a.arg)] = self.eval_all(sub, a.arg)
        for kt, rows in bykey.items():
            sel = np.asarray(rows, dtype=np.int64)
            sts = states.get(kt)
            if sts is None:
                sts = states[kt] = [_new_agg_state() for _ in aggs]
            fp = int(orig_positions[sel].min())
            if kt not in firsts or fp < firsts[kt]:
                firsts[kt] = fp
            for a, st in zip(aggs, sts):
                st["rows"] += len(sel)
                if a.func != "COUNT":
                    _fold_flat(st, _flat_elements(argcols[repr(a.arg)], sel))

    def _aggregate(self, view: DatasetView, q: Query) -> DatasetView:
        """GROUP BY / ungrouped aggregation over ``view``: stats-answered
        chunk groups contribute partials with zero payload fetches, the
        rest stream through the scan pipeline one chunk group at a time
        (module docstring).  Returns a derived-only view, one row per
        group in first-appearance (view) order — a single identity row
        for an ungrouped aggregate over an empty view."""
        specs, aggs = self._agg_output_items(q)
        keys = q.group_by or []
        names = []
        for node in list(keys) + [a.arg for a in aggs if a.arg is not None]:
            for nm in _referenced(node):
                if nm not in names and nm not in view.derived \
                        and nm in view.tensor_names:
                    names.append(nm)
        rand = any(c.name.upper() == "RANDOM" for c in q.find(Call))
        streamable = self.stream is not False and not rand
        unique_rows = len(np.unique(view.indices)) == len(view.indices)
        states: Dict[tuple, List[dict]] = {}
        firsts: Dict[tuple, int] = {}
        total_groups = answered = 0
        pipe = ScanPipeline.for_query(view, names, owner=self,
                                      tenant=self.tenant) \
            if streamable and names and len(view) else None
        fold_positions = np.arange(len(view), dtype=np.int64)
        if pipe is not None:
            total_groups = pipe.n_groups
            fold_parts: List[np.ndarray] = []
            if self.use_stats and unique_rows:
                srcs = {nm: view.scan_source(nm) for nm in pipe.names}
                for g in range(pipe.n_groups):
                    positions = pipe.group_positions(g)
                    recs: Dict[str, Any] = {}
                    for nm, o in zip(pipe.names, pipe.group_ords(g)):
                        rec = srcs[nm].stats_of(int(o))
                        # full coverage: every row of the chunk, exactly
                        # once (rows are globally unique) — partial
                        # coverage means the stats describe excluded rows
                        if rec is None or not rec.exact \
                                or rec.count != len(positions):
                            recs = {}
                            break
                        recs[nm] = rec
                    kt = self._agg_group_from_stats(keys, aggs, recs) \
                        if recs else None
                    if kt is None:
                        fold_parts.append(positions)
                        continue
                    answered += 1
                    sts = states.get(kt)
                    if sts is None:
                        sts = states[kt] = [_new_agg_state() for _ in aggs]
                    fp = int(positions.min())
                    if kt not in firsts or fp < firsts[kt]:
                        firsts[kt] = fp
                    self._agg_apply_stats(sts, aggs, recs, len(positions))
                pipe.close()
                fold_positions = np.sort(np.concatenate(fold_parts)) \
                    if fold_parts else np.empty(0, dtype=np.int64)
            else:
                pipe.close()
        # fetch+fold the remainder, streamed one chunk group at a time
        if len(fold_positions):
            sub = view[fold_positions] if len(fold_positions) != len(view) \
                else view
            fold_pipe = ScanPipeline.for_query(sub, names, owner=self,
                                               tenant=self.tenant) \
                if streamable and names else None
            if fold_pipe is not None and (self.stream or
                                          fold_pipe.n_groups > 1):
                if not total_groups:
                    total_groups = fold_pipe.n_groups
                for positions, gsub in fold_pipe.stream():
                    self._agg_fold(gsub, fold_positions[positions], keys,
                                   aggs, states, firsts)
            else:
                if fold_pipe is not None:
                    fold_pipe.close()
                if not total_groups:
                    total_groups = 1 if len(sub) else 0
                self._agg_fold(sub, fold_positions, keys, aggs, states,
                               firsts)
        if not keys and not states:  # empty input: one identity row
            states[()] = [_new_agg_state() for _ in aggs]
            firsts[()] = 0
        out_keys = sorted(states, key=lambda kt: firsts[kt])
        derived: Dict[str, List[Any]] = {}
        for name, (kind, j) in specs:
            if kind == "key":
                derived[name] = [kt[j] for kt in out_keys]
            else:
                derived[name] = [_agg_result(aggs[j].func, states[kt][j])
                                 for kt in out_keys]
        self.agg_plan = {
            "agg_rows": int(len(view)),
            "agg_groups": int(total_groups),
            "agg_groups_stats_answered": int(answered),
            "agg_groups_folded": int(total_groups - answered),
            "agg_out_groups": int(len(out_keys)),
            "grouped": int(bool(keys))}
        if self.scan_plan is not None:
            self.scan_plan.agg_groups_stats_answered = answered
        telemetry.registry().counter("tql.aggregates").inc()
        return DatasetView(view.dataset,
                           np.arange(len(out_keys), dtype=np.int64),
                           view.node_id, tensors=[], derived=derived)

    def run(self, base: DatasetView) -> DatasetView:
        q = self.query
        view = base
        # WHERE ------------------------------------------------------------
        if q.where is not None:
            if len(view):
                with telemetry.span("query.plan") as plan_sp:
                    # a cached plan (serving tier, immutable committed
                    # version) makes the repeat query pay zero planner work
                    if self.scan_plan_hint is not None and self.use_stats:
                        plan = self.scan_plan_hint
                    else:
                        plan = plan_where(view, q.where) if self.use_stats \
                            else None
                    self.scan_plan = plan
                    if plan is not None:
                        plan_sp.set(effective=int(plan.effective),
                                    **{k: v for k, v in plan.report().items()
                                       if isinstance(v, (int, float))})
                with telemetry.span("query.where"):
                    if plan is not None and plan.effective:
                        # stats pushdown: pruned chunks are never fetched;
                        # only 'verify' rows pay predicate evaluation,
                        # streamed per chunk group in verdict order on the
                        # scan pipeline
                        parts = [plan.sure]
                        if len(plan.verify):
                            sub = view[plan.verify]
                            keep = self._where_mask(sub, q.where)
                            parts.append(plan.verify[np.nonzero(keep)[0]])
                        view = view[np.sort(
                            np.concatenate(parts)).astype(np.int64)]
                    else:
                        keep = self._where_mask(view, q.where)
                        view = view[np.nonzero(keep)[0]]
        # GROUP BY / aggregation ---------------------------------------------
        if q.is_aggregate:
            with telemetry.span("query.aggregate") as agg_sp:
                out = self._aggregate(view, q)
                if self.agg_plan:
                    agg_sp.set(**{k: v for k, v in self.agg_plan.items()
                                  if isinstance(v, (int, float))})
            # LIMIT/OFFSET slice the aggregated group rows; ORDER/ARRANGE/
            # SAMPLE were rejected at parse time, projection already done
            if q.offset:
                out = out[q.offset:]
            if q.limit is not None:
                out = out[: q.limit]
            report = self.scan_plan.report() if self.scan_plan is not None \
                else {}
            report.update(self.agg_plan or {})
            out.scan_plan = report
            return out
        # ORDER BY ----------------------------------------------------------
        if q.order_by is not None and len(view):
            with telemetry.span("query.topk") as topk_sp:
                topk = self._order_limit_topk(view, q)
                if self.topk_plan is not None:
                    topk_sp.set(**{k: v for k, v in self.topk_plan.items()
                                   if isinstance(v, (int, float))})
            if topk is not None:
                # ORDER BY + LIMIT/OFFSET fully applied by the top-k plan
                view = topk
                q = Query(**{**q.__dict__, "limit": None, "offset": 0})
            else:
                keys = self._order_keys(view, q.order_by)
                order = np.argsort(keys, kind="stable")
                if q.order_desc:
                    order = order[::-1]
                view = view[order]
        # ARRANGE BY (stable regroup; §4.3 example) ---------------------------
        if q.arrange_by is not None and len(view):
            keys = self.eval_all(view, q.arrange_by)
            try:
                karr = np.asarray(keys, dtype=np.float64)
            except (TypeError, ValueError):
                karr = np.asarray([str(k) for k in keys])
            view = view[np.argsort(karr, kind="stable")]
        # SAMPLE BY (weighted; deeplake-style) -------------------------------
        if q.sample_by is not None and len(view):
            w = np.clip(np.asarray(self.eval_all(view, q.sample_by),
                                   dtype=np.float64), 0, None)
            w = np.nan_to_num(w)
            n = q.limit if q.limit is not None else len(view)
            if w.sum() <= 0:
                w = np.ones(len(view))
            idx = self.rng.choice(len(view), size=n, replace=q.sample_replace,
                                  p=w / w.sum())
            view = view[idx]
            q = Query(**{**q.__dict__, "limit": None, "offset": 0})
        # LIMIT/OFFSET --------------------------------------------------------
        if q.offset:
            view = view[q.offset:]
        if q.limit is not None:
            view = view[: q.limit]
        # SELECT ---------------------------------------------------------------
        out = self._project(view)
        if self.scan_plan is not None:
            out.scan_plan = self.scan_plan.report()
        if self.topk_plan is not None:
            out.topk_plan = dict(self.topk_plan)
        return out

    def _project(self, view: DatasetView) -> DatasetView:
        items = self.query.items
        if len(items) == 1 and items[0].is_star:
            return view
        keep_raw: List[str] = []
        derived: Dict[str, List[Any]] = {}
        for k, item in enumerate(items):
            if item.is_star:
                keep_raw = list(view.tensor_names)
                continue
            if isinstance(item.expr, TensorRef) and item.alias in (None,
                                                                   item.expr.name):
                keep_raw.append(item.expr.name)
                continue
            name = item.alias or f"col_{k}"
            if len(view):
                vals = self.eval_all(view, item.expr)
                derived[name] = ([v for v in vals] if vals.dtype != object
                                 else list(vals))
            else:
                derived[name] = []
        merged = dict(view.derived)
        merged.update(derived)
        return DatasetView(view.dataset, view.indices, view.node_id,
                           tensors=keep_raw, derived=merged)


def execute_query(source: Union["Dataset", DatasetView], text: str,
                  engine: str = "auto", use_stats: bool = True,
                  stream: Optional[bool] = None,
                  shards: Optional[int] = None,
                  tenant: Optional[str] = None) -> DatasetView:
    q = parse(text)
    if isinstance(source, DatasetView):
        if q.version:
            raise ValueError("VERSION not allowed when querying a view")
        base = source
    else:
        node_id = source.vc.resolve_ref(q.version) if q.version else None
        base = DatasetView.full(source, node_id=node_id)
    aliases = {it.alias for it in q.items if it.alias}
    missing = [t for t in q.referenced_tensors()
               if t not in base.tensor_names and t not in aliases]
    if missing:
        raise KeyError(f"query references unknown tensors: {missing}")
    return Executor(q, engine=engine, use_stats=use_stats, stream=stream,
                    shards=shards, tenant=tenant).run(base)
