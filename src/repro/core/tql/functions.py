"""TQL built-in tensor functions (§4.3).

Each function has a row implementation (single sample, numpy) and optionally a
batched implementation (leading batch axis) used by the vectorized/XLA
execution path.  ``register_function`` lets applications add UDFs — the paper's
example uses ``IOU`` as a user-defined function.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np


@dataclass
class FunctionSpec:
    name: str
    row: Callable[..., object]
    batched: Optional[Callable[..., object]] = None  # operates on (N, ...) arrays


_REGISTRY: Dict[str, FunctionSpec] = {}


def register_function(name: str, row: Callable[..., object],
                      batched: Optional[Callable[..., object]] = None) -> None:
    _REGISTRY[name.upper()] = FunctionSpec(name.upper(), row, batched)


def get_function(name: str) -> FunctionSpec:
    try:
        return _REGISTRY[name.upper()]
    except KeyError:
        raise ValueError(f"unknown TQL function {name!r}; have {sorted(_REGISTRY)}") \
            from None


def _reduce_all(np_reduce, empty):
    """Whole-sample reduction with an explicit empty-input identity.

    SUM of nothing is 0; MEAN/STD/MIN/MAX of nothing have no value and
    yield NaN (np.min/np.max raise on empty input, so the identity must
    be supplied rather than delegated).  The batched path returns the
    same identity per empty row so both execution paths agree.
    """
    def row(x):
        a = np.asarray(x)
        return np_reduce(a) if a.size else empty

    def batched(x, xp=np):
        a = x
        if a.ndim <= 1:
            return a
        if 0 in a.shape[1:]:  # every row's reduced slice is empty
            return xp.full((a.shape[0],), empty, dtype="float64")
        return np_reduce(a, axis=tuple(range(1, a.ndim)))
    return row, batched


def _pairwise_iou(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """IoU matrix between (N,4) and (M,4) LTRB boxes."""
    a = np.atleast_2d(np.asarray(a, dtype=np.float64))
    b = np.atleast_2d(np.asarray(b, dtype=np.float64))
    lt = np.maximum(a[:, None, :2], b[None, :, :2])
    rb = np.minimum(a[:, None, 2:4], b[None, :, 2:4])
    wh = np.clip(rb - lt, 0, None)
    inter = wh[..., 0] * wh[..., 1]
    area_a = np.clip(a[:, 2] - a[:, 0], 0, None) * np.clip(a[:, 3] - a[:, 1], 0, None)
    area_b = np.clip(b[:, 2] - b[:, 0], 0, None) * np.clip(b[:, 3] - b[:, 1], 0, None)
    union = area_a[:, None] + area_b[None, :] - inter
    return np.where(union > 0, inter / np.maximum(union, 1e-12), 0.0)


def iou(a, b) -> float:
    """Mean best-match IoU between two box sets (the paper's Fig-4 UDF)."""
    a = np.asarray(a)
    b = np.asarray(b)
    if a.size == 0 or b.size == 0:
        return 0.0
    m = _pairwise_iou(a, b)
    return float(m.max(axis=1).mean())


def normalize_boxes(boxes, crop) -> np.ndarray:
    """Re-express LTRB boxes in the coordinates of ``crop`` = [l, t, r, b],
    scaled to [0, 1] (the paper's Fig-4 NORMALIZE)."""
    boxes = np.atleast_2d(np.asarray(boxes, dtype=np.float64))
    l, t, r, b = [float(v) for v in np.asarray(crop).reshape(-1)[:4]]
    w, h = max(r - l, 1e-12), max(b - t, 1e-12)
    out = boxes.copy()
    out[:, 0::2] = (out[:, 0::2] - l) / w
    out[:, 1::2] = (out[:, 1::2] - t) / h
    return np.clip(out, 0.0, 1.0)


def contains(haystack, needle) -> bool:
    h = np.asarray(haystack)
    if h.dtype == np.uint8 and isinstance(needle, str):  # text htype
        return needle in h.tobytes().decode(errors="replace")
    return bool(np.isin(np.asarray(needle), h).all())


def _register_defaults() -> None:
    for name, red, empty in (("MEAN", np.mean, np.nan), ("SUM", np.sum, 0.0),
                             ("MAX", np.max, np.nan), ("MIN", np.min, np.nan),
                             ("STD", np.std, np.nan)):
        row, batched = _reduce_all(red, empty)
        register_function(name, row, batched)
    register_function("ABS", lambda x: np.abs(np.asarray(x)),
                      lambda x, xp=np: xp.abs(x))
    register_function("SQRT", lambda x: np.sqrt(np.asarray(x, dtype=np.float64)),
                      lambda x, xp=np: xp.sqrt(x))
    register_function("CLIP", lambda x, lo, hi: np.clip(np.asarray(x), lo, hi),
                      lambda x, lo, hi, xp=np: xp.clip(x, lo, hi))
    register_function(
        "ANY", lambda x: bool(np.any(x)),
        lambda x, xp=np: xp.any(x, axis=tuple(range(1, x.ndim))) if x.ndim > 1 else x)
    register_function(
        "ALL", lambda x: bool(np.all(x)),
        lambda x, xp=np: xp.all(x, axis=tuple(range(1, x.ndim))) if x.ndim > 1 else x)
    register_function(
        "L2_NORM", lambda x: float(np.linalg.norm(np.asarray(x, dtype=np.float64))),
        lambda x, xp=np: xp.sqrt(xp.sum(
            (x.astype("float32") if hasattr(x, "astype") else x) ** 2,
            axis=tuple(range(1, x.ndim)))))
    register_function("SHAPE", lambda x: np.asarray(np.asarray(x).shape, dtype=np.int64))
    register_function("IOU", iou)
    register_function("NORMALIZE", normalize_boxes)
    register_function("CONTAINS", contains)
    register_function("LEN", lambda x: int(np.asarray(x).shape[0])
                      if np.asarray(x).ndim else 1)
    register_function("CAST_FLOAT", lambda x: np.asarray(x, dtype=np.float32),
                      lambda x, xp=np: x.astype("float32"))
    # RANDOM is handled specially by the executor (deterministic per query).


_register_defaults()
