"""TQL lexer: regex tokenizer, case-insensitive keywords."""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Iterator, List

KEYWORDS = {
    "SELECT", "FROM", "WHERE", "ORDER", "ARRANGE", "SAMPLE", "GROUP", "BY",
    "AS", "LIMIT", "OFFSET", "VERSION", "ASC", "DESC", "AND", "OR", "NOT",
    "TRUE", "FALSE", "NULL", "REPLACE", "IN",
}

_TOKEN_RE = re.compile(r"""
    (?P<WS>\s+)
  | (?P<COMMENT>--[^\n]*)
  | (?P<NUMBER>\d+\.\d*(?:[eE][+-]?\d+)?|\.\d+(?:[eE][+-]?\d+)?|\d+(?:[eE][+-]?\d+)?)
  | (?P<STRING>"(?:[^"\\]|\\.)*"|'(?:[^'\\]|\\.)*')
  | (?P<IDENT>[A-Za-z_][A-Za-z_0-9]*(?:/[A-Za-z_0-9]+)*)
  | (?P<OP>==|!=|<>|>=|<=|[-+*/%(),\[\]:><.])
""", re.VERBOSE)


@dataclass
class Token:
    kind: str   # KEYWORD | IDENT | NUMBER | STRING | OP | EOF
    value: str
    pos: int


class TQLSyntaxError(ValueError):
    pass


def tokenize(text: str) -> List[Token]:
    out: List[Token] = []
    pos = 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            raise TQLSyntaxError(f"bad character {text[pos]!r} at {pos}")
        kind = m.lastgroup
        val = m.group()
        pos = m.end()
        if kind in ("WS", "COMMENT"):
            continue
        if kind == "IDENT" and val.upper() in KEYWORDS:
            out.append(Token("KEYWORD", val.upper(), m.start()))
        elif kind == "STRING":
            body = val[1:-1]
            body = body.replace('\\"', '"').replace("\\'", "'").replace("\\\\", "\\")
            out.append(Token("STRING", body, m.start()))
        elif kind == "OP" and val == "<>":
            out.append(Token("OP", "!=", m.start()))
        else:
            out.append(Token(kind, val, m.start()))
    out.append(Token("EOF", "", len(text)))
    return out
