"""TQL recursive-descent parser (§4.3).

Grammar (SQL subset + tensor extensions):

    query      := SELECT items FROM IDENT [VERSION STRING] clause* EOF
    clause     := WHERE expr
                | GROUP BY expr (',' expr)*
                | ORDER BY expr [ASC|DESC]
                | ARRANGE BY expr
                | SAMPLE BY expr [REPLACE (TRUE|FALSE)]
                | LIMIT INT [OFFSET INT]
    items      := '*' | expr [AS IDENT] (',' expr [AS IDENT])*
    expr       := or_expr
    or_expr    := and_expr (OR and_expr)*
    and_expr   := not_expr (AND not_expr)*
    not_expr   := NOT not_expr | cmp_expr
    cmp_expr   := add_expr ((==|!=|>|>=|<|<=|IN) add_expr)?
    add_expr   := mul_expr (('+'|'-') mul_expr)*
    mul_expr   := unary (('*'|'/'|'%') unary)*
    unary      := '-' unary | postfix
    postfix    := primary ('[' subscripts ']')*
    primary    := NUMBER | STRING | TRUE|FALSE|NULL | list | call | tensor | '(' expr ')'
    subscripts := sub (',' sub)* ; sub := expr | [expr]':'[expr][':'[expr]]

Each clause may appear at most once (a duplicate raises
:class:`TQLSyntaxError` instead of silently overwriting the first), and
``LIMIT``/``OFFSET`` operands must be non-negative integers.

GROUP BY is genuine aggregation, not a reorder alias:

* with ``GROUP BY k1, k2, ...`` every SELECT item must be either a
  grouping-key expression (by structure, or by alias naming a key) or a
  bare aggregate call -- ``COUNT()`` (zero arguments), ``SUM(e)``,
  ``MIN(e)``, ``MAX(e)``, ``AVG(e)`` (exactly one argument).  There is no
  HAVING clause, so that key-coverage rule is the whole validation story.
* an ungrouped query whose SELECT items are *all* aggregate calls
  (``SELECT COUNT(), MAX(x) FROM ds``) aggregates the entire result set
  into a single row.
* aggregation queries reject ``ORDER BY`` / ``ARRANGE BY`` / ``SAMPLE
  BY`` (there are no per-row results left to order or sample); ``LIMIT``
  and ``OFFSET`` apply to the aggregated group rows.

Outside aggregation SELECT items, ``SUM``/``MIN``/``MAX``/``MEAN`` keep
their per-row element-reduction meaning from :mod:`.functions` (e.g. in a
WHERE clause, ``SUM(x) > 4`` reduces one sample at a time); ``AVG`` and
zero-argument ``COUNT`` exist only as aggregates.
"""

from __future__ import annotations

from typing import List, Optional

from .ast_nodes import (AGGREGATE_FUNCS, Aggregate, BinOp, Call, Index,
                        ListExpr, Literal, Node, Query, SelectItem, SliceSpec,
                        TensorRef, UnaryOp)
from .lexer import Token, TQLSyntaxError, tokenize


class Parser:
    def __init__(self, text: str) -> None:
        self.tokens = tokenize(text)
        self.i = 0

    # ------------------------------------------------------------- plumbing
    @property
    def cur(self) -> Token:
        return self.tokens[self.i]

    def advance(self) -> Token:
        tok = self.cur
        self.i += 1
        return tok

    def accept(self, kind: str, value: Optional[str] = None) -> Optional[Token]:
        if self.cur.kind == kind and (value is None or self.cur.value == value):
            return self.advance()
        return None

    def expect(self, kind: str, value: Optional[str] = None) -> Token:
        tok = self.accept(kind, value)
        if tok is None:
            raise TQLSyntaxError(
                f"expected {value or kind} at pos {self.cur.pos}, got "
                f"{self.cur.value!r}")
        return tok

    def kw(self, word: str) -> Optional[Token]:
        return self.accept("KEYWORD", word)

    # --------------------------------------------------------------- query
    def parse_query(self) -> Query:
        self.expect("KEYWORD", "SELECT")
        items = self.parse_select_items()
        q = Query(items=items)
        if self.kw("FROM"):
            q.source = self.expect("IDENT").value
            if self.kw("VERSION"):
                q.version = self.expect("STRING").value
        seen: set = set()

        def once(clause: str) -> None:
            if clause in seen:
                raise TQLSyntaxError(f"duplicate {clause} clause")
            seen.add(clause)

        while True:
            if self.kw("WHERE"):
                once("WHERE")
                q.where = self.parse_expr()
            elif self.kw("GROUP"):
                once("GROUP BY")
                self.expect("KEYWORD", "BY")
                q.group_by = [self.parse_expr()]
                while self.accept("OP", ","):
                    q.group_by.append(self.parse_expr())
            elif self.kw("ORDER"):
                once("ORDER BY")
                self.expect("KEYWORD", "BY")
                q.order_by = self.parse_expr()
                if self.kw("DESC"):
                    q.order_desc = True
                else:
                    self.kw("ASC")
            elif self.kw("ARRANGE"):
                once("ARRANGE BY")
                self.expect("KEYWORD", "BY")
                q.arrange_by = self.parse_expr()
            elif self.kw("SAMPLE"):
                once("SAMPLE BY")
                self.expect("KEYWORD", "BY")
                q.sample_by = self.parse_expr()
                if self.kw("REPLACE"):
                    tok = self.expect("KEYWORD")
                    if tok.value not in ("TRUE", "FALSE"):
                        raise TQLSyntaxError("REPLACE expects TRUE or FALSE")
                    q.sample_replace = tok.value == "TRUE"
            elif self.kw("LIMIT"):
                once("LIMIT")
                q.limit = self._int_operand("LIMIT")
                if self.kw("OFFSET"):
                    q.offset = self._int_operand("OFFSET")
            else:
                break
        self.expect("EOF")
        self._resolve_aggregation(q)
        return q

    def _int_operand(self, clause: str) -> int:
        tok = self.expect("NUMBER")
        v = float(tok.value)
        if not v.is_integer():
            raise TQLSyntaxError(
                f"{clause} expects an integer, got {tok.value!r}")
        n = int(v)
        if n < 0:
            raise TQLSyntaxError(f"{clause} must be non-negative, got {n}")
        return n

    # --------------------------------------------------- aggregation shaping
    def _resolve_aggregation(self, q: Query) -> None:
        """Turn aggregate SELECT items into :class:`Aggregate` nodes and
        validate the aggregation query shape (see module docstring)."""

        def as_aggregate(expr: Node) -> Optional[Aggregate]:
            if not (isinstance(expr, Call) and expr.name in AGGREGATE_FUNCS):
                return None
            if expr.name == "COUNT":
                if expr.args:
                    raise TQLSyntaxError(
                        "COUNT() takes no arguments (it counts group rows)")
                return Aggregate("COUNT", None)
            if len(expr.args) != 1:
                raise TQLSyntaxError(
                    f"aggregate {expr.name} takes exactly one argument")
            return Aggregate(expr.name, expr.args[0])

        aggs = [as_aggregate(it.expr) for it in q.items]
        grouped = q.group_by is not None
        # Ungrouped: aggregation semantics only when EVERY item is an
        # aggregate call (so `SELECT SUM(x) ...` aggregates but the legacy
        # per-row `SELECT MEAN(images) / 255.0 ...` is untouched).  COUNT()
        # can only be an aggregate, so a mixed ungrouped select is an error.
        if not grouped:
            if all(a is not None for a in aggs) and aggs:
                for it, a in zip(q.items, aggs):
                    it.expr = a
            elif any(a is not None and a.func == "COUNT" for a in aggs):
                raise TQLSyntaxError(
                    "COUNT() outside GROUP BY requires every SELECT item "
                    "to be an aggregate")
            return

        # GROUP BY present: items are aggregates or grouping keys.
        if q.arrange_by is not None:
            raise TQLSyntaxError("ARRANGE BY cannot be combined with GROUP BY")
        if q.order_by is not None:
            raise TQLSyntaxError("ORDER BY cannot be combined with GROUP BY")
        if q.sample_by is not None:
            raise TQLSyntaxError("SAMPLE BY cannot be combined with GROUP BY")
        keys = q.group_by
        key_reprs = {repr(k) for k in keys}
        key_names = {k.name for k in keys if isinstance(k, TensorRef)}
        for it, a in zip(q.items, aggs):
            if it.is_star:
                raise TQLSyntaxError("SELECT * cannot be used with GROUP BY")
            if a is not None:
                it.expr = a
                continue
            matches_key = (repr(it.expr) in key_reprs
                           or (it.alias is not None and it.alias in key_names)
                           or (isinstance(it.expr, TensorRef)
                               and it.expr.name in key_names))
            if not matches_key:
                raise TQLSyntaxError(
                    "non-aggregated SELECT item must appear in GROUP BY "
                    f"(offending item: {it.alias or repr(it.expr)})")

    def parse_select_items(self) -> List[SelectItem]:
        if self.accept("OP", "*"):
            return [SelectItem(Literal("*"), None)]
        items = [self.parse_select_item()]
        while self.accept("OP", ","):
            items.append(self.parse_select_item())
        return items

    def parse_select_item(self) -> SelectItem:
        expr = self.parse_expr()
        alias = None
        if self.kw("AS"):
            alias = self.expect("IDENT").value
        return SelectItem(expr, alias)

    # ----------------------------------------------------------- expressions
    def parse_expr(self) -> Node:
        return self.parse_or()

    def parse_or(self) -> Node:
        left = self.parse_and()
        while self.kw("OR"):
            left = BinOp("or", left, self.parse_and())
        return left

    def parse_and(self) -> Node:
        left = self.parse_not()
        while self.kw("AND"):
            left = BinOp("and", left, self.parse_not())
        return left

    def parse_not(self) -> Node:
        if self.kw("NOT"):
            return UnaryOp("not", self.parse_not())
        return self.parse_cmp()

    def parse_cmp(self) -> Node:
        left = self.parse_add()
        for op in ("==", "!=", ">=", "<=", ">", "<"):
            if self.accept("OP", op):
                return BinOp(op, left, self.parse_add())
        if self.kw("IN"):
            return BinOp("in", left, self.parse_add())
        return left

    def parse_add(self) -> Node:
        left = self.parse_mul()
        while True:
            if self.accept("OP", "+"):
                left = BinOp("+", left, self.parse_mul())
            elif self.accept("OP", "-"):
                left = BinOp("-", left, self.parse_mul())
            else:
                return left

    def parse_mul(self) -> Node:
        left = self.parse_unary()
        while True:
            if self.accept("OP", "*"):
                left = BinOp("*", left, self.parse_unary())
            elif self.accept("OP", "/"):
                left = BinOp("/", left, self.parse_unary())
            elif self.accept("OP", "%"):
                left = BinOp("%", left, self.parse_unary())
            else:
                return left

    def parse_unary(self) -> Node:
        if self.accept("OP", "-"):
            return UnaryOp("-", self.parse_unary())
        return self.parse_postfix()

    def parse_postfix(self) -> Node:
        node = self.parse_primary()
        while self.accept("OP", "["):
            parts = [self.parse_subscript()]
            while self.accept("OP", ","):
                parts.append(self.parse_subscript())
            self.expect("OP", "]")
            node = Index(node, parts)
        return node

    def parse_subscript(self) -> SliceSpec:
        start = stop = step = None
        if self.cur.kind == "OP" and self.cur.value == ":":
            pass
        else:
            start = self.parse_expr()
        if self.accept("OP", ":"):
            if not (self.cur.kind == "OP" and self.cur.value in (":", "]", ",")):
                stop = self.parse_expr()
            if self.accept("OP", ":"):
                if not (self.cur.kind == "OP" and self.cur.value in ("]", ",")):
                    step = self.parse_expr()
            return SliceSpec(start, stop, step, True)
        return SliceSpec(start, None, None, False)

    def parse_primary(self) -> Node:
        tok = self.cur
        if tok.kind == "NUMBER":
            self.advance()
            text = tok.value
            return Literal(float(text) if any(c in text for c in ".eE") else int(text))
        if tok.kind == "STRING":
            self.advance()
            return Literal(tok.value)
        if tok.kind == "KEYWORD" and tok.value in ("TRUE", "FALSE", "NULL"):
            self.advance()
            return Literal({"TRUE": True, "FALSE": False, "NULL": None}[tok.value])
        if self.accept("OP", "("):
            e = self.parse_expr()
            self.expect("OP", ")")
            return e
        if self.accept("OP", "["):
            items = []
            if not (self.cur.kind == "OP" and self.cur.value == "]"):
                items.append(self.parse_expr())
                while self.accept("OP", ","):
                    items.append(self.parse_expr())
            self.expect("OP", "]")
            return ListExpr(items)
        if tok.kind == "IDENT":
            self.advance()
            if self.accept("OP", "("):
                args = []
                if not (self.cur.kind == "OP" and self.cur.value == ")"):
                    args.append(self.parse_expr())
                    while self.accept("OP", ","):
                        args.append(self.parse_expr())
                self.expect("OP", ")")
                return Call(tok.value.upper(), args)
            return TensorRef(tok.value)
        raise TQLSyntaxError(f"unexpected {tok.value!r} at pos {tok.pos}")


def parse(text: str) -> Query:
    return Parser(text).parse_query()


def parse_expression(text: str) -> Node:
    p = Parser(text)
    node = p.parse_expr()
    p.expect("EOF")
    return node
