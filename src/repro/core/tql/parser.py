"""TQL recursive-descent parser (§4.3).

Grammar (SQL subset + tensor extensions):

    query      := SELECT items FROM IDENT [VERSION STRING]
                  [WHERE expr] [ORDER BY expr [ASC|DESC]] [ARRANGE BY expr]
                  [SAMPLE BY expr [REPLACE (TRUE|FALSE)]]
                  [LIMIT NUMBER [OFFSET NUMBER]]
    items      := '*' | expr [AS IDENT] (',' expr [AS IDENT])*
    expr       := or_expr
    or_expr    := and_expr (OR and_expr)*
    and_expr   := not_expr (AND not_expr)*
    not_expr   := NOT not_expr | cmp_expr
    cmp_expr   := add_expr ((==|!=|>|>=|<|<=|IN) add_expr)?
    add_expr   := mul_expr (('+'|'-') mul_expr)*
    mul_expr   := unary (('*'|'/'|'%') unary)*
    unary      := '-' unary | postfix
    postfix    := primary ('[' subscripts ']')*
    primary    := NUMBER | STRING | TRUE|FALSE|NULL | list | call | tensor | '(' expr ')'
    subscripts := sub (',' sub)* ; sub := expr | [expr]':'[expr][':'[expr]]
"""

from __future__ import annotations

from typing import List, Optional

from .ast_nodes import (BinOp, Call, Index, ListExpr, Literal, Node, Query,
                        SelectItem, SliceSpec, TensorRef, UnaryOp)
from .lexer import Token, TQLSyntaxError, tokenize


class Parser:
    def __init__(self, text: str) -> None:
        self.tokens = tokenize(text)
        self.i = 0

    # ------------------------------------------------------------- plumbing
    @property
    def cur(self) -> Token:
        return self.tokens[self.i]

    def advance(self) -> Token:
        tok = self.cur
        self.i += 1
        return tok

    def accept(self, kind: str, value: Optional[str] = None) -> Optional[Token]:
        if self.cur.kind == kind and (value is None or self.cur.value == value):
            return self.advance()
        return None

    def expect(self, kind: str, value: Optional[str] = None) -> Token:
        tok = self.accept(kind, value)
        if tok is None:
            raise TQLSyntaxError(
                f"expected {value or kind} at pos {self.cur.pos}, got "
                f"{self.cur.value!r}")
        return tok

    def kw(self, word: str) -> Optional[Token]:
        return self.accept("KEYWORD", word)

    # --------------------------------------------------------------- query
    def parse_query(self) -> Query:
        self.expect("KEYWORD", "SELECT")
        items = self.parse_select_items()
        q = Query(items=items)
        if self.kw("FROM"):
            q.source = self.expect("IDENT").value
            if self.kw("VERSION"):
                q.version = self.expect("STRING").value
        if self.kw("WHERE"):
            q.where = self.parse_expr()
        if self.kw("GROUP"):
            # GROUP BY is aliased to ARRANGE BY (TQL has no aggregation joins)
            self.expect("KEYWORD", "BY")
            q.arrange_by = self.parse_expr()
        if self.kw("ORDER"):
            self.expect("KEYWORD", "BY")
            q.order_by = self.parse_expr()
            if self.kw("DESC"):
                q.order_desc = True
            else:
                self.kw("ASC")
        if self.kw("ARRANGE"):
            self.expect("KEYWORD", "BY")
            q.arrange_by = self.parse_expr()
        if self.kw("SAMPLE"):
            self.expect("KEYWORD", "BY")
            q.sample_by = self.parse_expr()
            if self.kw("REPLACE"):
                tok = self.expect("KEYWORD")
                if tok.value not in ("TRUE", "FALSE"):
                    raise TQLSyntaxError("REPLACE expects TRUE or FALSE")
                q.sample_replace = tok.value == "TRUE"
        if self.kw("LIMIT"):
            q.limit = int(float(self.expect("NUMBER").value))
            if self.kw("OFFSET"):
                q.offset = int(float(self.expect("NUMBER").value))
        self.expect("EOF")
        return q

    def parse_select_items(self) -> List[SelectItem]:
        if self.accept("OP", "*"):
            return [SelectItem(Literal("*"), None)]
        items = [self.parse_select_item()]
        while self.accept("OP", ","):
            items.append(self.parse_select_item())
        return items

    def parse_select_item(self) -> SelectItem:
        expr = self.parse_expr()
        alias = None
        if self.kw("AS"):
            alias = self.expect("IDENT").value
        return SelectItem(expr, alias)

    # ----------------------------------------------------------- expressions
    def parse_expr(self) -> Node:
        return self.parse_or()

    def parse_or(self) -> Node:
        left = self.parse_and()
        while self.kw("OR"):
            left = BinOp("or", left, self.parse_and())
        return left

    def parse_and(self) -> Node:
        left = self.parse_not()
        while self.kw("AND"):
            left = BinOp("and", left, self.parse_not())
        return left

    def parse_not(self) -> Node:
        if self.kw("NOT"):
            return UnaryOp("not", self.parse_not())
        return self.parse_cmp()

    def parse_cmp(self) -> Node:
        left = self.parse_add()
        for op in ("==", "!=", ">=", "<=", ">", "<"):
            if self.accept("OP", op):
                return BinOp(op, left, self.parse_add())
        if self.kw("IN"):
            return BinOp("in", left, self.parse_add())
        return left

    def parse_add(self) -> Node:
        left = self.parse_mul()
        while True:
            if self.accept("OP", "+"):
                left = BinOp("+", left, self.parse_mul())
            elif self.accept("OP", "-"):
                left = BinOp("-", left, self.parse_mul())
            else:
                return left

    def parse_mul(self) -> Node:
        left = self.parse_unary()
        while True:
            if self.accept("OP", "*"):
                left = BinOp("*", left, self.parse_unary())
            elif self.accept("OP", "/"):
                left = BinOp("/", left, self.parse_unary())
            elif self.accept("OP", "%"):
                left = BinOp("%", left, self.parse_unary())
            else:
                return left

    def parse_unary(self) -> Node:
        if self.accept("OP", "-"):
            return UnaryOp("-", self.parse_unary())
        return self.parse_postfix()

    def parse_postfix(self) -> Node:
        node = self.parse_primary()
        while self.accept("OP", "["):
            parts = [self.parse_subscript()]
            while self.accept("OP", ","):
                parts.append(self.parse_subscript())
            self.expect("OP", "]")
            node = Index(node, parts)
        return node

    def parse_subscript(self) -> SliceSpec:
        start = stop = step = None
        if self.cur.kind == "OP" and self.cur.value == ":":
            pass
        else:
            start = self.parse_expr()
        if self.accept("OP", ":"):
            if not (self.cur.kind == "OP" and self.cur.value in (":", "]", ",")):
                stop = self.parse_expr()
            if self.accept("OP", ":"):
                if not (self.cur.kind == "OP" and self.cur.value in ("]", ",")):
                    step = self.parse_expr()
            return SliceSpec(start, stop, step, True)
        return SliceSpec(start, None, None, False)

    def parse_primary(self) -> Node:
        tok = self.cur
        if tok.kind == "NUMBER":
            self.advance()
            text = tok.value
            return Literal(float(text) if any(c in text for c in ".eE") else int(text))
        if tok.kind == "STRING":
            self.advance()
            return Literal(tok.value)
        if tok.kind == "KEYWORD" and tok.value in ("TRUE", "FALSE", "NULL"):
            self.advance()
            return Literal({"TRUE": True, "FALSE": False, "NULL": None}[tok.value])
        if self.accept("OP", "("):
            e = self.parse_expr()
            self.expect("OP", ")")
            return e
        if self.accept("OP", "["):
            items = []
            if not (self.cur.kind == "OP" and self.cur.value == "]"):
                items.append(self.parse_expr())
                while self.accept("OP", ","):
                    items.append(self.parse_expr())
            self.expect("OP", "]")
            return ListExpr(items)
        if tok.kind == "IDENT":
            self.advance()
            if self.accept("OP", "("):
                args = []
                if not (self.cur.kind == "OP" and self.cur.value == ")"):
                    args.append(self.parse_expr())
                    while self.accept("OP", ","):
                        args.append(self.parse_expr())
                self.expect("OP", ")")
                return Call(tok.value.upper(), args)
            return TensorRef(tok.value)
        raise TQLSyntaxError(f"unexpected {tok.value!r} at pos {tok.pos}")


def parse(text: str) -> Query:
    return Parser(text).parse_query()


def parse_expression(text: str) -> Node:
    p = Parser(text)
    node = p.parse_expr()
    p.expect("EOF")
    return node
