"""TQL scan planner: chunk-statistics predicate pushdown (data skipping).

Delta-Lake-style file skipping, adapted to Deep Lake's chunked tensor layout:
each chunk carries :class:`~repro.core.chunks.ChunkStats` (element-wise
lo/hi bounds, NaN / empty-sample flags).  Before ``Executor.run`` evaluates a
``WHERE`` clause, :func:`plan_where` walks the predicate AST with interval
arithmetic over those bounds and classifies every row of the view into one of
three verdicts, grouped by the tuple of chunks the row lives in:

* **prune**  — the predicate is certainly False for every row of the group;
  the chunks are never fetched or decoded;
* **sure**   — certainly True; rows are kept without evaluating the predicate;
* **verify** — unknown; rows are evaluated normally (the only rows whose
  chunks are fetched during WHERE).

Soundness rules (all conservative — unknown always falls back to verify):

* a row's truth is ``_truthy(value)`` = "all elements non-zero, empty is
  False", so a comparison is certainly-True only when the whole stats
  interval satisfies it and certainly-False only when none of it can;
* NaN elements make ``== < <= > >=`` possibly-False and ``!=`` possibly-True
  (IEEE semantics); possibly-empty samples make any comparison
  possibly-False;
* ``tensor = literal`` / ``tensor IN [...]`` / ``CONTAINS(tensor, literal)``
  additionally consult the chunk's membership sketch
  (:meth:`~repro.core.chunks.ChunkStats.might_contain`): a value the sketch
  *proves absent* yields a definitive verdict (false positives merely cost a
  verify), with the empty-sample outcome derived from ``min_elems`` because
  empty samples contribute no sketch values — ``x == v`` and
  ``CONTAINS(x, v)`` are False on an empty sample but ``x IN [...]`` is
  True (``isin(empty, ...).all()`` is vacuously True);
* expressions the planner cannot analyze (UDFs, subscripts,
  string literals, ...) evaluate to the unknown interval TOP;
* computed values (literals the engine may cast to float32, arithmetic,
  MEAN/STD/SQRT/CAST_FLOAT) are widened outward by the worst-case float32
  evaluation rounding, and arithmetic that could overflow int64 becomes TOP
  — interval math in float64 alone would flip verdicts at bound-hugging
  predicates;
* a predicate containing RANDOM() disables planning entirely: evaluating it
  over a subset would change the random stream and thus the result.

Intervals use ``lo > hi`` to mean "no non-NaN numeric values" (e.g. MEAN of a
chunk of empty samples): comparisons then draw outcomes only from the
NaN/empty flags.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional

import numpy as np

from .. import telemetry
from .ast_nodes import (BinOp, Call, ListExpr, Literal, Node, TensorRef,
                        UnaryOp)
from ..chunks import ChunkStats, _hi_bound, _lo_bound

_CMP_OPS = ("==", "!=", ">", ">=", "<", "<=")

# The engine may evaluate in float32 (NEP-50 weak scalars keep a float32
# column float32), so every interval that models a *computed* value must be
# widened outward by the worst-case evaluation rounding, or a bound-hugging
# predicate could flip a verdict (e.g. float32(0.4 + 2**24) == 2**24).
_EPS32 = float(np.finfo(np.float32).eps)     # one-rounding relative error
_EPS_MEAN = 64 * _EPS32                      # pairwise-sum error, n <= 2**64
_EPS_STD = 256 * _EPS32                      # sum-of-squares + sqrt margin
_TINY32 = float(np.finfo(np.float32).tiny)   # absolute floor (subnormals)
_INT_GUARD = float(2 ** 62)                  # int64 arithmetic may overflow


def _pad(lo: float, hi: float, rel: float = _EPS32):
    """Widen [lo, hi] outward by the evaluation rounding margin; None means
    the magnitude is large enough that int64 overflow could wrap (→ TOP)."""
    m = max(abs(lo), abs(hi))
    if m >= _INT_GUARD:
        return None
    pad = rel * m + _TINY32
    return lo - pad, hi + pad

BOTH: FrozenSet[bool] = frozenset((True, False))
ONLY_T: FrozenSet[bool] = frozenset((True,))
ONLY_F: FrozenSet[bool] = frozenset((False,))


@dataclass(frozen=True)
class Interval:
    """Bounds on every element an expression can produce for rows of one
    chunk group.  ``known=False`` is TOP: nothing can be said."""

    lo: float = -math.inf
    hi: float = math.inf
    has_nan: bool = True
    maybe_empty: bool = True
    known: bool = False

    @property
    def has_values(self) -> bool:
        return self.known and self.lo <= self.hi

    def is_point(self) -> bool:
        return self.has_values and self.lo == self.hi \
            and not self.has_nan and not self.maybe_empty


TOP = Interval()


def _point(v: float) -> Interval:
    return Interval(float(v), float(v), has_nan=False, maybe_empty=False,
                    known=True)


def interval_from_stats(stats) -> Interval:
    """Map a ChunkStats record (or None) to the planner's interval domain."""
    if stats is None or not stats.exact:
        return TOP
    maybe_empty = stats.min_elems == 0 or stats.count == 0
    if stats.lo is None:  # no inspectable numeric values (all NaN / empty)
        return Interval(math.inf, -math.inf, has_nan=stats.nan_count > 0,
                        maybe_empty=maybe_empty, known=True)
    return Interval(float(stats.lo), float(stats.hi),
                    has_nan=stats.nan_count > 0, maybe_empty=maybe_empty,
                    known=True)


# ------------------------------------------------------------ interval algebra
def _flags(a: Interval, b: Interval) -> Dict[str, bool]:
    return {"has_nan": a.has_nan or b.has_nan,
            "maybe_empty": a.maybe_empty or b.maybe_empty}


def _arith(op: str, a: Interval, b: Interval) -> Interval:
    if not a.known or not b.known:
        return TOP
    if not a.has_values or not b.has_values:
        # one side is only-NaN/empty: result values are NaN or empty
        return Interval(math.inf, -math.inf, known=True, **_flags(a, b))
    if op == "+":
        lo, hi = a.lo + b.lo, a.hi + b.hi
    elif op == "-":
        lo, hi = a.lo - b.hi, a.hi - b.lo
    elif op == "*":
        prods = (a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi)
        lo, hi = min(prods), max(prods)
    elif op == "/":
        if b.lo <= 0 <= b.hi:
            return TOP  # division by (possibly) zero: anything can happen
        quots = (a.lo / b.lo, a.lo / b.hi, a.hi / b.lo, a.hi / b.hi)
        lo, hi = min(quots), max(quots)
    else:  # '%' and anything exotic
        return TOP
    if math.isnan(lo) or math.isnan(hi):
        return TOP
    padded = _pad(lo, hi)
    if padded is None:
        return TOP
    return Interval(*padded, known=True, **_flags(a, b))


def _neg(a: Interval) -> Interval:
    if not a.known:
        return TOP
    if not a.has_values:
        return a
    return Interval(-a.hi, -a.lo, has_nan=a.has_nan,
                    maybe_empty=a.maybe_empty, known=True)


def _cmp_truth(a: Interval, b: Interval, op: str) -> FrozenSet[bool]:
    if not a.known or not b.known:
        return BOTH
    out = set()
    if a.has_values and b.has_values:
        if op in ("<", ">"):
            lt = (a, b) if op == "<" else (b, a)
            if lt[0].hi < lt[1].lo:
                out.add(True)
            elif lt[0].lo >= lt[1].hi:
                out.add(False)
            else:
                out.update(BOTH)
        elif op in ("<=", ">="):
            le = (a, b) if op == "<=" else (b, a)
            if le[0].hi <= le[1].lo:
                out.add(True)
            elif le[0].lo > le[1].hi:
                out.add(False)
            else:
                out.update(BOTH)
        elif op == "==":
            if a.lo == a.hi == b.lo == b.hi:
                out.add(True)
            elif a.hi < b.lo or a.lo > b.hi:
                out.add(False)
            else:
                out.update(BOTH)
        elif op == "!=":
            if a.hi < b.lo or a.lo > b.hi:
                out.add(True)
            elif a.lo == a.hi == b.lo == b.hi:
                out.add(False)
            else:
                out.update(BOTH)
        else:
            return BOTH
    if a.has_nan or b.has_nan:
        out.add(True if op == "!=" else False)
    if a.maybe_empty or b.maybe_empty:
        out.add(False)  # empty comparison result -> _truthy is False
    return frozenset(out) if out else BOTH


def _truthify(iv: Interval) -> FrozenSet[bool]:
    """Possible row truth values of a non-comparison expression (§executor
    semantics: all elements non-zero; empty is False; NaN is truthy)."""
    if not iv.known:
        return BOTH
    out = set()
    if iv.has_values:
        if iv.lo > 0 or iv.hi < 0:
            out.add(True)
        elif iv.lo == 0 == iv.hi:
            out.add(False)
        else:
            out.update(BOTH)
    if iv.has_nan:
        out.add(True)
    if iv.maybe_empty:
        out.add(False)
    return frozenset(out) if out else BOTH


def _bool_interval(t: FrozenSet[bool]) -> Interval:
    if t == ONLY_T:
        return _point(1.0)
    if t == ONLY_F:
        return _point(0.0)
    return Interval(0.0, 1.0, has_nan=False, maybe_empty=False, known=True)


# ------------------------------------------------------------- membership
#: literal values the int sketch domain can reason about: actual chunk
#: elements are int64-representable integers, so an equal-comparing literal
#: either maps to one ("int") or provably equals no element ("never") —
#: anything murkier (strings, huge ints, non-finite) bails ("bail").
def _member_value(v):
    if isinstance(v, bool):
        return "int", int(v)
    if isinstance(v, (int, np.integer)):
        iv = int(v)
        return ("int", iv) if -(2 ** 63) <= iv < 2 ** 63 else ("bail", None)
    if isinstance(v, float):
        if not math.isfinite(v):
            return "bail", None
        if not float(v).is_integer():
            return "never", None  # non-integral: equals no int element
        iv = int(v)
        # integral but outside int64: a uint64 element CAN equal it under
        # the executor's float comparison — bail, never claim absence
        return ("int", iv) if -(2 ** 63) <= iv < 2 ** 63 else ("bail", None)
    return "bail", None


def _ref_and_literal(a: Node, b: Node):
    if isinstance(a, TensorRef) and isinstance(b, Literal):
        return a, b
    if isinstance(b, TensorRef) and isinstance(a, Literal):
        return b, a
    return None, None


# --------------------------------------------------------------- AST analysis
class _Analyzer:
    def __init__(self, env: Dict[str, Interval],
                 sketches: Optional[Dict[str, Optional[ChunkStats]]] = None
                 ) -> None:
        self.env = env
        self.sketches = sketches or {}

    # -- truth ---------------------------------------------------------------
    def truth(self, node: Node) -> FrozenSet[bool]:
        if isinstance(node, BinOp):
            if node.op in ("and", "or"):
                lt, rt = self.truth(node.left), self.truth(node.right)
                if node.op == "and":
                    return frozenset(a and b for a in lt for b in rt)
                return frozenset(a or b for a in lt for b in rt)
            if node.op in _CMP_OPS:
                base = _cmp_truth(self.interval(node.left),
                                  self.interval(node.right), node.op)
                memb = self._membership(node)
                if memb is not None:
                    # both are sound supersets of the possible row truths,
                    # so their intersection is too (guard the impossible)
                    both = base & memb
                    return both if both else base
                return base
            if node.op == "in":
                memb = self._membership(node)
                if memb is not None:
                    return memb
        if isinstance(node, Call):
            memb = self._membership(node)
            if memb is not None:
                return memb
        if isinstance(node, UnaryOp) and node.op == "not":
            return frozenset(not v for v in self.truth(node.operand))
        return _truthify(self.interval(node))

    # -- membership sketches -------------------------------------------------
    def _sketch_for(self, name: str, dom: str) -> Optional[ChunkStats]:
        st = self.sketches.get(name)
        if st is None or not st.sketch_usable(dom):
            return None
        return st

    @staticmethod
    def _maybe_empty(st: ChunkStats) -> bool:
        return st.min_elems == 0 or st.count == 0

    def _membership(self, node: Node) -> Optional[FrozenSet[bool]]:
        """Sketch verdict for ``=``/``!=``/``IN``/``CONTAINS`` over one base
        tensor and literal values; None = the sketch cannot refine.  All
        branches mirror the executor's row semantics exactly (`_truthy`
        over elementwise comparison; ``isin(sample, list).all()``;
        ``CONTAINS``'s text/elementwise split)."""
        if isinstance(node, BinOp) and node.op in ("==", "!="):
            ref, lit = _ref_and_literal(node.left, node.right)
            if ref is None:
                return None
            kind, v = _member_value(lit.value)
            if kind == "bail":
                return None
            st = self._sketch_for(ref.name, "int")
            if st is None:
                return None
            if kind == "int" and st.might_contain(v):
                return None
            # v provably equals no element of any sample in the chunk
            if node.op == "==":
                return ONLY_F          # empty samples are False too
            out = {True}               # all elements differ -> row True
            if self._maybe_empty(st):
                out.add(False)         # ...but an empty comparison is False
            return frozenset(out)
        if isinstance(node, BinOp) and node.op == "in" \
                and isinstance(node.left, TensorRef) \
                and isinstance(node.right, ListExpr):
            if not all(isinstance(it, Literal) for it in node.right.items):
                return None
            vals = []
            for it in node.right.items:
                kind, v = _member_value(it.value)
                if kind == "bail":
                    return None
                if kind == "int":
                    vals.append(v)     # "never" values match no element
            st = self._sketch_for(node.left.name, "int")
            if st is None:
                return None
            if not any(st.might_contain(v) for v in vals):
                # no element of any sample is in the list
                out = {False}
                if self._maybe_empty(st):
                    out.add(True)      # isin(empty, ...).all() is True
                return frozenset(out)
            if st.dct is not None and set(st.dct) <= set(vals):
                return ONLY_T          # every element everywhere is listed
            return None
        if isinstance(node, Call) and node.name.upper() == "CONTAINS" \
                and len(node.args) == 2 \
                and isinstance(node.args[0], TensorRef) \
                and isinstance(node.args[1], Literal):
            name, needle = node.args[0].name, node.args[1].value
            if isinstance(needle, str):
                # text domain: dictionary only (a bloom of whole strings
                # cannot answer substring probes); "" is in every string
                st = self._sketch_for(name, "str")
                if st is None or st.dct is None or needle == "":
                    return None
                hits = sum(needle in s for s in st.dct)
                if hits == 0:
                    return ONLY_F      # empty samples decode to "" -> False
                if hits == len(st.dct) and not self._maybe_empty(st):
                    return ONLY_T
                return None
            kind, v = _member_value(needle)
            if kind == "bail":
                return None
            st = self._sketch_for(name, "int")
            if st is None:
                return None
            if kind == "never":        # non-integral float: in no int sample
                return ONLY_F
            if not st.might_contain(v):
                return ONLY_F          # isin(v, empty).all() is False too
            if st.dct == [v] and not self._maybe_empty(st):
                return ONLY_T          # the only element value everywhere
            return None
        return None

    # -- intervals -----------------------------------------------------------
    def interval(self, node: Node) -> Interval:
        if isinstance(node, Literal):
            if isinstance(node.value, bool):
                return _point(1.0 if node.value else 0.0)
            if isinstance(node.value, (int, float)):
                # the engine may cast the literal to a column's float32: the
                # operand is then float32(v), so the interval is the exact
                # hull of both representations (a point when v is exact in
                # float32 — keeps integer comparisons decisively 'sure')
                v = node.value
                f32 = float(np.float32(v))
                if math.isnan(f32):
                    return TOP
                return Interval(_lo_bound(min(v, f32)),
                                _hi_bound(max(v, f32)),
                                has_nan=False, maybe_empty=False, known=True)
            return TOP
        if isinstance(node, TensorRef):
            return self.env.get(node.name, TOP)
        if isinstance(node, UnaryOp):
            if node.op == "-":
                return _neg(self.interval(node.operand))
            return _bool_interval(
                frozenset(not v for v in self.truth(node.operand)))
        if isinstance(node, BinOp):
            if node.op in ("and", "or") or node.op in _CMP_OPS:
                return _bool_interval(self.truth(node))
            return _arith(node.op, self.interval(node.left),
                          self.interval(node.right))
        if isinstance(node, Call):
            return self._call(node)
        return TOP  # Index, ListExpr, SliceSpec, unknown nodes

    def _call(self, node: Call) -> Interval:
        name = node.name.upper()
        if name in ("MEAN", "MIN", "MAX", "STD") and len(node.args) == 1:
            a = self.interval(node.args[0])
            if not a.known:
                return TOP
            if name == "STD":
                lo, hi = (0.0, a.hi - a.lo) if a.has_values else (math.inf,
                                                                  -math.inf)
            else:
                lo, hi = (a.lo, a.hi) if a.has_values else (math.inf,
                                                            -math.inf)
            # reductions of an empty sample yield NaN on both execution
            # paths (functions._reduce_all), folded into has_nan below
            if name in ("MEAN", "STD") and lo <= hi:
                # accumulating reductions round beyond the element bounds
                padded = _pad(lo, hi, _EPS_MEAN if name == "MEAN" else _EPS_STD)
                if padded is None:
                    return TOP
                lo, hi = padded
            return Interval(lo, hi,
                            has_nan=a.has_nan or a.maybe_empty,
                            maybe_empty=False, known=True)
        if name == "ABS" and len(node.args) == 1:
            a = self.interval(node.args[0])
            if not a.known:
                return TOP
            if not a.has_values:
                return a
            lo = 0.0 if a.lo <= 0 <= a.hi else min(abs(a.lo), abs(a.hi))
            return Interval(lo, max(abs(a.lo), abs(a.hi)), has_nan=a.has_nan,
                            maybe_empty=a.maybe_empty, known=True)
        if name == "SQRT" and len(node.args) == 1:
            a = self.interval(node.args[0])
            if not a.known:
                return TOP
            if not a.has_values:
                return a
            padded = _pad(math.sqrt(max(a.lo, 0.0)), math.sqrt(max(a.hi, 0.0)))
            if padded is None:
                return TOP
            return Interval(*padded,
                            has_nan=a.has_nan or a.lo < 0,
                            maybe_empty=a.maybe_empty, known=True)
        if name == "CAST_FLOAT" and len(node.args) == 1:
            a = self.interval(node.args[0])
            if not a.known or not a.has_values:
                return a if a.known else TOP
            padded = _pad(a.lo, a.hi)  # the cast rounds to float32
            if padded is None:
                return TOP
            return Interval(*padded, has_nan=a.has_nan,
                            maybe_empty=a.maybe_empty, known=True)
        if name in ("ANY", "ALL") and len(node.args) == 1:
            a = self.interval(node.args[0])
            if not a.known:
                return TOP
            out = set()
            if a.has_values:
                if a.lo > 0 or a.hi < 0:
                    out.add(True)
                elif a.lo == 0 == a.hi:
                    out.add(False)
                else:
                    out.update(BOTH)
            if a.has_nan:
                out.add(True)  # NaN is non-zero
            if a.maybe_empty:
                # np.any(empty) is False, np.all(empty) is True
                out.add(name == "ALL")
            return _bool_interval(frozenset(out) if out else BOTH)
        return TOP


# -------------------------------------------------------------------- planning
@dataclass
class ScanPlan:
    """Row-position partition of a view under a WHERE predicate."""

    n_rows: int
    pruned: np.ndarray        # positions certainly False  (never fetched)
    sure: np.ndarray          # positions certainly True   (kept, not evaluated)
    verify: np.ndarray        # positions needing evaluation
    groups: int               # distinct chunk-combinations examined
    groups_decided: int       # groups with a definitive (non-verify) verdict
    chunks_total: int         # chunks the view touches across planned tensors
    chunks_pruned: int        # chunks no surviving candidate row needs
    tensors: List[str]        # tensors whose stats were consulted
    chunks_consulted: int = 0      # distinct (tensor, chunk) stats lookups
    chunks_stats_missing: int = 0  # lookups without a usable (exact) record
    chunks_sketchless: int = 0     # usable records predating the sketches
    # aggregation pushdown (set by the executor after the fold): chunk
    # groups whose partial aggregates came straight from ChunkStats with
    # zero payload fetches
    agg_groups_stats_answered: int = 0

    @property
    def effective(self) -> bool:
        return len(self.pruned) > 0 or len(self.sure) > 0

    @property
    def stats_coverage(self) -> float:
        """Fraction of consulted chunks with usable stats — 0.0 on a
        pre-stats dataset, 1.0 after the maintenance backfill job."""
        if not self.chunks_consulted:
            return 1.0
        return 1.0 - self.chunks_stats_missing / self.chunks_consulted

    @property
    def sketch_coverage(self) -> float:
        """Fraction of consulted chunks written sketch-aware — below 1.0
        the membership pushdown (=/IN/CONTAINS) degrades to verify on the
        legacy records until ``backfill_stats`` lifts them."""
        if not self.chunks_consulted:
            return 1.0
        return 1.0 - ((self.chunks_stats_missing + self.chunks_sketchless)
                      / self.chunks_consulted)

    def report(self) -> dict:
        return {
            "rows": self.n_rows,
            "rows_pruned": int(len(self.pruned)),
            "rows_sure": int(len(self.sure)),
            "rows_verify": int(len(self.verify)),
            "groups": self.groups,
            "groups_decided": self.groups_decided,
            "chunks_total": self.chunks_total,
            "chunks_pruned": self.chunks_pruned,
            "chunks_consulted": self.chunks_consulted,
            "chunks_stats_missing": self.chunks_stats_missing,
            "chunks_sketchless": self.chunks_sketchless,
            "agg_groups_stats_answered": self.agg_groups_stats_answered,
            "stats_coverage": self.stats_coverage,
            "sketch_coverage": self.sketch_coverage,
            "tensors": list(self.tensors),
        }


def plan_where(view, where: Node) -> Optional[ScanPlan]:
    """Classify every row of ``view`` under ``where`` using chunk statistics.

    Statistics come from :meth:`DatasetView.scan_source
    <repro.core.views.DatasetView.scan_source>`: on a committed
    (manifest-covered) dataset the chunk-boundary table and per-chunk
    records ride in the manifest's column-statistics section, so planning
    runs straight off the cold open — zero tensor binds, zero storage
    requests (plan-at-open).  Legacy/stale nodes fall back to binding.

    Returns None when planning is impossible or meaningless: no base tensors
    referenced, RANDOM() present, or indices outside a tensor's range.  A
    returned plan is always sound: pruned rows are certainly False, sure rows
    certainly True, under the executor's `_truthy` row semantics.
    """
    # registry counter, not ad-hoc: the serving bench asserts a cached
    # plan's repeat query performs zero planner work via this exact key
    telemetry.registry().counter("tql.plans").inc()
    if where is None or len(view) == 0 or where.calls("RANDOM"):
        return None
    names = [n for n in _referenced(where)
             if n not in view.derived and n in view.tensor_names]
    if not names:
        return None
    sources = {}
    ord_cols = []
    for n in names:
        src = view.scan_source(n)
        try:
            ords = src.ords_of(view.indices)
        except IndexError:
            return None
        sources[n] = src
        ord_cols.append(ords)
    key_matrix = np.stack(ord_cols, axis=1)  # (rows, tensors)
    _uniq, inverse = np.unique(key_matrix, axis=0, return_inverse=True)
    stats_cache: Dict[tuple, tuple] = {}
    # stats-coverage accounting: how many consulted chunks carried a usable
    # record (on manifest datasets the sidecar is served straight from the
    # consolidated snapshot; the maintenance backfill job drives the
    # missing count of a pre-stats dataset to zero) — and how many of those
    # predate the membership sketches (same backfill lifts them)
    coverage = {"consulted": 0, "missing": 0, "sketchless": 0}

    def leaf(tname: str, chunk_ord: int):
        k = (tname, chunk_ord)
        if k not in stats_cache:
            st = sources[tname].stats_of(chunk_ord)
            coverage["consulted"] += 1
            if st is None or not st.exact:
                coverage["missing"] += 1
            elif not st.sketched:
                coverage["sketchless"] += 1
            stats_cache[k] = (interval_from_stats(st), st)
        return stats_cache[k]

    verdicts = np.empty(len(_uniq), dtype=np.int8)  # 0 prune, 1 sure, 2 verify
    decided = 0
    for g, key in enumerate(_uniq):
        env: Dict[str, Interval] = {}
        sketches: Dict[str, Optional[ChunkStats]] = {}
        for j, n in enumerate(names):
            env[n], sketches[n] = leaf(n, int(key[j]))
        t = _Analyzer(env, sketches).truth(where)
        if t == ONLY_F:
            verdicts[g] = 0
            decided += 1
        elif t == ONLY_T:
            verdicts[g] = 1
            decided += 1
        else:
            verdicts[g] = 2
    row_verdict = verdicts[inverse]
    positions = np.arange(len(view))
    pruned = positions[row_verdict == 0]
    sure = positions[row_verdict == 1]
    verify = positions[row_verdict == 2]
    # chunk accounting: chunks no candidate (sure|verify) row ever needs
    candidates = row_verdict != 0
    chunks_total = 0
    chunks_pruned = 0
    for j in range(key_matrix.shape[1]):
        col = key_matrix[:, j]
        all_chunks = np.unique(col)
        live_chunks = np.unique(col[candidates]) if candidates.any() \
            else np.empty(0)
        chunks_total += len(all_chunks)
        chunks_pruned += len(all_chunks) - len(live_chunks)
    return ScanPlan(
        n_rows=len(view), pruned=pruned, sure=sure, verify=verify,
        groups=len(_uniq), groups_decided=decided,
        chunks_total=chunks_total, chunks_pruned=chunks_pruned,
        tensors=names, chunks_consulted=coverage["consulted"],
        chunks_stats_missing=coverage["missing"],
        chunks_sketchless=coverage["sketchless"])


def group_key_intervals(view, pipe, key_expr: Node) -> List[Interval]:
    """Per-chunk-group interval of an ``ORDER BY`` key expression, under
    the same soundness rules as :func:`plan_where` (float32-rounding
    widened, int64-overflow guarded, NaN/empty flags) — the bound source
    for the executor's top-k chunk skipping.  ``pipe`` is the
    :class:`~repro.core.pipeline.ScanPipeline` built over ``view`` for the
    key's base tensors; group ``g``'s interval bounds every key value a row
    of that group can produce, so a group whose bound cannot beat the
    running k-th-element cutoff is never streamed."""
    sources = {n: view.scan_source(n) for n in pipe.names}
    cache: Dict[tuple, Interval] = {}
    out: List[Interval] = []
    for g in range(pipe.n_groups):
        env: Dict[str, Interval] = {}
        for n, o in zip(pipe.names, pipe.group_ords(g)):
            k = (n, o)
            if k not in cache:
                cache[k] = interval_from_stats(sources[n].stats_of(o))
            env[n] = cache[k]
        out.append(_Analyzer(env).interval(key_expr))
    return out


def _referenced(node: Node) -> List[str]:
    names: List[str] = []
    for r in node.find(TensorRef):
        if r.name not in names:
            names.append(r.name)
    return names
