"""Version control built into the storage format (§4.1).

Design follows the paper:

* the dataset directory holds a *version tree* file (``version_control_info.json``)
  with branches and commit nodes;
* each version (node) has its own sub-directory with per-tensor state —
  chunk-encoder snapshot, sample ids, ``chunk_set`` (names of chunks CREATED
  in that version) and ``commit_diff`` (what changed);
* chunks never move: reading a chunk traverses the commit chain from the
  current node toward the root and stops at the first version whose
  chunk_set contains the chunk name.  A chunk_set may carry an ``"at"``
  home map (``{chunk_name: node_id}``) redirecting individual names to the
  directory they were *physically* uploaded under — the commit-rebase path
  uses it to graft already-uploaded chunks onto a relocated head without
  copying a byte (GC reachability is (tensor, name)-based and location-
  agnostic, so grafted chunks are never swept);
* every branch head is a *writable, uncommitted* node.  ``commit`` seals the
  head and opens a fresh child node (state files copied, chunk_set empty);
* sample ids (random u64 per appended row) keep identity across branches so
  ``merge`` can align rows.

Storage layout (keys relative to dataset root):

    version_control_info.json
    versions/{node}/schema.json                      # tensor list at this version
    versions/{node}/tensors/{t}/meta.json
    versions/{node}/tensors/{t}/chunk_encoder
    versions/{node}/tensors/{t}/chunk_stats.json
    versions/{node}/tensors/{t}/sample_ids
    versions/{node}/tensors/{t}/chunk_set.json
    versions/{node}/tensors/{t}/commit_diff.json
    versions/{node}/tensors/{t}/chunks/{chunk_name}

Manifest integration (:mod:`.manifest`): all per-tensor state reads and
writes route through :meth:`VersionControl.get_state` /
:meth:`VersionControl.put_state`.  When a dataset manifest is attached,
reads of manifest-covered nodes are served from the consolidated snapshot
(zero storage requests on a cold open); writes always land in the loose
per-file layout above (it stays complete and authoritative for legacy
readers) after write-ahead-invalidating the node's manifest snapshot.
``commit`` publishes complete snapshots of the sealed node and the fresh
head through one CAS pointer swap — the ACID ingestion point.

Concurrent committers (rebase-and-retry): losing the pointer swap no
longer surfaces a raw :class:`~repro.core.manifest.ManifestConflict`.
:meth:`VersionControl.commit` reloads the pointer and **rebases**:
commits on *different* branches merge version trees outright (nothing
re-uploaded, nothing relocated); commits racing on the *same* branch
relocate this writer's pending work onto a fresh head under the winner's
newest sealed node **iff** the two writers touched disjoint tensor sets
(cheap ``commit_diff`` intersection along the winner's path), grafting
already-uploaded chunks via the chunk_set ``"at"`` home map.  Overlapping
same-branch writes raise a typed :class:`CommitContendedError` (a
``ManifestConflict`` subclass) after bounded attempts.  All durable state
writes go through ``StorageProvider.put_verified`` so torn uploads are
detected and re-put before anything references them.
"""

from __future__ import annotations

import json
import time
import uuid
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from . import manifest as manifestlib
from . import telemetry
from .chunk_encoder import ChunkEncoder, ChunkStatsTable
from .storage import StorageError, StorageProvider

VC_INFO_KEY = "version_control_info.json"

#: bounded rebase attempts in :meth:`VersionControl.commit` before a
#: contended commit gives up with :class:`CommitContendedError`
COMMIT_REBASE_ATTEMPTS = 8


class CommitContendedError(manifestlib.ManifestConflict):
    """A commit could not be rebased onto the winning history: either the
    concurrent writers touched overlapping tensor sets on one branch, or
    the bounded rebase budget ran out.  Subclasses
    :class:`~repro.core.manifest.ManifestConflict` so existing conflict
    handlers keep working; the dataset itself is untouched — re-open a
    fresh handle and replay the writes to retry."""


def _new_id() -> str:
    return uuid.uuid4().hex[:16]


@dataclass
class CommitNode:
    id: str
    parent: Optional[str]
    branch: str
    message: Optional[str] = None
    committed: bool = False
    timestamp: float = 0.0
    children: List[str] = field(default_factory=list)

    def to_json(self) -> dict:
        # children is copied: serialized snapshots must not alias the live
        # list (save_info compares them against later state to skip no-ops)
        return {"id": self.id, "parent": self.parent, "branch": self.branch,
                "message": self.message, "committed": self.committed,
                "timestamp": self.timestamp, "children": list(self.children)}

    @classmethod
    def from_json(cls, d: dict) -> "CommitNode":
        return cls(d["id"], d["parent"], d["branch"], d.get("message"),
                   d.get("committed", False), d.get("timestamp", 0.0),
                   list(d.get("children", [])))


@dataclass
class CommitDiff:
    """What changed for one tensor within one version."""
    added_first: int = -1      # first appended global index (-1: none)
    added_count: int = 0
    updated: Set[int] = field(default_factory=set)
    created: bool = False      # tensor created in this version

    def record_append(self, first_idx: int, count: int) -> None:
        if self.added_count == 0:
            self.added_first = first_idx
        self.added_count += count

    def record_update(self, idx: int) -> None:
        # an update to a row appended in this same version is not a cross-
        # version update — it is still part of the "added" set
        if self.added_first != -1 and idx >= self.added_first:
            return
        self.updated.add(int(idx))

    def is_empty(self) -> bool:
        return self.added_count == 0 and not self.updated and not self.created

    def to_json(self) -> dict:
        return {"added_first": self.added_first, "added_count": self.added_count,
                "updated": sorted(self.updated), "created": self.created}

    @classmethod
    def from_json(cls, d: dict) -> "CommitDiff":
        return cls(d.get("added_first", -1), d.get("added_count", 0),
                   set(d.get("updated", [])), d.get("created", False))


class VersionControl:
    """Owns the version tree and per-node tensor state for one dataset."""

    # chunk_stats.json rides with the encoder snapshot: both key by chunk
    # name, so the copy stays valid in the child node (chunks never move).
    STATE_FILES = ("meta.json", "chunk_encoder", "sample_ids",
                   "chunk_stats.json")
    #: every per-tensor state file a commit-node snapshot must capture
    ALL_STATE_FILES = STATE_FILES + ("chunk_set.json", "commit_diff.json")

    def __init__(self, storage: StorageProvider,
                 manifest: Optional[manifestlib.Manifest] = None) -> None:
        self.storage = storage
        self.manifest = manifest
        self.branches: Dict[str, str] = {}
        self.commits: Dict[str, CommitNode] = {}
        self.current_id: str = ""
        # per current-node mutable state (flushed by save_info / tensor flush)
        self._chunk_sets: Dict[Tuple[str, str], Set[str]] = {}   # (node, tensor)
        self._schemas: Dict[str, List[str]] = {}                 # node -> tensor list
        self._diffs: Dict[str, CommitDiff] = {}                  # tensor -> diff (current node)
        # chunk relocation bookkeeping (commit rebase): per (node, tensor)
        # the "at" home map of names stored under another node's directory,
        # and per (tensor, name) the node a chunk was physically put under
        self._chunk_home_maps: Dict[Tuple[str, str], Dict[str, str]] = {}
        self._chunk_put_homes: Dict[Tuple[str, str], str] = {}
        #: commit-path observability: rebases (either shape), cross-branch
        #: adoptions, same-branch relocations, grafted chunks, contended
        #: failures.  Mirrored into the process-wide telemetry registry
        #: (``commit.*`` counters) so benches read one snapshot API.
        self.commit_stats: Dict[str, int] = {
            "commits": 0, "rebases": 0, "adoptions": 0, "relocations": 0,
            "grafted_chunks": 0, "contended": 0}
        # read-through/write-through memo of state-file bytes per
        # (node, tensor, fname); None records an authoritative miss
        self._state_cache: Dict[Tuple[str, str, str], Optional[bytes]] = {}
        # last version-tree snapshot this handle loaded or published; lets
        # save_info() skip the publication (and its conflict fence) when
        # nothing changed, so a read-only handle's flush never rolls back
        # or conflicts with a foreign commit
        self._saved_info: Optional[dict] = None
        self._load_or_init()

    # ------------------------------------------------------------------ setup
    def _load_or_init(self) -> None:
        m = self.manifest
        if m is not None and m.vc_info:
            # manifest-first open: the version tree rides inside the pointer
            d = m.vc_info
            self.branches = dict(d["branches"])
            self.commits = {k: CommitNode.from_json(v)
                            for k, v in d["commits"].items()}
            self.current_id = d["current"]
            self._saved_info = self._info_dict()
            self._load_current_diffs()
            return
        raw = self.storage.get_or_none(VC_INFO_KEY)
        if raw is None:
            root = CommitNode(id=_new_id(), parent=None, branch="main")
            self.commits = {root.id: root}
            self.branches = {"main": root.id}
            self.current_id = root.id
            self._put_json(self._schema_key(root.id), {"tensors": []})
            self._schemas[root.id] = []
            self.save_info()
        else:
            d = json.loads(raw.decode())
            self.branches = dict(d["branches"])
            self.commits = {k: CommitNode.from_json(v) for k, v in d["commits"].items()}
            self.current_id = d["current"]
            self._saved_info = self._info_dict()
            self._load_current_diffs()

    def _info_dict(self) -> dict:
        return {
            "branches": dict(self.branches),
            "commits": {k: v.to_json() for k, v in self.commits.items()},
            "current": self.current_id,
        }

    def save_info(self, sync_manifest: bool = True,
                  force: bool = False) -> None:
        """Persist the version tree.  No-op when nothing changed since the
        last load/publication — a read-only handle's flush neither pays a
        pointer CAS nor conflicts with (or rolls back) a foreign commit.
        On manifest datasets the pointer swap is the fence: the loose
        legacy mirror is only written AFTER the swap wins, mirroring
        :meth:`commit`'s ordering.  ``force`` republishes even an
        unchanged tree (a freshly adopted pointer has no vc yet)."""
        info = self._info_dict()
        if not force and info == self._saved_info:
            return
        if sync_manifest and self.manifest is not None:
            self.manifest.update_vc(info)  # conflict fence; raises on loss
        self._put_json(VC_INFO_KEY, info)
        self._saved_info = info

    # ------------------------------------------------------------- key helpers
    @staticmethod
    def node_dir(node_id: str) -> str:
        return f"versions/{node_id}"

    def _schema_key(self, node_id: str) -> str:
        return f"{self.node_dir(node_id)}/schema.json"

    def tensor_dir(self, node_id: str, tensor: str) -> str:
        return f"{self.node_dir(node_id)}/tensors/{tensor}"

    def state_key(self, tensor: str, fname: str, node_id: Optional[str] = None) -> str:
        return f"{self.tensor_dir(node_id or self.current_id, tensor)}/{fname}"

    def chunk_key(self, node_id: str, tensor: str, chunk_name: str) -> str:
        return f"{self.tensor_dir(node_id, tensor)}/chunks/{chunk_name}"

    def _put_json(self, key: str, obj) -> None:
        self.storage.put_verified(key, json.dumps(obj).encode())

    def _get_json(self, key: str, default=None):
        raw = self.storage.get_or_none(key)
        return default if raw is None else json.loads(raw.decode())

    # ------------------------------------------------------------- state I/O
    def get_state(self, tensor: str, fname: str,
                  node_id: Optional[str] = None) -> Optional[bytes]:
        """Bytes of one per-tensor state file, manifest-first.

        Manifest-covered nodes are served from the consolidated snapshot
        (including authoritative misses — a covered node that never wrote
        the file); everything else falls back to the loose per-file
        layout.  Reads memoize per (node, tensor, file).
        """
        nid = node_id or self.current_id
        ck = (nid, tensor, fname)
        if ck in self._state_cache:
            return self._state_cache[ck]
        m = self.manifest
        if m is not None and m.covers(nid):
            data = m.state_bytes(nid, tensor, fname)
        else:
            data = self.storage.get_or_none(self.state_key(tensor, fname, nid))
        self._state_cache[ck] = data
        return data

    def put_state(self, tensor: str, fname: str, data: bytes,
                  node_id: Optional[str] = None) -> None:
        """Write one state file to the loose layout (always authoritative),
        write-ahead-invalidating the node's manifest snapshot first so a
        concurrent cold open can never read the superseded snapshot."""
        nid = node_id or self.current_id
        m = self.manifest
        if m is not None and m.covers(nid):
            node = self.commits.get(nid)
            m.mark_stale(nid,
                         known_committed=bool(node and node.committed))
        self.storage.put_verified(self.state_key(tensor, fname, nid), data)
        self._state_cache[(nid, tensor, fname)] = bytes(data)

    def _get_state_json(self, tensor: str, fname: str,
                        node_id: Optional[str] = None, default=None):
        raw = self.get_state(tensor, fname, node_id)
        return default if raw is None else json.loads(raw.decode())

    def node_snapshot(self, node_id: str) -> manifestlib.NodeState:
        """Complete :class:`~repro.core.manifest.NodeState` of one node
        (schema + raw bytes of every state file of every tensor), including
        the decoded column-statistics section (manifest format v2) so the
        TQL planner can classify chunk groups straight from the cold-open
        pointer fold, before any tensor binds."""
        schema = self.schema_tensors(node_id)
        tensors = {
            t: {f: self.get_state(t, f, node_id) for f in self.ALL_STATE_FILES}
            for t in schema}
        stats: Dict[str, manifestlib.ColumnStats] = {}
        for t in schema:
            cs = self._column_stats_from_state(tensors[t])
            if cs is not None:
                stats[t] = cs
        return manifestlib.NodeState(schema=schema, tensors=tensors,
                                     stats=stats)

    @staticmethod
    def _column_stats_from_state(
            files: Dict[str, Optional[bytes]]
    ) -> Optional[manifestlib.ColumnStats]:
        """Decode a tensor's encoder + stats-sidecar bytes into the
        manifest's scan index (None when the encoder bytes are absent or
        unreadable — the section is an optimization, never load-bearing)."""
        enc_raw = files.get("chunk_encoder")
        if not enc_raw:
            return None
        try:
            enc = ChunkEncoder.deserialize(enc_raw)
        except Exception:
            return None
        st_raw = files.get("chunk_stats.json")
        try:
            table = ChunkStatsTable.deserialize(st_raw) if st_raw \
                else ChunkStatsTable()
        except Exception:
            table = ChunkStatsTable()
        names = enc.chunk_names()
        return manifestlib.ColumnStats(
            last_idx=np.asarray([enc.chunk_span(o)[1]
                                 for o in range(len(names))],
                                dtype=np.int64),
            chunk_stats=[table.get(n) for n in names])

    def column_stats(self, tensor: str, node_id: Optional[str] = None
                     ) -> Optional[manifestlib.ColumnStats]:
        """Bind-free scan index of one tensor: served from the manifest's
        column-statistics section when the node is covered (zero requests),
        None otherwise — callers fall back to binding the tensor."""
        if self.manifest is None:
            return None
        return self.manifest.column_stats(node_id or self.current_id, tensor)

    def tensor_length(self, tensor: str,
                      node_id: Optional[str] = None) -> Optional[int]:
        """Row count of a tensor without binding it (manifest scan index),
        or None when the node is uncovered."""
        cs = self.column_stats(tensor, node_id)
        return None if cs is None else cs.num_samples

    # ------------------------------------------------------------ node state
    @property
    def current(self) -> CommitNode:
        return self.commits[self.current_id]

    def writable(self) -> bool:
        return not self.current.committed

    def require_writable(self) -> None:
        if not self.writable():
            raise PermissionError(
                f"HEAD {self.current_id} is a sealed commit; checkout a branch "
                f"(or create one) before writing")

    def schema_tensors(self, node_id: Optional[str] = None) -> List[str]:
        nid = node_id or self.current_id
        if nid not in self._schemas:  # memo: one GET per node, not per view
            m = self.manifest
            if m is not None and m.covers(nid):
                self._schemas[nid] = list(m.node_schema(nid) or [])
            else:
                d = self._get_json(self._schema_key(nid), {"tensors": []})
                self._schemas[nid] = list(d["tensors"])
        return list(self._schemas[nid])

    def set_schema_tensors(self, tensors: List[str]) -> None:
        m = self.manifest
        if m is not None and m.covers(self.current_id):
            m.mark_stale(self.current_id)
        self._schemas[self.current_id] = list(tensors)
        self._put_json(self._schema_key(self.current_id), {"tensors": tensors})

    # ----------------------------------------------------------- chunk lookup
    def chunk_set(self, node_id: str, tensor: str) -> Set[str]:
        key = (node_id, tensor)
        if key not in self._chunk_sets:
            d = self._get_state_json(tensor, "chunk_set.json", node_id,
                                     {"chunks": []})
            self._chunk_sets[key] = set(d["chunks"])
            at = d.get("at") or {}
            if at:  # grafted chunks live under another node's directory
                self._chunk_home_maps[key] = dict(at)
        return self._chunk_sets[key]

    def _chunk_home(self, node_id: str, tensor: str, chunk_name: str) -> str:
        """Node whose directory physically holds a chunk owned by
        ``node_id`` (== ``node_id`` unless the chunk was grafted)."""
        return self._chunk_home_maps.get((node_id, tensor), {}) \
            .get(chunk_name, node_id)

    def resolve_chunk_key(self, tensor: str, chunk_name: str,
                          node_id: Optional[str] = None) -> str:
        """Paper §4.1 traversal: walk current -> root, first chunk_set hit
        wins; the owning node's "at" home map may redirect the physical key."""
        nid = node_id or self.current_id
        while nid is not None:
            if chunk_name in self.chunk_set(nid, tensor):
                home = self._chunk_home(nid, tensor, chunk_name)
                return self.chunk_key(home, tensor, chunk_name)
            nid = self.commits[nid].parent
        raise StorageError(f"chunk {chunk_name!r} of tensor {tensor!r} not found "
                           f"in any ancestor of {node_id or self.current_id}")

    def register_new_chunk(self, tensor: str, chunk_name: str) -> str:
        """Record a chunk created in the current (writable) version."""
        self.require_writable()
        self.chunk_set(self.current_id, tensor).add(chunk_name)
        return self.chunk_key(
            self._chunk_home(self.current_id, tensor, chunk_name),
            tensor, chunk_name)

    def put_chunk(self, tensor: str, chunk_name: str, payload: bytes) -> str:
        """Verified upload of a chunk owned by the current writable node.

        The single chokepoint for chunk durability: routes through
        :meth:`StorageProvider.put_verified` (torn uploads detected and
        re-put), honors the relocation home map (a grafted chunk re-flushes
        to its birth directory, never forks), and records where the bytes
        physically landed so a later rebase can graft without re-uploading.
        Returns the physical key written.
        """
        nid = self.current_id
        home = self._chunk_home(nid, tensor, chunk_name)
        key = self.chunk_key(home, tensor, chunk_name)
        self.storage.put_verified(key, payload)
        self._chunk_put_homes[(tensor, chunk_name)] = home
        return key

    def forget_chunk(self, tensor: str, chunk_name: str) -> None:
        self.chunk_set(self.current_id, tensor).discard(chunk_name)
        self._chunk_home_maps.get((self.current_id, tensor), {}) \
            .pop(chunk_name, None)
        self._chunk_put_homes.pop((tensor, chunk_name), None)

    def flush_chunk_set(self, tensor: str) -> None:
        nid = self.current_id
        names = self.chunk_set(nid, tensor)
        payload: dict = {"chunks": sorted(names)}
        at = {n: h for n, h in
              self._chunk_home_maps.get((nid, tensor), {}).items()
              if n in names and h != nid}
        if at:
            payload["at"] = at
        self.put_state(tensor, "chunk_set.json", json.dumps(payload).encode())

    # ------------------------------------------------------------ diff state
    def diff_of(self, tensor: str) -> CommitDiff:
        if tensor not in self._diffs:
            d = self._get_state_json(tensor, "commit_diff.json")
            self._diffs[tensor] = CommitDiff.from_json(d) if d else CommitDiff()
        return self._diffs[tensor]

    def record_append(self, tensor: str, first_idx: int, count: int) -> None:
        self.diff_of(tensor).record_append(first_idx, count)

    def record_update(self, tensor: str, idx: int) -> None:
        self.diff_of(tensor).record_update(idx)

    def record_created(self, tensor: str) -> None:
        self.diff_of(tensor).created = True

    def flush_diff(self, tensor: str) -> None:
        self.put_state(tensor, "commit_diff.json",
                       json.dumps(self.diff_of(tensor).to_json()).encode())

    def _load_current_diffs(self) -> None:
        self._diffs = {}
        for t in self.schema_tensors():
            self.diff_of(t)

    def has_uncommitted_changes(self) -> bool:
        return any(not d.is_empty() for d in self._diffs.values())

    # --------------------------------------------------------------- commit
    def commit(self, message: str = "", *, flush=None) -> str:
        """Seal the current head; open a fresh writable child on the branch.

        On manifest datasets this is the ACID publication point: complete
        snapshots of the sealed node and the fresh head are folded into a
        new manifest segment and published with one CAS pointer swap
        (:meth:`Manifest.commit_update`).  Losing the swap to a concurrent
        committer triggers an automatic **rebase-and-retry**: the pointer
        is reloaded and this writer's pending work grafted onto the winning
        history (see :meth:`_rebase_commit`), then ``flush`` (the caller's
        tensor-flush callback, re-entrant) and the publication re-run —
        bounded by ``COMMIT_REBASE_ATTEMPTS``, after which (or when the
        writers' tensor sets overlap on one branch) a typed
        :class:`CommitContendedError` surfaces.  Already-uploaded chunks
        are never re-uploaded by a rebase: cross-branch winners leave our
        head untouched, same-branch relocation grafts them via the
        chunk_set ``"at"`` home map.  Legacy (pre-manifest) datasets adopt
        a manifest on their first commit.
        """
        self.require_writable()
        last: Optional[manifestlib.ManifestConflict] = None
        for _ in range(1 + COMMIT_REBASE_ATTEMPTS):
            try:
                if flush is not None:
                    flush()
                with telemetry.span("commit.publish",
                                    branch=self.current.branch):
                    sealed = self._commit_once(message)
                self.commit_stats["commits"] += 1
                telemetry.registry().counter("commit.commits").inc()
                return sealed
            except manifestlib.ManifestConflict as e:
                if isinstance(e, CommitContendedError):
                    raise
                last = e
                self._rebase_commit(e)
        self.commit_stats["contended"] += 1
        telemetry.registry().counter("commit.contended").inc()
        raise CommitContendedError(
            f"commit gave up after {COMMIT_REBASE_ATTEMPTS} rebase "
            f"attempts on branch {self.current.branch!r}") from last

    def _commit_once(self, message: str) -> str:
        """One seal + publish attempt; rolls the in-memory seal back on a
        publication conflict so a rebase can re-run the whole commit."""
        head = self.current
        prev_diffs = self._diffs
        head.committed = True
        head.message = message
        head.timestamp = time.time()
        sealed_id = head.id
        branch = head.branch
        child = CommitNode(id=_new_id(), parent=sealed_id, branch=branch)
        head.children.append(child.id)
        self.commits[child.id] = child
        self.branches[branch] = child.id
        try:
            self._copy_state(sealed_id, child.id)
            self.current_id = child.id
            self._load_current_diffs()
            if self.manifest is None:  # legacy dataset: adopt on first commit
                self.manifest = manifestlib.Manifest.create(self.storage)
            info = self._info_dict()
            self.manifest.commit_update(
                {sealed_id: self.node_snapshot(sealed_id),
                 child.id: self.node_snapshot(child.id)},
                info, branch=branch)
        except manifestlib.ManifestConflict:
            # the publish lost: undo the seal so the head is writable again
            # (the rebase re-runs the commit); the child's loose files —
            # a few tiny JSON objects — stay behind as GC-able orphans
            head.committed = False
            head.message = None
            head.timestamp = 0.0
            if child.id in head.children:
                head.children.remove(child.id)
            self.commits.pop(child.id, None)
            self.branches[branch] = sealed_id
            self.current_id = sealed_id
            self._diffs = prev_diffs
            self._schemas.pop(child.id, None)
            self._state_cache = {k: v for k, v in self._state_cache.items()
                                 if k[0] != child.id}
            self._chunk_sets = {k: v for k, v in self._chunk_sets.items()
                                if k[0] != child.id}
            raise
        # mirror to the legacy key only AFTER the pointer swap won: a
        # conflicted commit must not advance the loose version tree either
        self._put_json(VC_INFO_KEY, info)
        self._saved_info = info
        return sealed_id

    # --------------------------------------------------------------- rebase
    def _rebase_commit(self, cause: manifestlib.ManifestConflict) -> None:
        """Graft this writer's pending (uncommitted) work onto the winning
        history after a lost publication.

        Two shapes, mirroring where concurrent writers can actually
        collide:

        * **cross-branch** — the winner moved *other* branch heads; our
          head node is untouched.  Merge the version trees (their commits
          + our local-only nodes), adopt the fresh manifest, keep our head.
          Nothing is re-uploaded, nothing relocated.
        * **same-branch** — the winner sealed the very node we were
          writing to.  Iff the two writers touched disjoint tensor sets
          (``commit_diff`` intersection along the winner's new commits),
          relocate our pending state onto a fresh head under the winner's
          newest sealed node, grafting already-uploaded chunks in place
          via the chunk_set ``"at"`` home map.  Overlap raises
          :class:`CommitContendedError`.
        """
        self.commit_stats["rebases"] += 1
        telemetry.registry().counter("commit.rebases").inc()
        with telemetry.span("commit.rebase",
                            branch=self.current.branch) as sp:
            fresh = manifestlib.Manifest.load(self.storage)
            if fresh is None or not fresh.vc_info:
                raise cause  # nothing to rebase onto: surface the original
            their_commits = {k: CommitNode.from_json(v)
                             for k, v in fresh.vc_info["commits"].items()}
            their_branches = dict(fresh.vc_info.get("branches", {}))
            head_id = self.current_id
            branch = self.current.branch
            if their_branches.get(branch, head_id) == head_id:
                sp.set(shape="adopt")
                self._adopt_tree(fresh, their_commits, their_branches,
                                 head_id=head_id, branch=branch)
            else:
                sp.set(shape="relocate")
                self._relocate_head(fresh, their_commits, their_branches,
                                    head_id=head_id, branch=branch,
                                    cause=cause)

    def _merge_trees(self, their_commits: Dict[str, CommitNode],
                     their_branches: Dict[str, str]
                     ) -> Tuple[Dict[str, CommitNode], Dict[str, str]]:
        """The winner's tree + any local-only nodes (unpublished branches),
        re-linked into their parents."""
        merged = dict(their_commits)
        for nid, node in self.commits.items():
            if nid not in merged:
                merged[nid] = node
                p = node.parent
                if p is not None and p in merged \
                        and nid not in merged[p].children:
                    merged[p].children.append(nid)
        branches = dict(their_branches)
        for b, h in self.branches.items():
            branches.setdefault(b, h)
        return merged, branches

    def _adopt_tree(self, fresh: manifestlib.Manifest,
                    their_commits: Dict[str, CommitNode],
                    their_branches: Dict[str, str], *,
                    head_id: str, branch: str) -> None:
        merged, branches = self._merge_trees(their_commits, their_branches)
        merged[head_id] = self.commits[head_id]  # keep the live head object
        branches[branch] = head_id
        self.commits = merged
        self.branches = branches
        self.manifest = fresh
        self._saved_info = fresh.vc_info
        self.commit_stats["adoptions"] += 1
        telemetry.registry().counter("commit.adoptions").inc()
        # our head's cached state is still ours (nobody sealed it); every
        # other node's state is immutable, so no cache invalidation needed

    def _relocate_head(self, fresh: manifestlib.Manifest,
                       their_commits: Dict[str, CommitNode],
                       their_branches: Dict[str, str], *,
                       head_id: str, branch: str,
                       cause: manifestlib.ManifestConflict) -> None:
        th = their_branches.get(branch)
        if th is None or th not in their_commits:
            raise cause  # the branch vanished: not linearly rebaseable
        th_node = their_commits[th]
        tp = th_node.parent if not th_node.committed else th
        if tp is None:
            raise cause
        base = self.commits[head_id].parent
        # the winner's sealed chain since our base, newest first
        path: List[str] = []
        nid: Optional[str] = tp
        while nid is not None and nid != base:
            node = their_commits.get(nid)
            if node is None:
                raise cause
            path.append(nid)
            nid = node.parent
        if nid != base:
            raise cause  # our base is not in the winner's ancestry

        ours_touched = {t for t, d in self._diffs.items() if not d.is_empty()}
        theirs_touched: Set[str] = set()
        for pnid in path:
            ns = fresh.nodes.get(pnid)
            if ns is None:
                raise cause  # cannot prove disjointness without the snapshot
            for t, files in ns.tensors.items():
                raw = files.get("commit_diff.json")
                if raw and not CommitDiff.from_json(
                        json.loads(raw.decode())).is_empty():
                    theirs_touched.add(t)
        overlap = ours_touched & theirs_touched
        if overlap:
            self.commit_stats["contended"] += 1
            telemetry.registry().counter("commit.contended").inc()
            raise CommitContendedError(
                f"concurrent commits touched the same tensors "
                f"{sorted(overlap)} on branch {branch!r}; exactly one "
                f"writer won — replay these writes on a fresh handle to "
                f"retry") from cause
        tp_state = fresh.nodes.get(tp)
        if tp_state is None:
            raise cause

        # capture our flushed state bytes for touched tensors BEFORE the
        # old head's (now foreign-owned) caches are dropped; never-flushed
        # tensors re-flush from live Tensor memory on the commit retry
        old_schema = self.schema_tensors(head_id)
        captured = {t: {f: self.get_state(t, f, head_id)
                        for f in self.STATE_FILES}
                    for t in ours_touched}

        x2 = CommitNode(id=_new_id(), parent=tp, branch=branch)
        merged, branches = self._merge_trees(their_commits, their_branches)
        merged[x2.id] = x2
        if x2.id not in merged[tp].children:
            merged[tp].children.append(x2.id)
        branches[branch] = x2.id
        self.commits = merged
        self.branches = branches
        self.manifest = fresh
        self._saved_info = fresh.vc_info
        self.current_id = x2.id

        # move in-memory chunk ownership old head -> X2; chunks whose bytes
        # already landed keep their physical home (the graft)
        grafted = 0
        for t in ours_touched:
            moved = self._chunk_sets.pop((head_id, t), set())
            self._chunk_sets[(x2.id, t)] = moved
            inherited = self._chunk_home_maps.pop((head_id, t), {})
            homes: Dict[str, str] = {}
            for name in moved:
                home = self._chunk_put_homes.get((t, name),
                                                 inherited.get(name))
                if home is not None and home != x2.id:
                    homes[name] = home
                    grafted += 1
            if homes:
                self._chunk_home_maps[(x2.id, t)] = homes
        # drop our stale view of the old head: the winner's snapshot owns it
        self._state_cache = {k: v for k, v in self._state_cache.items()
                             if k[0] != head_id}
        self._schemas.pop(head_id, None)
        self._chunk_sets = {k: v for k, v in self._chunk_sets.items()
                            if k[0] != head_id}

        new_schema = list(tp_state.schema) + [t for t in old_schema
                                              if t not in tp_state.schema]
        self._put_json(self._schema_key(x2.id), {"tensors": new_schema})
        self._schemas[x2.id] = new_schema
        for t in new_schema:
            if t in ours_touched:
                for f, raw in captured[t].items():
                    if raw is not None:
                        self.put_state(t, f, raw, x2.id)
                self.flush_chunk_set(t)  # writes the "at" home map
                self.flush_diff(t)       # live diff survives the relocation
            else:  # untouched: inherit the winner's state (like _copy_state)
                files = tp_state.tensors.get(t, {})
                for f in self.STATE_FILES:
                    raw = files.get(f)
                    if raw is not None:
                        self.put_state(t, f, raw, x2.id)
                self.put_state(t, "chunk_set.json",
                               json.dumps({"chunks": []}).encode(), x2.id)
                self.put_state(t, "commit_diff.json",
                               json.dumps(CommitDiff().to_json()).encode(),
                               x2.id)
        self.commit_stats["relocations"] += 1
        self.commit_stats["grafted_chunks"] += grafted
        reg = telemetry.registry()
        reg.counter("commit.relocations").inc()
        reg.counter("commit.grafted_chunks").inc(grafted)

    def _copy_state(self, src_id: str, dst_id: str) -> None:
        """Copy small per-tensor state files; chunks stay where created."""
        tensors = self.schema_tensors(src_id)
        self._put_json(self._schema_key(dst_id), {"tensors": tensors})
        self._schemas[dst_id] = list(tensors)
        for t in tensors:
            for fname in self.STATE_FILES:
                raw = self.get_state(t, fname, src_id)
                if raw is not None:
                    self.put_state(t, fname, raw, dst_id)
            self.put_state(t, "chunk_set.json",
                           json.dumps({"chunks": []}).encode(), dst_id)
            self.put_state(t, "commit_diff.json",
                           json.dumps(CommitDiff().to_json()).encode(), dst_id)

    # -------------------------------------------------------------- checkout
    def resolve_ref(self, ref: str) -> str:
        if ref in self.branches:
            return self.branches[ref]
        if ref in self.commits:
            return ref
        raise KeyError(f"unknown branch or commit: {ref!r}")

    def checkout(self, ref: str, create: bool = False) -> str:
        if create:
            if ref in self.branches:
                raise ValueError(f"branch {ref!r} exists")
            base = self.current
            if not base.committed and self.has_uncommitted_changes():
                # paper/deeplake behavior: branching with dirty head auto-commits
                self.commit(f"auto-commit before branch {ref!r}")
                base = self.commits[self.current.parent]  # the sealed node
            parent_id = base.id if base.committed else base.parent
            node = CommitNode(id=_new_id(), parent=parent_id, branch=ref)
            self.commits[node.id] = node
            if parent_id is not None:
                self.commits[parent_id].children.append(node.id)
                self._copy_state(parent_id, node.id)
            else:
                self._put_json(self._schema_key(node.id), {"tensors": []})
                self._schemas[node.id] = []
            self.branches[ref] = node.id
            self.current_id = node.id
        else:
            self.current_id = self.resolve_ref(ref)
        self._load_current_diffs()
        self.save_info()
        return self.current_id

    # ------------------------------------------------------------------ log
    def log(self, ref: Optional[str] = None) -> List[CommitNode]:
        nid: Optional[str] = self.resolve_ref(ref) if ref else self.current_id
        out: List[CommitNode] = []
        while nid is not None:
            node = self.commits[nid]
            if node.committed:
                out.append(node)
            nid = node.parent
        return out

    def ancestry(self, node_id: str) -> List[str]:
        out = []
        nid: Optional[str] = node_id
        while nid is not None:
            out.append(nid)
            nid = self.commits[nid].parent
        return out

    def lowest_common_ancestor(self, a: str, b: str) -> Optional[str]:
        anc_a = set(self.ancestry(a))
        for nid in self.ancestry(b):
            if nid in anc_a:
                return nid
        return None

    # ----------------------------------------------------------------- diff
    def diff_between(self, ref_a: str, ref_b: str) -> Dict[str, Dict[str, dict]]:
        """Per-tensor changes on each side since the LCA: {'a': {...}, 'b': {...}}."""
        a, b = self.resolve_ref(ref_a), self.resolve_ref(ref_b)
        lca = self.lowest_common_ancestor(a, b)

        def path_diffs(nid: str) -> Dict[str, dict]:
            acc: Dict[str, CommitDiff] = {}
            cur: Optional[str] = nid
            while cur is not None and cur != lca:
                for t in self.schema_tensors(cur):
                    d = self._get_state_json(t, "commit_diff.json", cur)
                    if d:
                        cd = CommitDiff.from_json(d)
                        if cd.is_empty():
                            continue
                        tgt = acc.setdefault(t, CommitDiff())
                        if cd.added_count:
                            if tgt.added_count == 0 or cd.added_first < tgt.added_first:
                                tgt.added_first = cd.added_first if tgt.added_count == 0 \
                                    else min(tgt.added_first, cd.added_first)
                            tgt.added_count += cd.added_count
                        tgt.updated |= cd.updated
                        tgt.created |= cd.created
                cur = self.commits[cur].parent
            return {t: d.to_json() for t, d in acc.items()}

        return {"a": path_diffs(a), "b": path_diffs(b), "lca": lca}
