"""Dataset views (§4.3/§4.4): an index subset of a dataset at a version.

Query results are views; views stream into the dataloader or materialize into
a new optimally-chunked dataset.  Views can be persisted (id -> indices) so a
training run can record exactly which rows it consumed (data lineage).
"""

from __future__ import annotations

import json
import uuid
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .tensor import Tensor


class TensorView:
    def __init__(self, tensor: Tensor, indices: np.ndarray) -> None:
        self.tensor = tensor
        self.indices = indices

    def __len__(self) -> int:
        return len(self.indices)

    def read(self, i: int) -> np.ndarray:
        return self.tensor.read(int(self.indices[i]))

    def __getitem__(self, item):
        if isinstance(item, (int, np.integer)):
            return self.read(int(item))
        return [self.read(int(i)) for i in np.arange(len(self))[item]]

    def numpy(self) -> np.ndarray:
        return np.stack(self.tensor.read_batch(self.indices)) if len(self) \
            else np.zeros((0,), dtype=self.tensor.meta.dtype)

    @property
    def name(self) -> str:
        return self.tensor.name


class DatasetView:
    """Row subset of a dataset (optionally at a non-head version)."""

    #: scan-planner report attached by the TQL executor when chunk-statistics
    #: pushdown ran for this view's query (dict, see ScanPlan.report()); the
    #: dataloader reads it to account pruned chunks in LoaderStats.
    scan_plan = None
    #: top-k report attached when ORDER BY + LIMIT ran as a best-bound-first
    #: streamed scan (dict: groups, groups_scanned, groups_skipped, ...);
    #: the dataloader accounts skipped groups like pruned chunks.
    topk_plan = None

    def __init__(self, dataset, indices: np.ndarray,
                 node_id: Optional[str] = None,
                 tensors: Optional[Sequence[str]] = None,
                 derived: Optional[Dict[str, List[Any]]] = None) -> None:
        self.dataset = dataset
        self.indices = np.asarray(indices, dtype=np.int64)
        self.node_id = node_id
        self._tensor_names = list(tensors) if tensors is not None else None
        # computed columns produced by a query's SELECT expressions
        self.derived = derived or {}
        self._bound: Dict[str, Tensor] = {}

    # ------------------------------------------------------------- factory
    @classmethod
    def full(cls, dataset, node_id: Optional[str] = None) -> "DatasetView":
        """All rows of ``dataset`` at a version.  Row counts come from the
        manifest's column-statistics section when the node is covered, so
        opening the full view of a committed dataset binds no tensors."""
        names = dataset.vc.schema_tensors(node_id)
        lengths = []
        for t in names:
            if node_id is None and t in dataset._tensors:
                n = len(dataset._tensors[t])  # live handle may be unflushed
            else:
                n = dataset.vc.tensor_length(t, node_id)
            if n is None:  # uncovered/legacy node: bind for the count
                n = (len(dataset._tensor(t)) if node_id is None
                     else len(Tensor(t, dataset.vc, node_id=node_id)))
            lengths.append(n)
        return cls(dataset, np.arange(min(lengths, default=0)),
                   node_id=node_id)

    # ------------------------------------------------------------- tensors
    @property
    def tensor_names(self) -> List[str]:
        base = (self._tensor_names if self._tensor_names is not None
                else self.dataset.vc.schema_tensors(self.node_id))
        return base + [d for d in self.derived if d not in base]

    def _base_tensor(self, name: str) -> Tensor:
        if name not in self._bound:
            if self.node_id is None:
                self._bound[name] = self.dataset._tensor(name)
            else:
                self._bound[name] = Tensor(name, self.dataset.vc, node_id=self.node_id)
        return self._bound[name]

    def scan_source(self, name: str):
        """Chunk layout + statistics of one base tensor for planning and
        scheduling (:mod:`repro.core.pipeline`), resolved manifest-first:

        * an already-bound tensor (this view's cache, or the dataset's
          live handle, which may hold unflushed appends) always wins;
        * else a covered node's manifest column-statistics section serves
          the scan index with **zero tensor binds and zero requests**;
        * else the tensor is bound (legacy / stale-node fallback).
        """
        from .pipeline import ManifestScanSource, TensorScanSource
        if name in self._bound:
            return TensorScanSource(self._bound[name])
        if self.node_id is None and name in self.dataset._tensors:
            return TensorScanSource(self.dataset._tensors[name])
        cs = self.dataset.vc.column_stats(name, self.node_id)
        if cs is not None:
            return ManifestScanSource(name, cs)
        return TensorScanSource(self._base_tensor(name))

    def tensor(self, name: str) -> TensorView:
        return TensorView(self._base_tensor(name), self.indices)

    def __getitem__(self, item):
        if isinstance(item, str):
            if item in self.derived:
                return list(self.derived[item])
            return self.tensor(item)
        if isinstance(item, (int, np.integer)):
            return self.row(int(item))
        if isinstance(item, slice):
            sel = np.arange(len(self))[item]
        else:
            sel = np.asarray(item, dtype=np.int64)
        return DatasetView(self.dataset, self.indices[sel], self.node_id,
                           self._tensor_names,
                           {k: [v[i] for i in sel] for k, v in self.derived.items()})

    def __len__(self) -> int:
        return len(self.indices)

    def row(self, i: int, tensors: Optional[Sequence[str]] = None) -> Dict[str, Any]:
        names = list(tensors) if tensors else self.tensor_names
        out: Dict[str, Any] = {}
        for n in names:
            if n in self.derived:
                out[n] = self.derived[n][i]
            else:
                out[n] = self._base_tensor(n).read(int(self.indices[i]))
        return out

    def rows(self) -> List[Dict[str, Any]]:
        return [self.row(i) for i in range(len(self))]

    # --------------------------------------------------------------- persist
    def save(self, view_id: Optional[str] = None) -> str:
        """Persist the view (lineage: 'this run trained on exactly these rows')."""
        vid = view_id or uuid.uuid4().hex[:12]
        node = self.node_id or self.dataset.vc.current_id
        self.dataset.storage.put(
            f"views/{vid}.json",
            json.dumps({"node": node,
                        "indices": self.indices.tolist(),
                        "tensors": self._tensor_names}).encode())
        return vid

    @classmethod
    def load(cls, dataset, view_id: str) -> "DatasetView":
        from .storage import retry_transient
        raw = retry_transient(  # control-plane read: transients retried
            lambda: dataset.storage.get(f"views/{view_id}.json"),
            what=f"views/{view_id}.json")
        d = json.loads(raw.decode())
        return cls(dataset, np.asarray(d["indices"], dtype=np.int64),
                   node_id=d["node"], tensors=d["tensors"])

    # --------------------------------------------------------------- chaining
    def query(self, tql: str, engine: str = "auto", use_stats: bool = True,
              stream: Optional[bool] = None, shards: Optional[int] = None,
              tenant: Optional[str] = None) -> "DatasetView":
        from .tql import execute_query
        return execute_query(self, tql, engine=engine, use_stats=use_stats,
                             stream=stream, shards=shards, tenant=tenant)

    def dataloader(self, **kw):
        from .dataloader import DeepLakeLoader
        return DeepLakeLoader(self, **kw)

    def materialize(self, dest=None, **kw):
        from .materialize import materialize
        return materialize(self, dest, **kw)

    # ------------------------------------------------------------- locality
    def chunk_locality(self, tensor: str) -> float:
        """Fraction of adjacent index pairs living in the same chunk.

        1.0 = perfectly sequential layout; low values = sparse view whose
        streaming will be chunk-inefficient (§4.4 motivation for materialize).
        """
        if len(self.indices) < 2:
            return 1.0
        t = self._base_tensor(tensor)
        same = 0
        prev = t.encoder.chunk_ord_of(int(self.indices[0]))
        for i in self.indices[1:]:
            cur = t.encoder.chunk_ord_of(int(i))
            same += (cur == prev)
            prev = cur
        return same / (len(self.indices) - 1)
