"""Visualizer engine, server side (§4.2).

The paper's in-browser WebAssembly renderer cannot exist in this container;
what *is* reproducible is the htype-aware layout logic it depends on: decide
which tensors are primary (image/video/audio), which overlay (bbox/mask/
class_label), group by name prefix, and support sequence scrubbing without
fetching whole samples (per-frame region reads).  ``render_ascii`` gives a
terminal rendering used by the examples.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .htypes import get_htype


@dataclass
class LayoutPanel:
    primary: str
    overlays: List[str] = field(default_factory=list)
    secondary: List[str] = field(default_factory=list)


def plan_layout(ds) -> List[LayoutPanel]:
    """Group tensors into visualization panels by display role + group prefix."""
    roles: Dict[str, str] = {}
    for name, t in ds.tensors.items():
        roles[name] = get_htype(t.meta.htype).display
    primaries = [n for n, r in roles.items() if r == "primary"]
    panels = []
    for p in sorted(primaries):
        prefix = p.rsplit("/", 1)[0] + "/" if "/" in p else ""
        panel = LayoutPanel(primary=p)
        for n, r in sorted(roles.items()):
            if n == p:
                continue
            same_group = (n.startswith(prefix) if prefix else "/" not in n)
            if r == "overlay" and same_group:
                panel.overlays.append(n)
            elif r == "secondary" and same_group:
                panel.secondary.append(n)
        panels.append(panel)
    if not panels:  # tabular-only dataset: one panel of secondaries
        panels.append(LayoutPanel(primary="", secondary=sorted(roles)))
    return panels


def frame_of_sequence(ds, tensor: str, idx: int, frame: int) -> np.ndarray:
    """Jump to one frame of a sequence[...] sample without fetching the rest
    (§4.2 'jump to the specific position of the sequence')."""
    t = ds[tensor]
    if not t.is_sequence:
        raise TypeError(f"{tensor} is not a sequence htype")
    return t.read_region(idx, (slice(frame, frame + 1),))[0]


_RAMP = " .:-=+*#%@"


def _ascii_image(img: np.ndarray, width: int = 48) -> str:
    if img.ndim == 3:
        img = img.mean(axis=-1)
    h, w = img.shape
    step = max(1, w // width)
    rows = []
    for y in range(0, h, step * 2):
        row = ""
        for x in range(0, w, step):
            v = float(img[y, x]) / max(float(img.max()), 1.0)
            row += _RAMP[min(int(v * (len(_RAMP) - 1)), len(_RAMP) - 1)]
        rows.append(row)
    return "\n".join(rows)


def render_ascii(ds, idx: int, width: int = 48) -> str:
    """Terminal rendering of one row following the planned layout."""
    out = []
    for panel in plan_layout(ds):
        if panel.primary:
            arr = ds[panel.primary].read(idx)
            out.append(f"┌─ {panel.primary} {arr.shape} {arr.dtype}")
            if arr.ndim in (2, 3):
                out.append(_ascii_image(arr, width))
        for name in panel.overlays + panel.secondary:
            t = ds[name]
            if idx >= len(t):
                continue
            v = t.read(idx)
            if t.meta.htype == "text":
                out.append(f"│ {name} = {v.tobytes().decode(errors='replace')!r}")
            elif v.size <= 8:
                out.append(f"│ {name} = {np.array2string(v, precision=2)}")
            else:
                out.append(f"│ {name}: shape={v.shape} mean={v.mean():.3f}")
    return "\n".join(out)
