from .pipeline import DeviceFeeder, TokenBatcher, host_slice
from .synthetic import build_image_dataset, build_token_dataset

__all__ = ["DeviceFeeder", "TokenBatcher", "build_image_dataset",
           "build_token_dataset", "host_slice"]
