"""Deep Lake -> JAX training integration (the paper's C5 meeting pjit).

``TokenBatcher`` packs ragged documents from a Deep Lake view into fixed
(B, S+1) token blocks (targets = inputs shifted).  ``DeviceFeeder`` turns a
host batch iterator into sharded global device arrays with DOUBLE BUFFERING:
the next batch's device_put overlaps the current train step, so at steady
state the accelerator never waits on H2D — the Fig 6/7 property, carried to
the device boundary.

Multi-host note: each host feeds only its addressable shard of the global
batch (`host_slice`); in this single-process container that slice is the
whole batch, but the code path (slice -> device_put with NamedSharding) is
the production one.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core.dataloader import DeepLakeLoader
from repro.core.views import DatasetView


class TokenBatcher:
    """Streams (tokens, targets, loss_mask) host batches from a token view."""

    def __init__(self, view: DatasetView, *, batch_size: int, seq_len: int,
                 shuffle: bool = True, num_workers: int = 4, seed: int = 0,
                 pad_id: int = 0, num_codebooks: int = 0) -> None:
        self.view = view
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.num_codebooks = num_codebooks
        self.pad_id = pad_id
        self.loader = DeepLakeLoader(view, batch_size=1, shuffle=shuffle,
                                     num_workers=num_workers, seed=seed,
                                     tensors=["tokens"], collate="list")
        self._buf = np.zeros((0,), np.int32)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        B, S = self.batch_size, self.seq_len
        need = B * (S + 1)
        self._buf = np.zeros((0,), np.int32)
        for batch in self.loader:
            doc = np.asarray(batch["tokens"][0], np.int32).reshape(-1)
            self._buf = np.concatenate([self._buf, doc])
            while len(self._buf) >= need:
                block = self._buf[:need].reshape(B, S + 1)
                self._buf = self._buf[need:]
                out = {"tokens": block[:, :-1],
                       "targets": block[:, 1:],
                       "loss_mask": np.ones((B, S), np.float32)}
                if self.num_codebooks:
                    k = self.num_codebooks
                    out["tokens"] = np.stack([block[:, :-1]] * k, axis=1)
                    out["targets"] = np.stack([block[:, 1:]] * k, axis=1)
                yield out


class DeviceFeeder:
    """Double-buffered host->device feeder with per-batch NamedShardings."""

    def __init__(self, host_iter: Iterator[Dict[str, np.ndarray]],
                 shardings: Dict[str, NamedSharding], *,
                 prefetch: int = 2) -> None:
        self.host_iter = host_iter
        self.shardings = shardings
        self.prefetch = max(1, prefetch)

    def _put(self, batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        return {k: jax.device_put(v, self.shardings[k]) if k in self.shardings
                else jax.device_put(v) for k, v in batch.items()}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        DONE = object()
        err: list = []

        def producer():
            try:
                for batch in self.host_iter:
                    q.put(self._put(batch))  # device_put overlaps consumer step
            except BaseException as e:
                err.append(e)
            finally:
                q.put(DONE)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is DONE:
                if err:
                    raise err[0]
                return
            yield item


def host_slice(batch: Dict[str, np.ndarray], process_index: int,
               process_count: int) -> Dict[str, np.ndarray]:
    """Each host contributes its contiguous slice of the global batch."""
    out = {}
    for k, v in batch.items():
        per = v.shape[0] // process_count
        out[k] = v[process_index * per:(process_index + 1) * per]
    return out
