"""Synthetic Deep Lake datasets for training/benchmarks.

Mirrors the paper's experiment data: the "random dataset" of Fig 5 (random
images, here with the quant8 JPEG-class codec) and token corpora for the LM
architectures.  Everything is written through the public Dataset API, so
benchmarks exercise the actual ingestion path (Fig 5a).
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.dataset import Dataset


def build_token_dataset(ds: Dataset, *, num_docs: int = 256,
                        doc_len: int = 1024, vocab_size: int = 50_000,
                        seed: int = 0, commit: bool = True) -> Dataset:
    """Documents of int32 tokens (ragged lengths ±25%) + doc ids."""
    if "tokens" not in ds.tensor_names:
        ds.create_tensor("tokens", htype="tokens", dtype="int32",
                         sample_compression="zlib",
                         min_chunk_size=256 << 10, max_chunk_size=1 << 20)
        ds.create_tensor("doc_id", htype="class_label")
    rng = np.random.default_rng(seed)
    for i in range(num_docs):
        n = int(doc_len * rng.uniform(0.75, 1.25))
        ds.append({"tokens": rng.integers(0, vocab_size, n).astype(np.int32),
                   "doc_id": np.int64(i)})
    if commit:
        ds.commit(f"synthetic tokens x{num_docs}")
    return ds


def build_image_dataset(ds: Dataset, *, num_images: int = 512,
                        size: Tuple[int, int] = (250, 250), channels: int = 3,
                        codec: str = "quant8", seed: int = 0,
                        num_classes: int = 10, commit: bool = True) -> Dataset:
    """The paper's 'random dataset': colored (size x size) images (Fig 5)."""
    if "images" not in ds.tensor_names:
        ds.create_tensor("images", htype="image", dtype="uint8",
                         sample_compression=codec,
                         min_chunk_size=4 << 20, max_chunk_size=16 << 20)
        ds.create_tensor("labels", htype="class_label")
    rng = np.random.default_rng(seed)
    h, w = size
    for i in range(num_images):
        # smooth random fields compress like photos (pure noise wouldn't)
        base = rng.integers(0, 255, (h // 8 + 1, w // 8 + 1, channels))
        img = np.kron(base, np.ones((8, 8, 1)))[:h, :w].astype(np.uint8)
        img = np.clip(img + rng.integers(-8, 8, img.shape), 0, 255).astype(np.uint8)
        ds.append({"images": img, "labels": np.int64(i % num_classes)})
    if commit:
        ds.commit(f"synthetic images x{num_images}")
    return ds
