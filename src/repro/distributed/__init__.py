from .collectives import (collective_wire_bytes, make_quantized_allreduce,
                          quantized_psum)
from .fault_tolerance import (FailureInjector, HostFailure, StragglerDetector,
                              run_resilient)
from .sharding import (batch_specs, fit_spec, make_rules, make_shard_fn,
                       pspec_for_specs, sharding_for_specs, spec_for)

__all__ = ["FailureInjector", "HostFailure", "StragglerDetector",
           "batch_specs", "collective_wire_bytes", "fit_spec", "make_rules",
           "make_quantized_allreduce", "make_shard_fn", "pspec_for_specs",
           "quantized_psum", "run_resilient", "sharding_for_specs",
           "spec_for"]
