"""Distributed-training utilities.

``fault_tolerance`` is dependency-free and imported eagerly — the storage
layer's :class:`~repro.core.fetch.FetchEngine` reuses its
:class:`StragglerDetector` as the hedge trigger for prefetches, and must
not drag jax into pure-I/O paths.  The jax-backed submodules
(``collectives``, ``sharding``) load lazily on first attribute access.
"""

from .fault_tolerance import (FailureInjector, HostFailure, StragglerDetector,
                              run_resilient)

_COLLECTIVES = {"collective_wire_bytes", "make_quantized_allreduce",
                "quantized_psum"}
_SHARDING = {"batch_specs", "fit_spec", "make_rules", "make_shard_fn",
             "pspec_for_specs", "shard_groups", "shard_of",
             "sharding_for_specs", "spec_for"}

__all__ = ["FailureInjector", "HostFailure", "StragglerDetector",
           "run_resilient"] + sorted(_COLLECTIVES | _SHARDING)


def __getattr__(name):
    if name in _COLLECTIVES:
        from . import collectives
        return getattr(collectives, name)
    if name in _SHARDING:
        from . import sharding
        return getattr(sharding, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
