"""Explicit collectives for the cross-pod data-parallel path (shard_map).

Under plain pjit, gradient reductions are GSPMD-inserted and always run at
the accumulation dtype.  For the *cross-pod* hop (slow DCI links) we expose
an explicit quantized all-reduce: int8 payload + per-shard scale, error
feedback handled by the caller (optim.grad_compress).  Used by the
``--grad-compress`` training mode and tested on a host-device mesh.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def quantized_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce mean with int8 wire format (inside shard_map)."""
    absmax = jnp.max(jnp.abs(x)) + 1e-12
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    # wire payload is int8; sum in int32 to avoid overflow across shards
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)          # scales are tiny
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # average of dequantized shards (per-shard scale ~ shared scale regime)
    return (total.astype(jnp.float32) * (scale_sum / n) / n).astype(x.dtype)


def make_quantized_allreduce(mesh: Mesh, axis_name: str = "pod"):
    """Tree-level quantized mean-all-reduce over ``axis_name``."""

    def one(x):
        rest = P(*([None] * x.ndim))
        f = shard_map(functools.partial(quantized_psum, axis_name=axis_name),
                      mesh=mesh, in_specs=P(axis_name, *([None] * (x.ndim - 1))),
                      out_specs=P(None, *([None] * (x.ndim - 1))),
                      check_rep=False)
        return f(x)

    def allreduce(tree: Any) -> Any:
        return jax.tree_util.tree_map(one, tree)

    return allreduce


def collective_wire_bytes(tree, compressed: bool) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    if compressed:
        return sum(l.size + 4 for l in leaves)
    return sum(l.size * l.dtype.itemsize for l in leaves)
