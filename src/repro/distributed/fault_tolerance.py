"""Fault tolerance + straggler mitigation for long training runs.

* :class:`StragglerDetector` — per-step wall times, EWMA baseline; a step
  (or in multi-host deployments, a host heartbeat) slower than
  ``threshold ×`` baseline is flagged; repeated flags trigger the mitigation
  callback (on real fleets: demote/replace the host; here: logged + counted,
  and the training driver rebuilds its data pipeline, the most common
  CPU-side straggler cause).
* :class:`FailureInjector` — deterministic fault injection for tests/examples
  (raise at step N, or with probability p).
* :func:`run_resilient` — the restart loop: run the training driver; on a
  (simulated or real) failure, restore the latest checkpoint — possibly onto
  a *smaller* mesh (elastic rescale) — and continue.  Guarantees progress:
  at most ``checkpoint_every`` steps are ever recomputed.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional


class HostFailure(RuntimeError):
    """Stands in for a lost host / SIGTERM'd worker."""


@dataclass
class StragglerDetector:
    """Also serves as the hedge trigger of the storage prefetch pool
    (:class:`~repro.core.fetch.FetchEngine`): clean fetch wall times feed
    the baseline, and a request outliving ``threshold ×`` baseline is a
    straggler the engine duplicates.  ``observe`` is therefore thread-safe
    — training drivers call it from one thread, the prefetch pool from
    many."""

    threshold: float = 2.0
    alpha: float = 0.2
    patience: int = 3
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _ewma: Optional[float] = None
    _strikes: int = 0
    flagged_steps: List[int] = field(default_factory=list)
    mitigations: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    @property
    def baseline(self) -> Optional[float]:
        """Current healthy-step EWMA (None until the first observation)."""
        with self._lock:
            return self._ewma

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True when mitigation fired at this step."""
        with self._lock:
            if self._ewma is None:
                self._ewma = seconds
                return False
            slow = seconds > self.threshold * self._ewma
            if slow:
                self._strikes += 1
                self.flagged_steps.append(step)
            else:
                self._strikes = 0
                # only fold healthy steps into the baseline
                self._ewma = ((1 - self.alpha) * self._ewma
                              + self.alpha * seconds)
            fire = self._strikes >= self.patience
            if fire:
                self.mitigations += 1
                self._strikes = 0
            ewma = self._ewma
        if fire:
            if self.on_straggler:
                self.on_straggler(step, seconds, ewma)
            return True
        return False


@dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    seen: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.seen:
            self.seen.add(step)
            raise HostFailure(f"injected host failure at step {step}")


def run_resilient(
    make_runner: Callable[[Optional[int]], Callable[[], int]],
    *,
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, BaseException], None]] = None,
) -> Dict[str, Any]:
    """``make_runner(restore_step)`` builds a driver callable that trains to
    completion and returns the final step; on HostFailure we rebuild (restore
    from checkpoint, maybe re-mesh) and resume."""
    restarts = 0
    restore_step: Optional[int] = None
    while True:
        runner = make_runner(restore_step)
        try:
            final_step = runner()
            return {"final_step": final_step, "restarts": restarts}
        except HostFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart:
                on_restart(restarts, e)
            restore_step = None  # runner restores from latest itself
