"""Logical-axis -> mesh sharding rules, with divisibility safeguards.

Rules map logical axis names ("batch", "fsdp", "model", "heads", "vocab",
"ff", "expert", "seq") to mesh axes.  ``fit_spec`` drops a mesh axis when a
dimension does not divide it (e.g. starcoder2's 24 heads on a 16-wide model
axis, granite's 49155 vocab) — GQA KV replication and unsharded odd vocabs
are standard practice, and the roofline table shows their cost honestly.

Per-cell rule selection:
* train/prefill/decode default: batch+fsdp -> ("pod","data"), tensor axes ->
  "model", seq unsharded;
* long_500k (global_batch=1): batch unshardable -> the KV/latent cache's
  *sequence* axis takes ("pod","data") instead (sequence-parallel decode).

jax (and the model param registry) are imported lazily inside the
functions that need them: the dependency-free partitioners at the top
(``shard_groups``, ``shard_of``) are reused by the lakehouse serving tier
(:mod:`repro.core.serving`, ``ScanPipeline.stream_sharded``) for
chunk-group -> worker assignment, and pure-I/O paths must not drag jax in
(same contract as :mod:`repro.distributed.fault_tolerance`).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple


# ---------------------------------------------------------------------------
# dependency-free work partitioners (no jax; safe for repro.core imports)

def shard_groups(n_items: int, n_shards: int) -> List[List[int]]:
    """Partition ``range(n_items)`` across ``n_shards`` workers round-robin
    in item order: shard ``w`` owns items ``w, w + n_shards, ...``.

    Round-robin (rather than contiguous blocks) keeps the *earliest* items
    at the head of every shard's list, so when items are chunk groups in
    plan order each worker starts on the group the consumer needs soonest —
    the serving tier's ordered re-merge then never waits on a worker that
    is busy with far-future groups.  Empty shards are dropped.
    """
    if n_items < 0 or n_shards <= 0:
        raise ValueError(f"invalid partition: {n_items} items, "
                         f"{n_shards} shards")
    shards = [list(range(w, n_items, n_shards)) for w in range(n_shards)]
    return [s for s in shards if s]


def shard_of(item: int, n_shards: int) -> int:
    """Inverse of :func:`shard_groups`: which shard owns ``item``."""
    if n_shards <= 0:
        raise ValueError(f"invalid shard count {n_shards}")
    return item % n_shards


# ---------------------------------------------------------------------------
# jax-backed mesh sharding (imports deferred to first use)

def make_rules(kind: str = "train", *, long_context: bool = False,
               fsdp: bool = True, seq_shard=None) -> Dict[str, Any]:
    """``seq_shard``: None | mesh-axis name for the cache sequence dim.
    Decode with batch on (pod, data) can hand "model" to the cache sequence
    (beyond-paper H2b: keeps 32k caches sharded when kv_heads < model axis)."""
    from repro.models.param import DEFAULT_RULES
    rules = dict(DEFAULT_RULES)
    if not fsdp:
        rules["fsdp"] = None
    if long_context:
        # batch=1: hand the data axes to the sequence dimension instead
        rules["batch"] = None
        rules["seq"] = ("pod", "data")
    elif seq_shard:
        rules["seq"] = "data" if seq_shard is True else seq_shard
    else:
        rules["seq"] = None
    return rules


def axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, (tuple, list)) else (entry,)
    size = 1
    for a in axes:
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return size


def fit_spec(shape: Tuple[int, ...], spec, mesh):
    """Drop mesh axes from dims they don't divide (GSPMD-safe fallback)."""
    from jax.sharding import PartitionSpec as P
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        size = axis_size(mesh, entry)
        out.append(entry if size and dim % size == 0 else None)
    return P(*out)


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             mesh, rules: Dict[str, Any]):
    from repro.models.param import logical_to_spec
    return fit_spec(shape, logical_to_spec(axes, rules, mesh), mesh)


def sharding_for_specs(specs, mesh, rules: Dict[str, Any]):
    """ParamSpec pytree -> NamedSharding pytree (divisibility-safe)."""
    from jax.sharding import NamedSharding

    from repro.models.param import tree_map_specs
    return tree_map_specs(
        lambda s: NamedSharding(mesh, spec_for(s.shape, s.axes, mesh, rules)),
        specs)


def pspec_for_specs(specs, mesh, rules: Dict[str, Any]):
    from repro.models.param import tree_map_specs
    return tree_map_specs(
        lambda s: spec_for(s.shape, s.axes, mesh, rules), specs)


def make_shard_fn(mesh, rules: Dict[str, Any]) -> Callable:
    """Activation-sharding-constraint callback threaded through the models."""
    if mesh is None:
        return lambda x, axes=None: x

    import jax
    from jax.sharding import NamedSharding

    def shard(x, axes=None):
        if axes is None:
            return x
        spec = spec_for(x.shape, tuple(axes), mesh, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard


def batch_specs(cfg, shape_cfg, mesh, rules: Dict[str, Any]):
    """(ShapeDtypeStruct pytree, NamedSharding pytree) for a train/prefill
    batch of the given architecture and shape point."""
    import jax
    import numpy as np
    from jax.sharding import NamedSharding

    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    ax: Dict[str, Tuple[Optional[str], ...]] = {}
    if cfg.num_codebooks:
        specs["tokens"] = jax.ShapeDtypeStruct((B, cfg.num_codebooks, S), np.int32)
        ax["tokens"] = ("batch", None, None)
        if shape_cfg.kind == "train":
            specs["targets"] = specs["tokens"]
            ax["targets"] = ax["tokens"]
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), np.int32)
        ax["tokens"] = ("batch", None)
        if shape_cfg.kind == "train":
            specs["targets"] = specs["tokens"]
            ax["targets"] = ax["tokens"]
    if shape_cfg.kind == "train":
        specs["loss_mask"] = jax.ShapeDtypeStruct((B, S), np.float32)
        ax["loss_mask"] = ("batch", None)
    if cfg.num_image_tokens:
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, 1024), np.float32)
        ax["image_embeds"] = ("batch", None, None)
    shardings = {k: NamedSharding(mesh, spec_for(v.shape, ax[k], mesh, rules))
                 for k, v in specs.items()}
    return specs, shardings
