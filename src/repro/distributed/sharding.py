"""Logical-axis -> mesh sharding rules, with divisibility safeguards.

Rules map logical axis names ("batch", "fsdp", "model", "heads", "vocab",
"ff", "expert", "seq") to mesh axes.  ``fit_spec`` drops a mesh axis when a
dimension does not divide it (e.g. starcoder2's 24 heads on a 16-wide model
axis, granite's 49155 vocab) — GQA KV replication and unsharded odd vocabs
are standard practice, and the roofline table shows their cost honestly.

Per-cell rule selection:
* train/prefill/decode default: batch+fsdp -> ("pod","data"), tensor axes ->
  "model", seq unsharded;
* long_500k (global_batch=1): batch unshardable -> the KV/latent cache's
  *sequence* axis takes ("pod","data") instead (sequence-parallel decode).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.param import DEFAULT_RULES, ParamSpec, logical_to_spec, tree_map_specs


def make_rules(kind: str = "train", *, long_context: bool = False,
               fsdp: bool = True, seq_shard=None) -> Dict[str, Any]:
    """``seq_shard``: None | mesh-axis name for the cache sequence dim.
    Decode with batch on (pod, data) can hand "model" to the cache sequence
    (beyond-paper H2b: keeps 32k caches sharded when kv_heads < model axis)."""
    rules = dict(DEFAULT_RULES)
    if not fsdp:
        rules["fsdp"] = None
    if long_context:
        # batch=1: hand the data axes to the sequence dimension instead
        rules["batch"] = None
        rules["seq"] = ("pod", "data")
    elif seq_shard:
        rules["seq"] = "data" if seq_shard is True else seq_shard
    else:
        rules["seq"] = None
    return rules


def axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, (tuple, list)) else (entry,)
    size = 1
    for a in axes:
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return size


def fit_spec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't divide (GSPMD-safe fallback)."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        size = axis_size(mesh, entry)
        out.append(entry if size and dim % size == 0 else None)
    return P(*out)


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             mesh: Mesh, rules: Dict[str, Any]) -> P:
    return fit_spec(shape, logical_to_spec(axes, rules, mesh), mesh)


def sharding_for_specs(specs, mesh: Mesh, rules: Dict[str, Any]):
    """ParamSpec pytree -> NamedSharding pytree (divisibility-safe)."""
    return tree_map_specs(
        lambda s: NamedSharding(mesh, spec_for(s.shape, s.axes, mesh, rules)),
        specs)


def pspec_for_specs(specs, mesh: Mesh, rules: Dict[str, Any]):
    return tree_map_specs(
        lambda s: spec_for(s.shape, s.axes, mesh, rules), specs)


def make_shard_fn(mesh: Optional[Mesh], rules: Dict[str, Any]) -> Callable:
    """Activation-sharding-constraint callback threaded through the models."""
    if mesh is None:
        return lambda x, axes=None: x

    def shard(x, axes=None):
        if axes is None:
            return x
        spec = spec_for(x.shape, tuple(axes), mesh, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard


def batch_specs(cfg, shape_cfg, mesh: Mesh, rules: Dict[str, Any]):
    """(ShapeDtypeStruct pytree, NamedSharding pytree) for a train/prefill
    batch of the given architecture and shape point."""
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    ax: Dict[str, Tuple[Optional[str], ...]] = {}
    if cfg.num_codebooks:
        specs["tokens"] = jax.ShapeDtypeStruct((B, cfg.num_codebooks, S), np.int32)
        ax["tokens"] = ("batch", None, None)
        if shape_cfg.kind == "train":
            specs["targets"] = specs["tokens"]
            ax["targets"] = ax["tokens"]
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), np.int32)
        ax["tokens"] = ("batch", None)
        if shape_cfg.kind == "train":
            specs["targets"] = specs["tokens"]
            ax["targets"] = ax["tokens"]
    if shape_cfg.kind == "train":
        specs["loss_mask"] = jax.ShapeDtypeStruct((B, S), np.float32)
        ax["loss_mask"] = ("batch", None)
    if cfg.num_image_tokens:
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, 1024), np.float32)
        ax["image_embeds"] = ("batch", None, None)
    shardings = {k: NamedSharding(mesh, spec_for(v.shape, ax[k], mesh, rules))
                 for k, v in specs.items()}
    return specs, shardings
