"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships as <name>/<name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper, custom_vjp where trainable) and ref.py (pure-jnp
oracle); tests sweep shapes/dtypes and assert allclose vs the oracle in
interpret mode (this container is CPU-only; TPU is the lowering target).
"""

from . import decode_attention, flash_attention, fused_preprocess, ssd_scan

__all__ = ["decode_attention", "flash_attention", "fused_preprocess",
           "ssd_scan"]
