"""Single-token decode attention kernel (TPU Pallas).

One query token per (batch, head) attends over a long KV cache.  Grid
(B, H, nT) with the cache-block axis innermost: each core streams cache
blocks HBM->VMEM while the (1, D) accumulator + scalar softmax stats stay
in VMEM scratch — flash-decoding restructured for the TPU's sequential
grid iteration (no cross-split reduction pass needed).

The current position arrives as a (1, 1) scalar operand; blocks entirely
beyond ``pos`` are skipped with ``pl.when`` — at 500k cache and pos=1000
that's 99.8% of the streaming skipped, which a masked XLA einsum cannot do.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_t: int, n_t: int, window: int):
    ti = pl.program_id(2)
    pos = pos_ref[0, 0]

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # ring-buffer caches (window) hold at most min(pos+1, T) valid entries
    limit = jnp.minimum(pos + 1, jnp.int32(n_t * block_t)) if window else pos + 1

    @pl.when(ti * block_t < limit)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)            # (1, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bt, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (1, bt)
        s = s * (1.0 / (q.shape[-1] ** 0.5))
        idx = ti * block_t + jax.lax.broadcasted_iota(jnp.int32, (1, block_t), 1)
        s = jnp.where(idx < limit, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ti == n_t - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(q, cache_k, cache_v, *, pos, window: int = 0,
                         block_t: int = 512, interpret: bool = False):
    """q (B,H,D); caches (B,T,Hkv,D); pos () int32 -> out (B,H,D)."""
    B, H, D = q.shape
    T, Hkv = cache_k.shape[1], cache_k.shape[2]
    G = H // Hkv
    block_t = min(block_t, T)
    assert T % block_t == 0, (T, block_t)
    n_t = T // block_t
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1, 1)
    q4 = q[:, None]                                          # (B,1,H,D)

    kernel = functools.partial(_kernel, block_t=block_t, n_t=n_t, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_t),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ti: (0, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ti: (b, 0, h, 0)),
            pl.BlockSpec((1, block_t, 1, D), lambda b, h, ti: (b, ti, h // G, 0)),
            pl.BlockSpec((1, block_t, 1, D), lambda b, h, ti: (b, ti, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ti: (b, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q4, cache_k, cache_v)
    return out[:, 0]
