"""Jit'd wrapper for the decode-attention kernel (inference only: no vjp)."""

from __future__ import annotations

from .decode_attention import decode_attention_fwd


def decode_attention(q, cache_k, cache_v, *, pos, window: int = 0,
                     block_t: int = 512, interpret: bool = False):
    return decode_attention_fwd(q, cache_k, cache_v, pos=pos, window=window,
                                block_t=block_t, interpret=interpret)
