"""Pure-jnp oracle for single-token decode attention."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ref_decode_attention(q, cache_k, cache_v, *, pos, window: int = 0):
    """q (B,H,D); caches (B,T,Hkv,D) -> (B,H,D).

    Valid cache entries: idx <= pos (full cache) or the ring-buffer rule
    idx < min(pos+1, T) for window caches.
    """
    B, H, D = q.shape
    T, Hkv = cache_k.shape[1], cache_k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bgnd,btgd->bgnt", qg, cache_k.astype(jnp.float32))
    s = s / np.sqrt(D)
    idx = jnp.arange(T)
    limit = jnp.minimum(pos + 1, T) if window else pos + 1
    s = jnp.where((idx < limit)[None, None, None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bgnt,btgd->bgnd", p, cache_v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
