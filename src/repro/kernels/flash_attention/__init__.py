from . import ops, ref
from .flash_attention import flash_attention_fwd
from .ops import flash_attention

__all__ = ["flash_attention", "flash_attention_fwd", "ops", "ref"]
