"""Flash attention forward kernel (TPU Pallas).

Tiling: grid (B, H, nQ, nK), K-blocks innermost so each core streams KV
blocks through VMEM while the (block_q, D) accumulator + (block_q,) softmax
stats live in VMEM scratch across the nK steps.  GQA is handled in the
BlockSpec index maps (kv head = h // group_size), so no KV replication ever
touches HBM.  Causal/sliding-window blocks that are fully masked are skipped
with ``pl.when`` (the roofline win vs the masked XLA path).

Block sizes default to (128, 512): MXU-aligned (multiples of 128 on the
contracted and lane dims) and sized so  q(128xD) + k,v(512xD) + acc fit in
~2 MB of VMEM at D=256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, window: int, block_q: int,
               block_k: int, n_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # skip blocks strictly above the causal diagonal / beyond the window
    def need_block():
        ok = True
        if causal:
            ok = jnp.logical_and(ok, k_start <= q_start + block_q - 1)
        if window:
            ok = jnp.logical_and(ok, k_start + block_k - 1 >= q_start - window + 1)
        return ok

    @pl.when(need_block())
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, q_pos >= k_pos)
        if window:
            ok = jnp.logical_and(ok, q_pos - k_pos < window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None, block_q: int = 128,
                        block_k: int = 512, interpret: bool = False):
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    if scale is None:
        scale = float(1.0 / (D ** 0.5))
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    n_q, n_k = S // block_q, T // block_k
    grid = (B, H, n_q, n_k)

    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),     # l (running sum)
        ],
        interpret=interpret,
    )(q, k, v)
