"""Jit'd public wrapper for the flash-attention kernel.

Forward runs the Pallas kernel; backward is a custom VJP that recomputes
attention with the pure-jnp reference formula (activation-recompute bwd —
the standard pattern while a dedicated bwd kernel lands; on CPU containers
only the interpret-mode forward is exercised anyway).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_fwd
from .ref import ref_attention


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 512, interpret: bool = False):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=interpret)


def _fwd(q, k, v, causal, window, scale, block_q, block_k, interpret):
    out = flash_attention(q, k, v, causal, window, scale, block_q, block_k,
                          interpret)
    return out, (q, k, v)


def _bwd(causal, window, scale, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref_attention(q_, k_, v_, causal=causal,
                                         window=window, scale=scale),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
