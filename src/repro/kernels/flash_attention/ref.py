"""Pure-jnp oracle for flash attention (causal + sliding window + GQA)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ref_attention(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None) -> jnp.ndarray:
    """q (B,S,H,D), k/v (B,T,Hkv,D) -> (B,S,H,D); materializes SxT (oracle)."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bsgnd,btgd->bgnst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = ok & (qi >= ki)
    if window:
        ok = ok & (qi - ki < window)
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bgnst,btgd->bsgnd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)
