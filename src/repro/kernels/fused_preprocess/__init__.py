from . import ops, ref
from .ops import fused_preprocess

__all__ = ["fused_preprocess", "ops", "ref"]
