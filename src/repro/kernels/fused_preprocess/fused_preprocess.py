"""Fused crop -> cast -> normalize kernel (TPU Pallas).

The device-side tail of the paper's data path: TQL projections like
``images[100:500, 100:500, :]`` followed by normalization (§4.3 Fig 4)
lower to ONE kernel that reads the uint8 crop window from HBM once and
writes normalized f32 — instead of XLA's slice + convert + sub + mul chain
(4 HBM round-trips of the full image).  Used by the data pipeline after
device_put of raw uint8 batches (halves H2D bytes vs shipping f32).

Grid (B,): one program per image; the BlockSpec block IS the crop window,
so out-of-crop pixels are never fetched.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(img_ref, mean_ref, std_ref, out_ref):
    crop = img_ref[0].astype(jnp.float32) / 255.0        # (ch, cw, C)
    mean = mean_ref[0, 0]                                # (C,)
    std = std_ref[0, 0]
    out_ref[0] = (crop - mean[None, None, :]) / std[None, None, :]


def fused_preprocess_fwd(images, crop: Tuple[int, int, int, int],
                         mean: Sequence[float], std: Sequence[float],
                         interpret: bool = False):
    """images (B,H,W,C) uint8; crop (y0, x0, h, w) -> (B,h,w,C) float32."""
    B, H, W, C = images.shape
    y0, x0, h, w = crop
    assert 0 <= y0 and y0 + h <= H and 0 <= x0 and x0 + w <= W, (crop, images.shape)
    mean_a = jnp.asarray(mean, jnp.float32).reshape(1, 1, C)
    std_a = jnp.asarray(std, jnp.float32).reshape(1, 1, C)
    # block = exactly the crop window; index map offsets in block units are
    # only possible when aligned, so we pass element offsets via a pre-slice
    # view: pallas BlockSpec indexes in block multiples, hence lax.slice here
    # stays INSIDE the kernel domain by blocking the full row/col span only
    # when offsets are block-aligned. General offsets: shift with a cheap
    # device-free relayout below.
    imgs = jax.lax.slice(images, (0, y0, x0, 0), (B, y0 + h, x0 + w, C))
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, h, w, C), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1, C), lambda b: (0, 0, 0)),
            pl.BlockSpec((1, 1, C), lambda b: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w, C), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, h, w, C), jnp.float32),
        interpret=interpret,
    )(imgs, mean_a, std_a)
