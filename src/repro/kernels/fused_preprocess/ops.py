"""Jit'd wrapper for fused preprocess (data path: no vjp needed)."""

from __future__ import annotations

import functools

import jax

from .fused_preprocess import fused_preprocess_fwd


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def fused_preprocess(images, crop, mean, std, interpret: bool = False):
    return fused_preprocess_fwd(images, crop, tuple(mean), tuple(std),
                                interpret=interpret)
