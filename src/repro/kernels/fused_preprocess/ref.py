"""Pure-jnp oracle for fused crop+normalize."""

from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp


def ref_preprocess(images, crop: Tuple[int, int, int, int],
                   mean: Sequence[float], std: Sequence[float]):
    y0, x0, h, w = crop
    x = images[:, y0:y0 + h, x0:x0 + w, :].astype(jnp.float32) / 255.0
    mean_a = jnp.asarray(mean, jnp.float32)
    std_a = jnp.asarray(std, jnp.float32)
    return (x - mean_a) / std_a
