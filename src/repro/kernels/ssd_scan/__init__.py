from . import ops, ref
from .ops import ssd
from .ssd_scan import ssd_fwd

__all__ = ["ops", "ref", "ssd", "ssd_fwd"]
