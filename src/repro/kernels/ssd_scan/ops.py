"""Jit'd wrapper for the SSD kernel; bwd = recompute via the chunked XLA
formulation (identical math), standard recompute-vjp pattern."""

from __future__ import annotations

import functools

import jax

from repro.models.ssm import ssd_chunked

from .ssd_scan import ssd_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ssd(x, dt, A, Bm, Cm, chunk: int = 256, interpret: bool = False):
    return ssd_fwd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


def _fwd(x, dt, A, Bm, Cm, chunk, interpret):
    out = ssd(x, dt, A, Bm, Cm, chunk, interpret)
    return out, (x, dt, A, Bm, Cm)


def _bwd(chunk, interpret, res, g):
    x, dt, A, Bm, Cm = res
    gy, gstate = g

    def f(x_, dt_, A_, B_, C_):
        return ssd_chunked(x_, dt_, A_, B_, C_, chunk=chunk)

    _, vjp = jax.vjp(f, x, dt, A, Bm, Cm)
    return vjp((gy, gstate))


ssd.defvjp(_fwd, _bwd)
