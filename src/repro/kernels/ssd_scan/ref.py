"""Pure-jnp oracle for the SSD kernel: the naive per-token recurrence."""

from repro.models.ssm import ssd_reference as ref_ssd  # single source of truth

__all__ = ["ref_ssd"]
