"""Mamba2 SSD chunked-scan kernel (TPU Pallas).

The SSD decomposition (DESIGN.md §6, arXiv:2405.21060) maps perfectly onto
the TPU: the intra-chunk quadratic part is three (Q×Q)/(Q×N)/(Q×P) matmuls
(MXU), and the inter-chunk recurrence is a sequential state pass that lives
in VMEM scratch across the innermost grid axis.

Grid (B, n_heads, n_chunks), chunks innermost: for each (batch, head) a core
walks the chunks left-to-right, carrying the (N, P) state in scratch — the
HBM traffic is exactly one read of x/dt/B/C and one write of y (+ one final
state write), vs the XLA path's materialized (nc, N, P) inter-chunk states.

Cumulative sums inside the kernel use a lower-triangular ones matmul
(MXU-friendly; avoids relying on mosaic scan lowering).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                state_acc, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_acc[...] = jnp.zeros_like(state_acc)

    x = x_ref[0, :, 0, :].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)             # (Q,)
    A = a_ref[0, 0]                                      # scalar (negative)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)           # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)           # (Q, N)

    a = dt * A                                           # (Q,) log-decays
    # inclusive cumsum via lower-triangular ones matmul (MXU)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril_incl = (ii >= jj).astype(jnp.float32)           # i >= j
    a_cum = jax.lax.dot_general(tril_incl, a[:, None],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)[:, 0]
    a_tot = a_cum[-1]

    # intra-chunk: masked-decay attention-like matmuls
    seg = a_cum[:, None] - a_cum[None, :]                # sum over (j, i]
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    M = scores * L * dt[None, :]
    y_intra = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: contribution of carried state, then state update
    state = state_acc[...]                               # (N, P)
    y_inter = jax.lax.dot_general(Cm, state, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(a_cum)[:, None]
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    wts = dt * jnp.exp(a_tot - a_cum)                    # (Q,)
    upd = jax.lax.dot_general(Bm, x * wts[:, None], (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    state_acc[...] = state * jnp.exp(a_tot) + upd

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_ref[0, 0, :, :] = state_acc[...]


def ssd_fwd(x, dt, A, Bm, Cm, *, chunk: int = 256, interpret: bool = False):
    """x (B,S,nh,P), dt (B,S,nh), A (nh,), Bm/Cm (B,S,G,N)
    -> y (B,S,nh,P), final_state (B,nh,N,P)."""
    B, S, nh, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hg = nh // G
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    a2 = A.reshape(nh, 1).astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=Q, n_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c: (b, c, h // hg, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c: (b, c, h // hg, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((B, nh, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt.astype(jnp.float32), a2, Bm, Cm)
    return y, state
