import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent on 512
placeholder devices (the two lines above MUST precede any jax import).

For every (architecture x input-shape) cell and mesh:

    with mesh:
        lowered = jax.jit(step, in_shardings=...).lower(*abstract_inputs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())    # proves it fits
        print(compiled.cost_analysis())      # FLOPs/bytes for §Roofline

Results (memory/cost/collective stats) land in experiments/dryrun/*.json,
which EXPERIMENTS.md §Dry-run and §Roofline are generated from.

Usage:
    python -m repro.launch.dryrun --arch granite-moe-1b-a400m --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--jobs 1]
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape: str, mesh_kind: str, *, attn_impl: str = "xla",
             microbatches: int = 1, grad_compress: bool = False,
             fsdp=None, remat=None, seq_shard: bool = False,
             tag: str = "", verbose: bool = True) -> dict:
    import jax  # first jax touch happens AFTER the XLA_FLAGS line
    from repro.configs import ARCHS, SHAPES, cell_is_runnable
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import (Roofline, active_param_count,
                                       extract_cost, model_flops)
    from repro.launch.steps import lower_cell
    from repro.models.param import count_params

    cfg = ARCHS[arch]
    if seq_shard:
        cfg = cfg.with_(seq_shard_attn=True)
    shape_cfg = SHAPES[shape]
    if not cell_is_runnable(arch, shape):
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "SKIP(full-attention)"}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    t0 = time.time()
    lowered, model, rules = lower_cell(cfg, shape_cfg, mesh,
                                       attn_impl=attn_impl,
                                       microbatches=microbatches,
                                       grad_compress=grad_compress,
                                       fsdp=fsdp, remat=remat)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    flops_ca, nbytes_ca, peak = extract_cost(compiled)
    if verbose:
        print(mem)
        print({"flops": flops_ca, "bytes accessed": nbytes_ca})
    # exact per-device accounting: scan bodies x trip count (hlo_analysis);
    # cost_analysis (counts loop bodies once) kept for cross-reference
    hlo = analyze(compiled.as_text())
    n_active = active_param_count(cfg, model)
    rl = Roofline(
        arch=arch, shape=shape, mesh=mesh_kind, chips=chips,
        flops_per_device=hlo.flops, bytes_per_device=hlo.hbm_bytes,
        collective_bytes=hlo.collective_bytes,
        collective_breakdown={k: int(v)
                              for k, v in hlo.collective_by_kind.items()},
        peak_memory_per_device=peak,
        model_flops_total=model_flops(cfg, shape_cfg, n_active),
    )
    result = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "status": "OK",
        "chips": chips, "kind": shape_cfg.kind,
        "params_total": count_params(model.param_specs()),
        "params_active": n_active,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        },
        "cost_analysis_raw": {"flops": flops_ca, "bytes": nbytes_ca},
        "collective_counts": {k: int(v)
                              for k, v in hlo.collective_count.items()},
        "roofline": rl.to_json(),
        "knobs": {"attn_impl": attn_impl, "microbatches": microbatches,
                  "grad_compress": grad_compress, "fsdp": fsdp,
                  "remat": remat},
        "tag": tag,
    }
    return result


def cell_filename(arch: str, shape: str, mesh_kind: str, tag: str = "") -> Path:
    suffix = f"__{tag}" if tag else ""
    return OUT_DIR / f"{arch}__{shape}__{mesh_kind}{suffix}.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attn-impl", default="xla")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--remat", default=None)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.configs import ARCHS, SHAPES  # safe: flags already set
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = 0
        for arch in ARCHS:
            for shape in SHAPES:
                for mesh_kind in meshes:
                    out = cell_filename(arch, shape, mesh_kind, args.tag)
                    if args.skip_existing and out.exists():
                        print(f"skip (exists): {out.name}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--mesh", mesh_kind, "--tag", args.tag,
                           "--attn-impl", args.attn_impl]
                    print(f"=== {arch} x {shape} x {mesh_kind}", flush=True)
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=args.timeout)
                    if r.returncode != 0:
                        failures += 1
                        print(f"FAIL rc={r.returncode}\n{r.stdout[-2000:]}"
                              f"\n{r.stderr[-4000:]}")
                    else:
                        print(r.stdout.strip().splitlines()[-1]
                              if r.stdout.strip() else "(no output)")
        print(f"dry-run driver done; failures={failures}")
        return 1 if failures else 0

    fsdp = None if args.fsdp is None else (args.fsdp == "on")
    try:
        result = run_cell(args.arch, args.shape, args.mesh,
                          attn_impl=args.attn_impl,
                          microbatches=args.microbatches,
                          grad_compress=args.grad_compress,
                          fsdp=fsdp, remat=args.remat,
                          seq_shard=args.seq_shard, tag=args.tag)
    except Exception:
        traceback.print_exc()
        result = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                  "status": "ERROR", "error": traceback.format_exc()[-2000:],
                  "tag": args.tag}
    out = cell_filename(args.arch, args.shape, args.mesh, args.tag)
    out.write_text(json.dumps(result, indent=2))
    print(json.dumps({k: result.get(k) for k in
                      ("arch", "shape", "mesh", "status", "compile_s")}))
    return 0 if result.get("status", "ERROR") in ("OK",) or \
        str(result.get("status", "")).startswith("SKIP") else 1


if __name__ == "__main__":
    sys.exit(main())
