"""Exact roofline accounting from compiled HLO text.

``compiled.cost_analysis()`` visits every computation ONCE — while-loop
(lax.scan) bodies are not multiplied by trip count, so an 80-layer scanned
transformer reports ~1 layer of FLOPs.  This analyzer parses the optimized
HLO, resolves the computation call graph (while bodies x trip count, fusions,
calls, conditionals), and accumulates:

* flops             — dot ops (2*M*N*K*batch from contracting dims) + a
                      convolution fallback;
* hbm_bytes         — per top-level op: result bytes + operand bytes
                      (operands resolved to their def-site result shapes;
                      fusion internals don't touch HBM);
* collective_bytes  — result-shape bytes of all-gather / all-reduce /
                      reduce-scatter / all-to-all / collective-permute,
                      multiplied through the loop structure.

Trip counts come from the while condition's comparison constant.  Validated
against cost_analysis() on scan-free programs (test_hlo_analysis.py).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f8e4m3fn|f8e5m2|[subfc]\d+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.-]+)")
_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|condition=|body=|branch_computations=\{)%?([\w.-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.-]+)\s*\(([^)]*)\)\s*->")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# Pure layout/precision movement: the CPU backend materializes bf16<->f32
# converts and relayouts that the TPU backend fuses into consumers.  Charging
# them would bill CPU-lowering artifacts to the TPU roofline, so fusions made
# ONLY of these opcodes (plus their slices) count as free.
_PURE_MOVE = {"convert", "bitcast", "copy", "transpose", "broadcast",
              "reshape", "parameter", "constant", "iota", "dynamic-slice",
              "slice", "get-tuple-element", "tuple"}


def _shape_info(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """-> (total bytes, [(dtype, dims), ...]) for possibly-tuple types."""
    shapes = []
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        dd = [int(x) for x in dims.split(",") if x]
        n = 1
        for x in dd:
            n *= x
        total += n * _DTYPE_BYTES.get(dt, 4)
        shapes.append((dt, dd))
    return total, shapes


@dataclass
class OpInfo:
    name: str
    opcode: str
    result_bytes: int
    result_shapes: List[Tuple[str, List[int]]]
    operands: List[str]
    called: List[str]
    text: str


@dataclass
class Computation:
    name: str
    params: Dict[str, int] = field(default_factory=dict)        # name -> bytes
    param_shapes: Dict[str, List[Tuple[str, List[int]]]] = field(
        default_factory=dict)
    ops: List[OpInfo] = field(default_factory=list)


_OPCODE_RE = re.compile(
    r"^(?:\([^)]*\)|[a-z0-9\[\],{}#*_:./\s-]+?)\s+([a-z][\w-]*)\s*\(")


def _split_top_level(s: str) -> List[str]:
    """Split on commas at paren/bracket/brace depth 0."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _parse_header(stripped: str) -> Optional[Tuple[str, str, bool]]:
    """'%name (sig) -> type {'  ->  (name, sig, is_entry)."""
    if "->" not in stripped or not stripped.endswith("{"):
        return None
    is_entry = stripped.startswith("ENTRY")
    head = stripped[len("ENTRY "):].strip() if is_entry else stripped
    if not head.startswith("%") and not is_entry:
        return None
    lp = head.find("(")
    if lp < 0:
        return None
    name = head[:lp].strip().lstrip("%").strip()
    depth = 0
    rp = -1
    for i in range(lp, len(head)):
        if head[i] == "(":
            depth += 1
        elif head[i] == ")":
            depth -= 1
            if depth == 0:
                rp = i
                break
    if rp < 0 or not name:
        return None
    return name, head[lp + 1: rp], is_entry


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped or stripped.startswith(("HloModule", "//", "#")):
            continue
        if stripped == "}":
            cur = None
            continue
        if line and not line.startswith(" ") and stripped.endswith("{"):
            hdr = _parse_header(stripped)
            if hdr:
                name, sig, is_entry = hdr
                cur = Computation(name)
                comps[name] = cur
                if is_entry:
                    entry = name
                for part in _split_top_level(sig):
                    if ":" not in part:
                        continue
                    pname, ptype = part.split(":", 1)
                    pname = pname.strip().lstrip("%")
                    nbytes, shapes = _shape_info(ptype)
                    cur.params[pname] = nbytes
                    cur.param_shapes[pname] = shapes
                continue
        if cur is None:
            continue
        dm = _DEF_RE.match(stripped)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        # parameters appear as defs too:  %p.1 = f32[..] parameter(0)
        om = _OPCODE_RE.match(rhs)
        opcode = om.group(1) if om else rhs.split("(")[0].split()[-1]
        type_part = rhs.split(opcode + "(")[0] if opcode + "(" in rhs else rhs
        nbytes, shapes = _shape_info(type_part)
        args_part = rhs[rhs.find("("):]
        operands = _OPND_RE.findall(args_part.split("),")[0]) \
            if "(" in rhs else []
        called = _CALLED_RE.findall(rhs)
        cur.ops.append(OpInfo(name, opcode, nbytes, shapes, operands, called,
                              rhs))
    return comps, entry


def _dot_flops(op: OpInfo, shape_of: Dict[str, List[Tuple[str, List[int]]]]
               ) -> float:
    lhs = shape_of.get(op.operands[0]) if op.operands else None
    rhs_ = shape_of.get(op.operands[1]) if len(op.operands) > 1 else None
    if not lhs or not rhs_ or not lhs[0][1] or not rhs_[0][1]:
        # fall back: 2 * result elements (cannot resolve contraction)
        n = 1
        for _, dims in op.result_shapes:
            for d in dims:
                n *= d
        return 2.0 * n
    ldims = lhs[0][1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.text)
    b = re.search(r"lhs_batch_dims=\{([0-9,]*)\}", op.text)
    contract = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    k = 1
    for c in contract:
        if c < len(ldims):
            k *= ldims[c]
    out_n = 1
    for _, dims in op.result_shapes:
        for d in dims:
            out_n *= d
    return 2.0 * out_n * k


def _trip_count(cond: Computation) -> int:
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.text)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _trip_from_carry(op: OpInfo) -> int:
    """lax.scan lowers xs as stacked (L, ...) arrays threaded through the
    while carry; L is therefore the modal leading dim of the carry tuple's
    non-scalar elements.  Used when the loop bound constant was fused out of
    the condition computation."""
    from collections import Counter
    leads = Counter()
    for _dt, dims in op.result_shapes:
        if len(dims) >= 2 and dims[0] > 1:
            leads[dims[0]] += 1
    if not leads:
        return 1
    dim, count = leads.most_common(1)[0]
    return dim if count >= 2 else 1


@dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)


def _fusion_operand_bytes(op: OpInfo, fused: Optional[Computation],
                          bytes_of: Dict[str, int],
                          accum_size: Optional[int] = None) -> int:
    """HBM bytes read by a fusion: operands consumed only through
    dynamic-slice / dynamic-update-slice / gather count as the slice size,
    not the whole buffer (stacked scan params, KV caches)."""
    if fused is None:
        return sum(bytes_of.get(o, 0) for o in op.operands)
    # positional param name -> consumers inside the fused computation
    pidx: Dict[int, str] = {}
    local_bytes: Dict[str, int] = dict(fused.params)
    for fop in fused.ops:
        local_bytes[fop.name] = fop.result_bytes
        if fop.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", fop.text)
            if m:
                pidx[int(m.group(1))] = fop.name
    consumers: Dict[str, List[OpInfo]] = {}
    for fop in fused.ops:
        for o in fop.operands:
            consumers.setdefault(o, []).append(fop)
    total = 0
    for i, oname in enumerate(op.operands):
        full = bytes_of.get(oname, 0)
        if accum_size is not None and full == accum_size:
            continue  # in-place accumulator operand: covered by update charge
        pname = pidx.get(i)
        cons = consumers.get(pname, []) if pname else []
        if cons and all(c.opcode in ("dynamic-slice", "gather") for c in cons):
            total += sum(c.result_bytes for c in cons)
        elif cons and all(c.opcode == "dynamic-update-slice" for c in cons):
            # in-place update: read/write the update region only
            total += sum(local_bytes.get(c.operands[1], 0)
                         if len(c.operands) > 1 else 0 for c in cons)
        else:
            total += full
    return total


def analyze(text: str) -> HloCosts:
    comps, entry = parse_hlo(text)
    memo: Dict[str, HloCosts] = {}

    def visit(cname: str, top_level: bool) -> HloCosts:
        key = f"{cname}:{top_level}"
        if key in memo:
            return memo[key]
        comp = comps.get(cname)
        out = HloCosts()
        if comp is None:
            memo[key] = out
            return out
        shape_of: Dict[str, List[Tuple[str, List[int]]]] = dict(
            comp.param_shapes)
        bytes_of: Dict[str, int] = dict(comp.params)
        for op in comp.ops:
            shape_of[op.name] = op.result_shapes
            bytes_of[op.name] = op.result_bytes
        for op in comp.ops:
            if op.opcode in ("parameter", "constant", "iota",
                             "get-tuple-element", "tuple", "bitcast",
                             "convert", "copy", "transpose", "broadcast",
                             "reshape"):
                continue
            if op.opcode == "dot":
                out.flops += _dot_flops(op, shape_of)
            elif op.opcode == "convolution":
                n = sum(1 for _ in ())
                total = 1
                for _, dims in op.result_shapes:
                    for d in dims:
                        total *= d
                out.flops += 2.0 * total
            if op.opcode in _COLLECTIVES or any(
                    op.opcode == c + "-start" for c in _COLLECTIVES):
                kind = op.opcode.replace("-start", "")
                out.collective_bytes += op.result_bytes
                out.collective_by_kind[kind] = \
                    out.collective_by_kind.get(kind, 0) + op.result_bytes
                out.collective_count[kind] = \
                    out.collective_count.get(kind, 0) + 1
            if op.opcode == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.-]+)", op.text)
                cm = re.search(r"condition=%?([\w.-]+)", op.text)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if trips <= 1:  # bound constant fused away: infer from carry
                    trips = _trip_from_carry(op)
                if body:
                    sub = visit(body, top_level)
                    _accumulate(out, sub, trips)
                continue
            if op.opcode == "fusion":
                called = op.called[:1]
                for c in called:
                    sub = visit(c, False)   # fusion internals: flops only
                    out.flops += sub.flops
                    out.collective_bytes += sub.collective_bytes
                    for k, v in sub.collective_by_kind.items():
                        out.collective_by_kind[k] = \
                            out.collective_by_kind.get(k, 0) + v
                if top_level:
                    fused = comps.get(called[0]) if called else None
                    if fused and fused.ops and all(
                            f.opcode in _PURE_MOVE for f in fused.ops):
                        continue  # convert/relayout artifact: free on TPU
                    result_charge = op.result_bytes
                    dus_update = 0
                    if fused:
                        lb = {f.name: f.result_bytes for f in fused.ops}
                        lb.update(fused.params)
                        dus = [f for f in fused.ops
                               if f.opcode == "dynamic-update-slice"]
                        if dus:  # in-place accumulator/cache write
                            dus_update = sum(
                                lb.get(f.operands[1], 0) for f in dus
                                if len(f.operands) > 1)
                            result_charge = dus_update
                    opnd = _fusion_operand_bytes(op, fused, bytes_of,
                                                 accum_size=op.result_bytes
                                                 if dus_update else None)
                    out.hbm_bytes += result_charge + opnd
                continue
            if op.opcode == "dynamic-slice":
                # reads only the slice, not the sliced buffer
                out.hbm_bytes += 2 * op.result_bytes if top_level else 0
                continue
            if op.opcode == "dynamic-update-slice":
                upd = bytes_of.get(op.operands[1], 0) if len(op.operands) > 1 \
                    else op.result_bytes
                out.hbm_bytes += 2 * upd if top_level else 0  # in-place r/w
                continue
            if op.opcode in ("call", "conditional", "map", "reduce",
                             "reduce-window", "sort", "scatter", "select-and-scatter",
                             "custom-call", "async-start"):
                for c in op.called:
                    sub = visit(c, False)
                    out.flops += sub.flops
                    _accumulate_coll(out, sub, 1)
            if top_level:
                out.hbm_bytes += op.result_bytes + sum(
                    bytes_of.get(o, 0) for o in op.operands)
        memo[key] = out
        return out

    def _accumulate(dst: HloCosts, src: HloCosts, mult: int) -> None:
        dst.flops += src.flops * mult
        dst.hbm_bytes += src.hbm_bytes * mult
        _accumulate_coll(dst, src, mult)

    def _accumulate_coll(dst: HloCosts, src: HloCosts, mult: int) -> None:
        dst.collective_bytes += src.collective_bytes * mult
        for k, v in src.collective_by_kind.items():
            dst.collective_by_kind[k] = dst.collective_by_kind.get(k, 0) \
                + v * mult
        for k, v in src.collective_count.items():
            dst.collective_count[k] = dst.collective_count.get(k, 0) \
                + v * mult

    if entry is None:
        return HloCosts()
    return visit(entry, True)
