"""Production mesh construction.

A FUNCTION (not a module constant) so importing never touches jax device
state — dryrun.py sets XLA_FLAGS before any jax init; tests and benches see
the single real CPU device.

Topology (TPU v5e): one pod = 16x16 = 256 chips, mesh axes (data, model);
multi-pod adds the leading "pod" axis over the DCI: (2, 16, 16) = 512 chips.
"batch"/"fsdp" logical axes map to ("pod", "data") so both the gradient
all-reduce hierarchy (fast ICI within a pod, slow DCI across) and ZeRO
param sharding scale with total chips.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever devices exist locally, as (data, model) — smoke/example scale."""
    n = len(jax.devices())
    assert n % model_axis == 0, (n, model_axis)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


HW = {
    "peak_flops_bf16": 197e12,   # per chip, TPU v5e
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
}
