"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json."""

from __future__ import annotations

import json
import sys
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"
ARCH_ORDER = ["starcoder2-3b", "qwen2-72b", "gemma-2b", "gemma3-27b",
              "musicgen-medium", "phi-3-vision-4.2b", "deepseek-v3-671b",
              "granite-moe-1b-a400m", "mamba2-1.3b", "zamba2-2.7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(mesh: str, tag: str = ""):
    cells = {}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            suffix = f"__{tag}" if tag else ""
            p = OUT_DIR / f"{arch}__{shape}__{mesh}{suffix}.json"
            if p.exists():
                cells[(arch, shape)] = json.loads(p.read_text())
    return cells


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def roofline_table(mesh: str = "single", tag: str = "") -> str:
    cells = load_cells(mesh, tag)
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "useful/HLO | roofline-frac | mem/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            c = cells.get((arch, shape))
            if c is None:
                continue
            if c["status"].startswith("SKIP"):
                lines.append(f"| {arch} | {shape} | — | — | — | "
                             f"SKIP(full-attn) | — | — | — |")
                continue
            r = c["roofline"]
            mem = c["memory_analysis"]
            dev_bytes = (mem["argument_bytes"] + mem["temp_bytes"]
                         + mem["output_bytes"] - mem["alias_bytes"])
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
                f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
                f"{r['roofline_fraction']:.3f} | {fmt_b(dev_bytes)} |")
    return "\n".join(lines)


def dryrun_table(mesh: str) -> str:
    cells = load_cells(mesh)
    lines = [
        "| arch | shape | status | compile | params | HLO flops/dev | "
        "collectives (count) |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            c = cells.get((arch, shape))
            if c is None:
                continue
            if c["status"].startswith("SKIP"):
                lines.append(f"| {arch} | {shape} | SKIP(full-attn) | — | — "
                             f"| — | — |")
                continue
            r = c["roofline"]
            cc = ", ".join(f"{k.replace('collective-','c-')}:{v}"
                           for k, v in sorted(c["collective_counts"].items()))
            lines.append(
                f"| {arch} | {shape} | {c['status']} | {c['compile_s']}s | "
                f"{c['params_total']/1e9:.2f}B | {r['flops_per_device']:.2e} "
                f"| {cc or '—'} |")
    return "\n".join(lines)


def pick_hillclimb(mesh: str = "single"):
    cells = {k: v for k, v in load_cells(mesh).items()
             if v["status"] == "OK"}
    worst = min(cells.items(), key=lambda kv: kv[1]["roofline"]
                ["roofline_fraction"])
    coll = max(cells.items(), key=lambda kv: kv[1]["roofline"]["collective_s"]
               / max(kv[1]["roofline"]["compute_s"], 1e-12))
    return worst[0], coll[0]


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    tag = sys.argv[2] if len(sys.argv) > 2 else ""
    print(roofline_table(mesh, tag))
    print()
    print("hillclimb picks (worst-frac, most-collective):",
          pick_hillclimb(mesh))
