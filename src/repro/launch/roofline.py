"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / (links x link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()`` (the SPMD module is the
per-device program).  Collective bytes are NOT in cost_analysis: we parse
the compiled HLO text and sum result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction
(all-reduce wire bytes ~ 2x result size ring-wise; we report the raw sum
and apply the 2(n-1)/n ring factor in the term).
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

from .mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%x = bf16[1,2,3]{...} all-reduce(...)` or tuple results
_INSTR_RE = re.compile(
    r"=\s*(\(?)([a-z0-9\[\],{}\s/#*_:.-]+?)\)?\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[\s(.]", re.IGNORECASE)
_SHAPE_RE = re.compile(r"(pred|[subf]\d+|bf16|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind = m.group(3).lower()
        if "-start" in line.split(kind)[1][:8]:
            pass  # async start counted; matching -done has no shape cost
        nbytes = sum(_shape_bytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(m.group(2)))
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collective_breakdown: Dict[str, int]
    peak_memory_per_device: float
    model_flops_total: float

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / HW["peak_flops_bf16"]

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HW["hbm_bw"]

    @property
    def collective_s(self) -> float:
        # v5e: 4 ICI links/chip usable concurrently for ring collectives;
        # ring AR moves ~2x payload.  Conservative: 2 links effective.
        eff_bw = 2 * HW["ici_bw"]
        return 2.0 * self.collective_bytes / eff_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        denom = self.flops_per_device * self.chips
        return (self.model_flops_total / denom) if denom else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / achievable step time (higher = closer to
        the compute roofline)."""
        useful_s = (self.model_flops_total / self.chips) / HW["peak_flops_bf16"]
        return useful_s / self.bound_s if self.bound_s else 0.0

    def to_json(self) -> dict:
        d = asdict(self)
        for k in ("compute_s", "memory_s", "collective_s", "dominant",
                  "useful_flops_ratio", "roofline_fraction"):
            d[k] = getattr(self, k)
        return d


def extract_cost(compiled) -> Tuple[float, float, float]:
    """(flops, bytes_accessed, peak_memory) from a compiled executable."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        peak = float(getattr(ma, "temp_size_in_bytes", 0)
                     + getattr(ma, "argument_size_in_bytes", 0)
                     + getattr(ma, "output_size_in_bytes", 0)
                     - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        peak = 0.0
    return flops, nbytes, peak


def model_flops(cfg, shape_cfg, n_params: int) -> float:
    """6·N·D (train) / 2·N·D (forward-only prefill) / 2·N per decoded token."""
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n_params * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_params * tokens
    return 2.0 * n_params * shape_cfg.global_batch   # one token / sequence


def active_param_count(cfg, model) -> int:
    """N for MODEL_FLOPS: MoE counts only activated experts (6·N_active·D)."""
    from repro.models.param import count_params
    total = count_params(model.param_specs())
    if cfg.moe is None:
        return total
    m = cfg.moe
    n_moe_layers = cfg.num_layers - m.first_dense_layers
    per_expert = 3 * cfg.d_model * m.d_expert
    inactive = n_moe_layers * (m.num_experts - m.top_k) * per_expert
    return total - inactive
