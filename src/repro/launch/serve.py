"""Serving driver: batched prefill + decode with a KV/SSM cache.

Requests are batched (continuous batching would slot-swap; here the batch is
fixed-size with left-aligned prompts, the shape the decode_* dry-run cells
lower).  Greedy or temperature sampling; prompts stream from a Deep Lake
view when --from-lake is set (inference is one of the paper's §3.5 access
patterns).

CLI:  python -m repro.launch.serve --arch gemma-2b --smoke --tokens 16
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_arch, reduce_for_smoke
from repro.distributed import make_rules, make_shard_fn
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model


@dataclass
class ServeJob:
    arch: str = "gemma-2b"
    smoke: bool = True
    batch: int = 4
    prompt_len: int = 32
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0
    model_axis: int = 1


class Server:
    def __init__(self, job: ServeJob, params=None) -> None:
        self.job = job
        cfg = get_arch(job.arch)
        if job.smoke:
            cfg = reduce_for_smoke(cfg)
        self.cfg = cfg
        self.mesh = make_local_mesh(model_axis=job.model_axis)
        rules = make_rules("decode")
        self.model = build_model(cfg, shard_fn=make_shard_fn(self.mesh, rules))
        self.params = params if params is not None else \
            self.model.init(jax.random.PRNGKey(job.seed))
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0}

    def generate(self, prompts: np.ndarray, max_new_tokens: Optional[int] = None
                 ) -> np.ndarray:
        """prompts (B, P) int32 -> (B, P + new) generated ids (greedy/sampled)."""
        job = self.job
        new = max_new_tokens or job.max_new_tokens
        B, P = prompts.shape
        total = P + new
        cache = self.model.init_cache(B, total)
        rng = jax.random.PRNGKey(job.seed)
        out = np.zeros((B, total), np.int32)
        out[:, :P] = prompts
        t0 = time.perf_counter()
        with self.mesh:
            # prompt absorption token-by-token through the decode path (the
            # cache layout then matches decode exactly); prefill-step lowering
            # is exercised separately by the dry-run prefill cells.
            logits = None
            for t in range(P):
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(out[:, t]),
                                             jnp.int32(t))
            self.stats["prefill_s"] += time.perf_counter() - t0
            t0 = time.perf_counter()
            for t in range(P, total):
                nxt = self._sample(logits, rng, t)
                out[:, t] = np.asarray(nxt)
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(out[:, t]),
                                             jnp.int32(t))
            self.stats["decode_s"] += time.perf_counter() - t0
            self.stats["tokens"] += B * new
        return out

    def _sample(self, logits, rng, t):
        logits = logits[..., : self.cfg.vocab_size]
        if self.job.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(rng, t)
        return jax.random.categorical(
            key, logits / self.job.temperature, axis=-1).astype(jnp.int32)

    def throughput(self) -> float:
        return self.stats["tokens"] / self.stats["decode_s"] \
            if self.stats["decode_s"] else 0.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    job = ServeJob(arch=args.arch, smoke=args.smoke, batch=args.batch,
                   prompt_len=args.prompt_len, max_new_tokens=args.tokens,
                   temperature=args.temperature)
    server = Server(job)
    rng = np.random.default_rng(0)
    if job.smoke and server.cfg.num_codebooks:
        raise SystemExit("serve CLI demo targets text archs; musicgen decode "
                         "is covered by tests/dry-run")
    prompts = rng.integers(0, server.cfg.vocab_size,
                           (job.batch, job.prompt_len)).astype(np.int32)
    out = server.generate(prompts)
    print(f"generated {out.shape} | decode throughput "
          f"{server.throughput():.1f} tok/s "
          f"(batch {job.batch}, CPU smoke scale)")
    print("sample ids:", out[0, job.prompt_len:job.prompt_len + 12].tolist())


if __name__ == "__main__":
    main()
