"""Step builders: train / prefill / decode, plus abstract input specs.

Everything here is mesh-aware but allocation-free: abstract state builders
return ShapeDtypeStructs so the 671B-parameter configs lower without a byte
of HBM — the multi-pod dry-run contract.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (batch_specs, make_rules, make_shard_fn,
                                        sharding_for_specs, spec_for)
from repro.models.model import Model, build_model
from repro.models.param import ParamSpec, abstract, materialize
from repro.optim import (AdamW, apply_updates, compress_grads,
                         init_error_feedback)


# ----------------------------------------------------------------- builders
def make_train_step(model: Model, optimizer: AdamW, *,
                    grad_compress: bool = False, microbatches: int = 1):
    """state {"params", "opt"[, "error_fb"]} x batch -> (state, metrics)."""

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, metrics
        # gradient accumulation: scan over microbatches (memory knob)
        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])
        mb = jax.tree_util.tree_map(split, batch)
        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, b):
            (_loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, b)
            acc = jax.tree_util.tree_map(
                lambda a, x: a + x.astype(jnp.float32) / microbatches, acc, g)
            return acc, metrics

        grads, metrics_stack = jax.lax.scan(body, zero_g, mb)
        metrics = jax.tree_util.tree_map(lambda m: m.mean(), metrics_stack)
        return grads, metrics

    def train_step(state, batch):
        params = state["params"]
        grads, metrics = compute_grads(params, batch)
        if grad_compress:
            grads, new_fb = compress_grads(grads, state["error_fb"])
        updates, opt_state, opt_metrics = optimizer.update(
            grads, state["opt"], params)
        new_state = {"params": apply_updates(params, updates),
                     "opt": opt_state}
        if grad_compress:
            new_state["error_fb"] = new_fb
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_state, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)
    return decode_step


# --------------------------------------------------------------- state specs
def train_state_specs(model: Model, optimizer: AdamW, *,
                      grad_compress: bool = False) -> Dict[str, Any]:
    psp = model.param_specs()
    out = {"params": psp, "opt": optimizer.state_specs(psp)}
    if grad_compress:
        from repro.models.param import tree_map_specs
        out["error_fb"] = tree_map_specs(
            lambda s: ParamSpec(s.shape, s.axes, init="zeros", dtype="float32"),
            psp)
    return out


def abstract_state(specs):
    return abstract(specs)


def init_state(model: Model, optimizer: AdamW, key, *,
               grad_compress: bool = False) -> Dict[str, Any]:
    params = model.init(key)
    out = {"params": params, "opt": optimizer.init(params)}
    if grad_compress:
        out["error_fb"] = init_error_feedback(params)
    return out


# -------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape_cfg: ShapeConfig, model: Model,
                mesh: Mesh, rules) -> Tuple[Any, Any]:
    """(abstract inputs, shardings) for the step matching shape_cfg.kind.

    train:   {"tokens","targets","loss_mask"[, "image_embeds"]}
    prefill: {"tokens"[, "image_embeds"]}
    decode:  (cache, tokens_last, pos)
    """
    if shape_cfg.kind in ("train", "prefill"):
        specs, shardings = batch_specs(cfg, shape_cfg, mesh, rules)
        return specs, shardings
    # decode: cache at full seq_len + one token per sequence
    B = shape_cfg.global_batch
    cache_sp = model.cache_specs(B, shape_cfg.seq_len)
    cache_abs = abstract(cache_sp)
    cache_sh = sharding_for_specs(cache_sp, mesh, rules)
    tok_shape = (B, cfg.num_codebooks) if cfg.num_codebooks else (B,)
    tok_axes = ("batch", None) if cfg.num_codebooks else ("batch",)
    tokens = jax.ShapeDtypeStruct(tok_shape, np.int32)
    tokens_sh = NamedSharding(mesh, spec_for(tok_shape, tok_axes, mesh, rules))
    pos = jax.ShapeDtypeStruct((), np.int32)
    pos_sh = NamedSharding(mesh, spec_for((), (), mesh, rules))
    return (cache_abs, tokens, pos), (cache_sh, tokens_sh, pos_sh)


# --------------------------------------------------------------- cell lowering
def build_cell(arch_cfg: ModelConfig, shape_cfg: ShapeConfig, mesh: Mesh, *,
               attn_impl: str = "xla", fsdp: Optional[bool] = None,
               microbatches: int = 1, grad_compress: bool = False,
               remat: Optional[str] = None):
    """Everything needed to lower one (arch x shape x mesh) cell."""
    long_ctx = shape_cfg.name == "long_500k"
    # H2b: when the cache sequence is marked shardable, decode shapes put it
    # on the model axis (batch already owns the data axes)
    seq_axis = None
    if arch_cfg.seq_shard_attn and not long_ctx:
        seq_axis = "model" if shape_cfg.kind == "decode" else "data"
    rules = make_rules(shape_cfg.kind, long_context=long_ctx,
                       fsdp=arch_cfg.fsdp_params if fsdp is None else fsdp,
                       seq_shard=seq_axis)
    if remat is not None:
        arch_cfg = arch_cfg.with_(remat=remat)
    model = build_model(arch_cfg, shard_fn=make_shard_fn(mesh, rules),
                        attn_impl=attn_impl)
    if shape_cfg.kind == "train":
        from repro.optim import cosine_schedule
        opt = AdamW(cosine_schedule(3e-4, 100, 10_000),
                    moment_dtype=arch_cfg.adam_moment_dtype)
        step = make_train_step(model, opt, microbatches=microbatches,
                               grad_compress=grad_compress)
        st_specs = train_state_specs(model, opt, grad_compress=grad_compress)
        st_abs = abstract(st_specs)
        st_sh = sharding_for_specs(st_specs, mesh, rules)
        in_abs, in_sh = input_specs(arch_cfg, shape_cfg, model, mesh, rules)
        args = (st_abs, in_abs)
        in_shardings = (st_sh, in_sh)
        fn = step
    elif shape_cfg.kind == "prefill":
        p_abs = abstract(model.param_specs())
        p_sh = sharding_for_specs(model.param_specs(), mesh, rules)
        in_abs, in_sh = input_specs(arch_cfg, shape_cfg, model, mesh, rules)
        args = (p_abs, in_abs)
        in_shardings = (p_sh, in_sh)
        fn = make_prefill_step(model)
    else:  # decode
        p_abs = abstract(model.param_specs())
        p_sh = sharding_for_specs(model.param_specs(), mesh, rules)
        (cache_abs, tokens, pos), (cache_sh, tok_sh, pos_sh) = input_specs(
            arch_cfg, shape_cfg, model, mesh, rules)
        args = (p_abs, cache_abs, tokens, pos)
        in_shardings = (p_sh, cache_sh, tok_sh, pos_sh)
        base_fn = make_decode_step(model)

        def fn(params, cache, toks, pos_):
            logits, new_cache = base_fn(params, cache, toks, pos_)
            # pin output cache to the input shardings so donation aliases
            new_cache = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, new_cache, cache_sh)
            return logits, new_cache
    return model, fn, args, in_shardings, rules


def lower_cell(arch_cfg, shape_cfg, mesh, **kw):
    model, fn, args, in_shardings, rules = build_cell(arch_cfg, shape_cfg,
                                                      mesh, **kw)
    # donate the training state / decode cache so buffers alias in place
    donate = (0,) if shape_cfg.kind == "train" else \
        (1,) if shape_cfg.kind == "decode" else ()
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_shardings, donate_argnums=donate)
        lowered = jitted.lower(*args)
    return lowered, model, rules
