"""Training driver: Deep Lake streaming -> pjit train loop, with
checkpoint/restart, straggler detection, failure injection and elastic
restore.  Runs the production code path at any scale — examples use reduced
configs on the local CPU mesh; the same Trainer drives pod-scale runs.

CLI:
    python -m repro.launch.train --arch gemma-2b --smoke --steps 20
    python -m repro.launch.train --arch starcoder2-3b --smoke --steps 50 \
        --grad-compress --fail-at 12 --checkpoint-every 5
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, get_arch, reduce_for_smoke
from repro.core.dataset import Dataset
from repro.core.storage import MemoryProvider, SimulatedS3Provider, chain
from repro.core.views import DatasetView
from repro.data import DeviceFeeder, TokenBatcher, build_token_dataset
from repro.distributed import (FailureInjector, StragglerDetector, make_rules,
                               make_shard_fn, sharding_for_specs)
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import init_state, make_train_step, train_state_specs
from repro.models.model import build_model
from repro.optim import AdamW, cosine_schedule


@dataclass
class TrainJob:
    arch: str = "gemma-2b"
    smoke: bool = True              # reduced config (CPU scale)
    steps: int = 20
    global_batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    warmup: int = 10
    microbatches: int = 1
    grad_compress: bool = False
    checkpoint_every: int = 10
    keep_checkpoints: int = 3
    remote_data: bool = False       # stream through the SimulatedS3 provider
    shuffle: bool = True
    num_docs: int = 64
    tql_filter: Optional[str] = None
    fail_at: tuple = ()
    seed: int = 0
    model_axis: int = 1
    log_every: int = 5


class Trainer:
    def __init__(self, job: TrainJob, *, data_ds: Optional[Dataset] = None,
                 ckpt: Optional[CheckpointManager] = None) -> None:
        self.job = job
        cfg = get_arch(job.arch)
        if job.smoke:
            cfg = reduce_for_smoke(cfg)
        self.cfg = cfg
        self.mesh = make_local_mesh(model_axis=job.model_axis)
        self.rules = make_rules("train")
        self.model = build_model(cfg, shard_fn=make_shard_fn(self.mesh,
                                                             self.rules))
        self.opt = AdamW(cosine_schedule(job.lr, job.warmup, max(job.steps, 2)),
                         moment_dtype=cfg.adam_moment_dtype)
        self.step_fn = jax.jit(
            make_train_step(self.model, self.opt,
                            microbatches=job.microbatches,
                            grad_compress=job.grad_compress),
            donate_argnums=(0,))
        self.ckpt = ckpt or CheckpointManager(MemoryProvider(),
                                              keep=job.keep_checkpoints)
        self.data_ds = data_ds or self._make_data()
        self.straggler = StragglerDetector(
            on_straggler=lambda s, t, base: print(
                f"[straggler] step {s}: {t*1e3:.0f}ms vs baseline "
                f"{base*1e3:.0f}ms -> rebuilding input pipeline"))
        self.injector = FailureInjector(fail_at_steps=tuple(job.fail_at))
        self.history: List[Dict[str, float]] = []

    # ------------------------------------------------------------------ data
    def _make_data(self) -> Dataset:
        if self.job.remote_data:
            store = chain(MemoryProvider(),
                          SimulatedS3Provider(time_scale=0.02),
                          capacity_bytes=64 << 20)
        else:
            store = MemoryProvider()
        ds = Dataset(store)
        build_token_dataset(ds, num_docs=self.job.num_docs,
                            doc_len=self.job.seq_len * 4,
                            vocab_size=self.cfg.vocab_size, seed=self.job.seed)
        return ds

    def _batches(self) -> Iterator[Dict[str, jax.Array]]:
        view = (self.data_ds.query(self.job.tql_filter)
                if self.job.tql_filter else DatasetView.full(self.data_ds))
        batcher = TokenBatcher(view, batch_size=self.job.global_batch,
                               seq_len=self.job.seq_len,
                               shuffle=self.job.shuffle, seed=self.job.seed,
                               num_codebooks=self.cfg.num_codebooks)
        from repro.distributed.sharding import batch_specs
        from repro.configs.base import ShapeConfig
        sc = ShapeConfig("job", self.job.seq_len, self.job.global_batch, "train")
        _, shardings = batch_specs(self.cfg, sc, self.mesh, self.rules)

        def with_extras():
            rng = np.random.default_rng(self.job.seed)
            for b in batcher:
                if self.cfg.num_image_tokens:
                    b["image_embeds"] = rng.standard_normal(
                        (self.job.global_batch, self.cfg.num_image_tokens,
                         1024)).astype(np.float32)
                yield b

        return iter(DeviceFeeder(with_extras(), shardings))

    # ------------------------------------------------------------------ run
    def run(self, *, restore: bool = True) -> Dict[str, Any]:
        job = self.job
        state_specs = train_state_specs(self.model, self.opt,
                                        grad_compress=job.grad_compress)
        shardings = sharding_for_specs(state_specs, self.mesh, self.rules)
        start_step = 0
        if restore and self.ckpt.latest_step() is not None:
            from repro.models.param import abstract
            state = self.ckpt.restore(abstract(state_specs),
                                      shardings=shardings)
            start_step = self.ckpt.latest_step()
            print(f"[restore] resumed from step {start_step}")
        else:
            state = init_state(self.model, self.opt, jax.random.PRNGKey(job.seed),
                               grad_compress=job.grad_compress)
        batches = self._batches()
        step = start_step
        with self.mesh:
            while step < job.steps:
                try:
                    batch = next(batches)
                except StopIteration:
                    batches = self._batches()  # next epoch
                    continue
                t0 = time.perf_counter()
                self.injector.check(step)
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                if self.straggler.observe(step, dt):
                    batches = self._batches()  # mitigation: rebuild pipeline
                self.history.append({"step": step, "loss": loss, "sec": dt})
                if step % job.log_every == 0:
                    print(f"step {step:5d} loss {loss:8.4f} "
                          f"({dt*1e3:6.0f} ms)")
                step += 1
                if step % job.checkpoint_every == 0 or step == job.steps:
                    self.ckpt.save(state, step)
        self.ckpt.wait()
        return {"state": state, "final_step": step,
                "final_loss": self.history[-1]["loss"] if self.history else None,
                "history": self.history}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--remote-data", action="store_true")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--tql", default=None)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()
    job = TrainJob(arch=args.arch, smoke=args.smoke, steps=args.steps,
                   global_batch=args.global_batch, seq_len=args.seq_len,
                   microbatches=args.microbatches,
                   grad_compress=args.grad_compress,
                   remote_data=args.remote_data,
                   checkpoint_every=args.checkpoint_every,
                   fail_at=tuple(args.fail_at), tql_filter=args.tql,
                   model_axis=args.model_axis)
    from repro.distributed import HostFailure, run_resilient

    ckpt = CheckpointManager(MemoryProvider(), keep=3)
    trainer_box = {}

    def make_runner(_restore_step):
        def run():
            t = Trainer(job, ckpt=ckpt,
                        data_ds=trainer_box.get("data"))
            trainer_box["data"] = t.data_ds
            out = t.run()
            trainer_box["out"] = out
            return out["final_step"]
        return run

    result = run_resilient(make_runner, max_restarts=3,
                           on_restart=lambda n, e: print(f"[restart {n}] {e}"))
    print(f"done: final_step={result['final_step']} "
          f"restarts={result['restarts']} "
          f"final_loss={trainer_box['out']['final_loss']:.4f}")


if __name__ == "__main__":
    main()
