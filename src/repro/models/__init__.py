"""Model zoo substrate: four architecture families behind one Model API."""

from .model import Model, build_model
from .param import (ParamSpec, abstract, count_params, materialize,
                    param_bytes, pspecs, shardings)

__all__ = ["Model", "ParamSpec", "abstract", "build_model", "count_params",
           "materialize", "param_bytes", "pspecs", "shardings"]
