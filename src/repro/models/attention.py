"""Attention: GQA/MQA (RoPE, causal, sliding-window) and DeepSeek-style MLA.

Three execution paths:

* ``xla`` — blockwise online-softmax attention expressed in pure lax ops
  (scan over query blocks, scan over KV blocks with running (m, l, acc)).
  Never materializes the S×S score matrix, so prefill_32k fits.  Causal
  masking is applied per block; blocks entirely above the diagonal are
  still computed then masked (the cost shows up in HLO FLOPs — see
  EXPERIMENTS.md §Perf where the pair-scan variant removes it).
* ``xla_pairs`` — beyond-paper optimized causal path: a scan over only the
  lower-triangular (q-block, kv-block) pairs, halving attention FLOPs.
* ``pallas`` / ``pallas_interpret`` — the flash-attention TPU kernel
  (kernels/flash_attention), used on real TPUs / in tests respectively.

Decode is single-token: direct einsum over the cache (scores (B,H,T) is
small even at T=512k), with cache update via dynamic_update_slice; local
(sliding-window) layers keep a ring-buffer cache of size ``window``.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_rope
from .param import ParamSpec

NEG_INF = -1e30


# ------------------------------------------------------------------ specs
def gqa_specs(cfg, stack: Tuple[int, ...] = ()) -> Dict[str, ParamSpec]:
    ax = (None,) * len(stack)
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec(stack + (d, H, hd), ax + ("fsdp", "model", None),
                        dtype=cfg.dtype, fan_in=d),
        "wk": ParamSpec(stack + (d, Hkv, hd), ax + ("fsdp", "model", None),
                        dtype=cfg.dtype, fan_in=d),
        "wv": ParamSpec(stack + (d, Hkv, hd), ax + ("fsdp", "model", None),
                        dtype=cfg.dtype, fan_in=d),
        "wo": ParamSpec(stack + (H, hd, d), ax + ("model", None, "fsdp"),
                        dtype=cfg.dtype, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec(stack + (H, hd), ax + ("model", None), init="zeros",
                                dtype=cfg.dtype)
        specs["bk"] = ParamSpec(stack + (Hkv, hd), ax + ("model", None), init="zeros",
                                dtype=cfg.dtype)
        specs["bv"] = ParamSpec(stack + (Hkv, hd), ax + ("model", None), init="zeros",
                                dtype=cfg.dtype)
    return specs


def mla_specs(cfg, stack: Tuple[int, ...] = ()) -> Dict[str, ParamSpec]:
    ax = (None,) * len(stack)
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk = m.nope_head_dim
    return {
        "w_dq": ParamSpec(stack + (d, m.q_lora_rank), ax + ("fsdp", None), dtype=cfg.dtype),
        "q_norm": ParamSpec(stack + (m.q_lora_rank,), ax + (None,), init="ones",
                            dtype="float32"),
        "w_uq": ParamSpec(stack + (m.q_lora_rank, H, qk + m.rope_head_dim),
                          ax + (None, "model", None), dtype=cfg.dtype,
                          fan_in=m.q_lora_rank),
        "w_dkv": ParamSpec(stack + (d, m.kv_lora_rank), ax + ("fsdp", None), dtype=cfg.dtype),
        "kv_norm": ParamSpec(stack + (m.kv_lora_rank,), ax + (None,), init="ones",
                             dtype="float32"),
        "w_uk": ParamSpec(stack + (m.kv_lora_rank, H, qk),
                          ax + (None, "model", None), dtype=cfg.dtype,
                          fan_in=m.kv_lora_rank),
        "w_uv": ParamSpec(stack + (m.kv_lora_rank, H, m.v_head_dim),
                          ax + (None, "model", None), dtype=cfg.dtype,
                          fan_in=m.kv_lora_rank),
        "w_kr": ParamSpec(stack + (d, m.rope_head_dim), ax + ("fsdp", None), dtype=cfg.dtype),
        "wo": ParamSpec(stack + (H, m.v_head_dim, d), ax + ("model", None, "fsdp"),
                        dtype=cfg.dtype, fan_in=H * m.v_head_dim),
    }


# ------------------------------------------------------- qkv projections
def gqa_qkv(params, x, positions, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# -------------------------------------------------- blockwise XLA attention
def _block_mask(q_pos, k_pos, window: int):
    """(qc, kc) additive mask for causal (+ optional sliding window)."""
    diff = q_pos[:, None] - k_pos[None, :]
    ok = diff >= 0
    if window:
        ok = jnp.logical_and(ok, diff < window)
    return jnp.where(ok, 0.0, NEG_INF)


def _online_block(acc, m, l, q, k, v, mask, scale):
    """One (q-block × kv-block) online-softmax update. fp32 stats."""
    s = jnp.einsum("bqgnd,bkgd->bgnqk", q, k).astype(jnp.float32) * scale
    s = s + mask[None, None, None, :, :]
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bgnqk,bkgd->bgnqd", p.astype(v.dtype), v).astype(jnp.float32)
    return acc_new, m_new, l_new


def blockwise_attention(q, k, v, *, scale: float, causal: bool = True,
                        window: int = 0, q_block: int = 512,
                        kv_block: int = 512, pairs: bool = False,
                        q_offset=0) -> jax.Array:
    """q (B,S,H,D), k/v (B,T,Hkv,D) -> (B,S,H,D); never materializes SxT.

    ``pairs=True`` scans only lower-triangular block pairs (causal FLOPs
    halved); requires S == T and q_offset == 0.
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    if S % q_block or T % kv_block:
        # pad to block multiples; padded keys sit at positions >= T so the
        # causal mask hides them, padded query rows are sliced off below
        S_pad = -(-S // q_block) * q_block
        T_pad = -(-T // kv_block) * kv_block
        q_p = jnp.pad(q, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
        k_p = jnp.pad(k, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
        v_p = jnp.pad(v, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
        out = blockwise_attention(q_p, k_p, v_p, scale=scale, causal=True,
                                  window=window, q_block=q_block,
                                  kv_block=kv_block, pairs=pairs,
                                  q_offset=q_offset)
        return out[:, :S]
    nq, nk = S // q_block, T // kv_block
    qg = q.reshape(B, nq, q_block, Hkv, G, D)
    kg = k.reshape(B, nk, kv_block, Hkv, D)
    vg = v.reshape(B, nk, kv_block, Hkv, D)
    q_pos_base = jnp.arange(S) + q_offset
    k_pos = jnp.arange(T)

    if pairs and causal and S == T and q_block == kv_block:
        return _pairs_attention(qg, kg, vg, scale, window, q_block, nq, B, Hkv,
                                G, D, S, H)

    def per_qblock(qi, qb):
        q_pos = q_pos_base[qi * q_block:(qi + 1) * q_block] if False else \
            jax.lax.dynamic_slice_in_dim(q_pos_base, qi * q_block, q_block)

        def inner(carry, inputs):
            acc, m, l = carry
            kb, vb, ki = inputs
            kp = jax.lax.dynamic_slice_in_dim(k_pos, ki * kv_block, kv_block)
            mask = _block_mask(q_pos, kp, window) if (causal or window) else \
                jnp.zeros((q_block, kv_block))
            acc, m, l = _online_block(acc, m, l, qb, kb, vb, mask, scale)
            return (acc, m, l), None

        acc0 = jnp.zeros((B, Hkv, G, q_block, D), jnp.float32)
        m0 = jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            inner, (acc0, m0, l0),
            (jnp.moveaxis(kg, 1, 0), jnp.moveaxis(vg, 1, 0), jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # (B, Hkv, G, q_block, D)

    outs = jax.lax.map(lambda args: per_qblock(*args),
                       (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    # (nq, B, Hkv, G, q_block, D) -> (B, S, H, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq, Hkv, G, q_block, D)
    out = jnp.moveaxis(out, (1, 4), (1, 2)).reshape(B, S, Hkv * G, D)
    return out.astype(q.dtype)


def _pairs_attention(qg, kg, vg, scale, window, blk, nb, B, Hkv, G, D, S, H):
    """Beyond-paper causal path: scan lower-triangular block pairs only.

    Pairs are ordered row-major (qi ascending, ki ascending within qi) so the
    online-softmax state for each q block is finalized before the next row
    starts; states for ALL q blocks are carried (they live in the output
    accumulator anyway).
    """
    pairs = np.array([(qi, ki) for qi in range(nb) for ki in range(qi + 1)],
                     dtype=np.int32)
    pos = jnp.arange(S)

    def body(carry, pair):
        acc, m, l = carry
        qi, ki = pair[0], pair[1]
        qb = jax.lax.dynamic_index_in_dim(qg, qi, 1, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kg, ki, 1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vg, ki, 1, keepdims=False)
        qp = jax.lax.dynamic_slice_in_dim(pos, qi * blk, blk)
        kp = jax.lax.dynamic_slice_in_dim(pos, ki * blk, blk)
        mask = _block_mask(qp, kp, window)
        acc_i = jax.lax.dynamic_index_in_dim(acc, qi, 1, keepdims=False)
        m_i = jax.lax.dynamic_index_in_dim(m, qi, 1, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, qi, 1, keepdims=False)
        acc_i, m_i, l_i = _online_block(acc_i, m_i, l_i, qb, kb, vb, mask, scale)
        acc = jax.lax.dynamic_update_index_in_dim(acc, acc_i, qi, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_i, qi, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_i, qi, 1)
        return (acc, m, l), None

    acc0 = jnp.zeros((B, nb, Hkv, G, blk, D), jnp.float32)
    m0 = jnp.full((B, nb, Hkv, G, blk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nb, Hkv, G, blk), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.asarray(pairs))
    out = acc / jnp.maximum(l[..., None], 1e-30)       # (B, nb, Hkv, G, blk, D)
    out = jnp.moveaxis(out, 4, 2).reshape(B, S, Hkv * G, D)
    return out.astype(qg.dtype)


# ------------------------------------------------------------ public paths
def gqa_attend(q, k, v, cfg, *, window: int = 0, impl: str = "xla",
               q_offset=0) -> jax.Array:
    scale = 1.0 / np.sqrt(q.shape[-1])
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(
            q, k, v, causal=True, window=window, scale=scale,
            interpret=(impl == "pallas_interpret"))
    return blockwise_attention(q, k, v, scale=scale, causal=True, window=window,
                               pairs=(impl == "xla_pairs"), q_offset=q_offset)


def gqa_train(params, x, positions, cfg, *, window: int = 0,
              impl: str = "xla") -> jax.Array:
    q, k, v = gqa_qkv(params, x, positions, cfg)
    out = gqa_attend(q, k, v, cfg, window=window, impl=impl)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def gqa_prefill(params, x, positions, cfg, *, window: int = 0,
                impl: str = "xla"):
    """Forward + return the KV cache this segment produces."""
    q, k, v = gqa_qkv(params, x, positions, cfg)
    out = gqa_attend(q, k, v, cfg, window=window, impl=impl)
    if window:
        k, v = k[:, -window:], v[:, -window:]
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), (k, v)


def gqa_decode(params, x, cache_k, cache_v, pos, cfg, *, window: int = 0,
               impl: str = "xla"):
    """One-token decode. x (B,1,d); caches (B,T,Hkv,D); pos () int32.

    Local layers use a ring buffer of size ``window`` (slot = pos % window).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    T = cache_k.shape[1]
    slot = (pos % window) if window else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                                  slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                                  slot, axis=1)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention(
            q[:, 0], cache_k, cache_v, pos=pos, window=window,
            interpret=(impl == "pallas_interpret"))[:, None]
    else:
        B, _, H, D = q.shape
        Hkv = cache_k.shape[2]
        G = H // Hkv
        qg = q.reshape(B, Hkv, G, D)
        s = jnp.einsum("bgnd,btgd->bgnt", qg, cache_k).astype(jnp.float32)
        s = s / np.sqrt(D)
        idx = jnp.arange(T)
        if window:
            valid = jnp.logical_and(idx != slot, idx < jnp.minimum(pos, window))
            valid = jnp.logical_or(valid, idx == slot)
        else:
            valid = idx <= pos
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bgnt,btgd->bgnd", p.astype(cache_v.dtype), cache_v)
        out = out.reshape(B, 1, H, D)
    proj = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])
    return proj, cache_k, cache_v


# ------------------------------------------------------------------- MLA
def _mla_rms(scale, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def mla_project_q(params, x, positions, cfg):
    m = cfg.mla
    cq = _mla_rms(params["q_norm"], x @ params["w_dq"])
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    q_nope = q[..., : m.nope_head_dim]
    q_rope = apply_rope(q[..., m.nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_latents(params, x, positions, cfg):
    m = cfg.mla
    c_kv = _mla_rms(params["kv_norm"], x @ params["w_dkv"])     # (B,S,r)
    k_rope = (x @ params["w_kr"])[:, :, None, :]                # (B,S,1,rd)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_train(params, x, positions, cfg, *, impl: str = "xla") -> jax.Array:
    """Training path: expand K/V from latents, run standard attention."""
    m = cfg.mla
    q_nope, q_rope = mla_project_q(params, x, positions, cfg)
    c_kv, k_rope = mla_latents(params, x, positions, cfg)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
    H = cfg.num_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (H, m.rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    # pad V up to the QK head dim so one attention call serves both
    scale = 1.0 / np.sqrt(m.nope_head_dim + m.rope_head_dim)
    out = blockwise_attention(q, k, v_pad := jnp.pad(
        v, ((0, 0), (0, 0), (0, 0), (0, q.shape[-1] - v.shape[-1]))),
        scale=scale, causal=True, pairs=(impl == "xla_pairs"))
    out = out[..., : m.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def mla_prefill(params, x, positions, cfg, *, impl: str = "xla"):
    out = mla_train(params, x, positions, cfg, impl=impl)
    c_kv, k_rope = mla_latents(params, x, positions, cfg)
    return out, (c_kv, k_rope)


def mla_decode(params, x, cache_ckv, cache_kr, pos, cfg):
    """Absorbed single-token MLA decode: attend in the 512-d latent space.

    Cache holds (c_kv, k_rope) only — the MLA memory win: r + rd floats per
    token instead of 2·H·D.
    """
    m = cfg.mla
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = mla_project_q(params, x, positions, cfg)   # (B,1,H,*)
    c_kv, k_rope = mla_latents(params, x, positions, cfg)       # (B,1,r),(B,1,rd)
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv.astype(cache_ckv.dtype), pos, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, k_rope.astype(cache_kr.dtype), pos, axis=1)
    # absorb W_uk into q:  q_abs (B,H,r)
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], params["w_uk"])
    s = jnp.einsum("bhr,btr->bht", q_abs, cache_ckv).astype(jnp.float32)
    s = s + jnp.einsum("bhk,btk->bht", q_rope[:, 0], cache_kr).astype(jnp.float32)
    s = s / np.sqrt(m.nope_head_dim + m.rope_head_dim)
    T = cache_ckv.shape[1]
    s = jnp.where((jnp.arange(T) <= pos)[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bht,btr->bhr", p.astype(cache_ckv.dtype), cache_ckv)
    out = jnp.einsum("bhr,rhk->bhk", ctx, params["w_uv"])        # (B,H,vd)
    proj = jnp.einsum("bhk,hkd->bd", out.astype(x.dtype), params["wo"])[:, None]
    return proj, cache_ckv, cache_kr
