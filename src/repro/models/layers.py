"""Shared neural layers: norms, rotary embeddings, GLU MLPs, embedding/head.

Pure-functional JAX: every layer is ``fn(params, x, ...)`` with params built
from :class:`ParamSpec` trees.  Activation sharding constraints are applied at
block boundaries by the caller (model.py) — layers stay mesh-agnostic.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .param import ParamSpec


# ----------------------------------------------------------------- norms
def rmsnorm_spec(dim: int) -> ParamSpec:
    return ParamSpec((dim,), (None,), init="ones", dtype="float32")


def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------ rope
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)                 # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- mlp
def mlp_specs(d_model: int, d_ff: int, variant: str, dtype: str,
              stack: Tuple[int, ...] = ()) -> dict:
    ax = (None,) * len(stack)
    gated = variant.endswith("_glu")
    specs = {
        "wi": ParamSpec(stack + (d_model, d_ff), ax + ("fsdp", "model"), dtype=dtype),
        "wo": ParamSpec(stack + (d_ff, d_model), ax + ("model", "fsdp"), dtype=dtype),
    }
    if gated:
        specs["wg"] = ParamSpec(stack + (d_model, d_ff), ax + ("fsdp", "model"),
                                dtype=dtype)
    return specs


def mlp(params: dict, x: jax.Array, variant: str) -> jax.Array:
    h = x @ params["wi"]
    if variant == "silu_glu":
        h = jax.nn.silu(x @ params["wg"]) * h
    elif variant == "gelu_glu":
        h = jax.nn.gelu(x @ params["wg"], approximate=True) * h
    elif variant == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    else:
        raise ValueError(variant)
    return h @ params["wo"]


# ------------------------------------------------------------- embeddings
def embedding_spec(vocab: int, d_model: int, dtype: str) -> ParamSpec:
    return ParamSpec((vocab, d_model), ("vocab", "fsdp"), init="normal",
                     scale=1.0, dtype=dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed_logits(table_or_head: jax.Array, h: jax.Array,
                   transpose: bool) -> jax.Array:
    """h (..., d) x (V, d)ᵀ or (d, V) -> logits (..., V), fp32 for stability."""
    w = table_or_head.astype(jnp.float32)
    hf = h.astype(jnp.float32)
    return hf @ (w.T if transpose else w)


# ------------------------------------------------------------------- loss
def softmax_cross_entropy(logits: jax.Array, targets: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean CE over possibly vocab-sharded logits (GSPMD inserts the
    cross-shard max/sum reductions)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------- remat
def remat_policy(name: str):
    if name == "none":
        return None
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots_no_batch":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    raise ValueError(f"unknown remat policy {name!r}")


def maybe_remat(fn, policy_name: str):
    policy = remat_policy(policy_name)
    if policy is None and policy_name == "none":
        return fn
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)
