"""Model assembly: one `Model` API over four architecture families.

    dense   - starcoder2 / qwen2 / gemma / gemma3 / musicgen / phi3v backbones
    moe     - deepseek-v3 (MLA + 1 shared + 256 routed), granite (GQA + 32e)
    ssm     - mamba2 (attention-free SSD)
    hybrid  - zamba2 (mamba2 backbone + one SHARED GQA block every N layers)

Design notes (compile-scale):
* layers are stacked and iterated with `lax.scan` so the HLO stays one
  block body regardless of depth (80-layer qwen2 compiles like 1 layer);
* heterogeneous patterns (gemma3 5 local : 1 global) scan over *periods*
  with a static inner loop, remainder layers in a small tail scan;
* zamba2's shared attention block is closed over (not scanned), so its
  parameters are physically shared across all invocations;
* activations get logical sharding constraints via ``self.shard`` at block
  boundaries (MaxText-style), which the launcher binds to the mesh.

API:
    m = build_model(cfg)
    specs  = m.param_specs()                  # ParamSpec pytree
    params = m.init(key)                      # real arrays (smoke scale)
    loss, metrics = m.loss_fn(params, batch)  # train forward
    logits, cache = m.prefill(params, batch)
    logits, cache = m.decode_step(params, cache, tokens, pos)
    cache_sp = m.cache_specs(batch, max_len)  # ParamSpec pytree for dry-run
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import (embed, maybe_remat, mlp, mlp_specs, rmsnorm,
                     softmax_cross_entropy)
from .param import ParamSpec, abstract, materialize


def _ln(d: int, stack: Tuple[int, ...] = ()) -> ParamSpec:
    return ParamSpec(stack + (d,), (None,) * len(stack) + (None,), init="ones",
                     dtype="float32")


Identity = lambda x, axes=None: x


class Model:
    def __init__(self, cfg: ModelConfig, shard_fn: Callable = Identity,
                 attn_impl: str = "xla") -> None:
        self.cfg = cfg
        self.shard = shard_fn
        self.attn_impl = attn_impl

    # ------------------------------------------------------------ param specs
    def _attn_specs(self, stack):
        if self.cfg.attention == "mla":
            return attn.mla_specs(self.cfg, stack)
        return attn.gqa_specs(self.cfg, stack)

    def _dense_block_specs(self, stack):
        cfg = self.cfg
        return {
            "ln1": _ln(cfg.d_model, stack),
            "attn": self._attn_specs(stack),
            "ln2": _ln(cfg.d_model, stack),
            "mlp": mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp, cfg.dtype, stack),
        }

    def _moe_block_specs(self, stack):
        cfg = self.cfg
        return {
            "ln1": _ln(cfg.d_model, stack),
            "attn": self._attn_specs(stack),
            "ln2": _ln(cfg.d_model, stack),
            "moe": moe_lib.moe_specs(cfg, stack),
        }

    def _ssm_block_specs(self, stack):
        return {"ln": _ln(self.cfg.d_model, stack),
                "ssm": ssm_lib.ssm_specs(self.cfg, stack)}

    def _shared_attn_specs(self):
        """zamba2 shared block: GQA + (optional) MLP, UNSTACKED."""
        cfg = self.cfg
        h = cfg.hybrid
        sub = cfg.with_(num_heads=h.shared_attn_heads,
                        num_kv_heads=h.shared_attn_kv_heads,
                        head_dim=cfg.d_model // h.shared_attn_heads)
        specs = {"ln1": _ln(cfg.d_model), "attn": attn.gqa_specs(sub)}
        if h.shared_attn_d_ff:
            specs["ln2"] = _ln(cfg.d_model)
            specs["mlp"] = mlp_specs(cfg.d_model, h.shared_attn_d_ff, cfg.mlp,
                                     cfg.dtype)
        return specs

    def param_specs(self):
        cfg = self.cfg
        specs: Dict[str, Any] = {}
        # ---- embeddings / modality frontends
        V = cfg.padded_vocab   # padded so the vocab axis always TP-shards
        if cfg.num_codebooks:          # musicgen: K codebook embeddings + heads
            specs["embed"] = ParamSpec((cfg.num_codebooks, V, cfg.d_model),
                                       (None, "vocab", "fsdp"),
                                       dtype=cfg.dtype, fan_in=cfg.d_model)
            specs["head"] = ParamSpec((cfg.d_model, cfg.num_codebooks, V),
                                      ("fsdp", None, "vocab"),
                                      dtype=cfg.dtype, fan_in=cfg.d_model)
        else:
            specs["embed"] = ParamSpec((V, cfg.d_model),
                                       ("vocab", "fsdp"), dtype=cfg.dtype,
                                       fan_in=cfg.d_model)
            if not cfg.tie_embeddings:
                specs["head"] = ParamSpec((cfg.d_model, V),
                                          ("fsdp", "vocab"), dtype=cfg.dtype)
        if cfg.num_image_tokens:       # phi3v: projector from frontend stub
            specs["img_proj"] = ParamSpec((1024, cfg.d_model), (None, "fsdp"),
                                          dtype=cfg.dtype)
        specs["final_ln"] = _ln(cfg.d_model)
        # ---- blocks per family
        if cfg.family in ("dense", "audio", "vlm"):
            if cfg.local_global_pattern:
                P = len(cfg.local_global_pattern)
                n_per, n_tail = divmod(cfg.num_layers, P)
                specs["periods"] = self._dense_block_specs((n_per, P))
                if n_tail:
                    specs["tail"] = self._dense_block_specs((n_tail,))
            else:
                specs["blocks"] = self._dense_block_specs((cfg.num_layers,))
        elif cfg.family == "moe":
            nd = cfg.moe.first_dense_layers
            if nd:
                specs["dense_blocks"] = self._dense_block_specs((nd,))
            specs["moe_blocks"] = self._moe_block_specs((cfg.num_layers - nd,))
            if cfg.mtp_depth:
                specs["mtp"] = {
                    "proj": ParamSpec((2 * cfg.d_model, cfg.d_model),
                                      ("fsdp", None), dtype=cfg.dtype),
                    "block": self._dense_block_specs(()),
                    "ln": _ln(cfg.d_model),
                }
        elif cfg.family == "ssm":
            specs["blocks"] = self._ssm_block_specs((cfg.num_layers,))
        elif cfg.family == "hybrid":
            P = cfg.hybrid.shared_attn_period
            n_per = cfg.num_layers // P
            specs["shared_attn"] = self._shared_attn_specs()
            specs["mamba"] = self._ssm_block_specs((n_per, P))
        else:
            raise ValueError(cfg.family)
        return specs

    def init(self, key: jax.Array, dtype_override: Optional[str] = None):
        return materialize(self.param_specs(), key, dtype_override)

    def abstract_params(self):
        return abstract(self.param_specs())

    # ------------------------------------------------------------- block fwd
    def _dense_block(self, p, h, positions, kind: str, aux):
        cfg = self.cfg
        window = cfg.sliding_window if kind == "L" else 0
        hn = rmsnorm(p["ln1"], h, cfg.norm_eps)
        if cfg.attention == "mla":
            a = attn.mla_train(p["attn"], hn, positions, cfg, impl=self.attn_impl)
        else:
            a = attn.gqa_train(p["attn"], hn, positions, cfg, window=window,
                               impl=self.attn_impl)
        h = h + a
        h = self.shard(h, ("batch", None, None))
        hn = rmsnorm(p["ln2"], h, cfg.norm_eps)
        if "moe" in p:
            out, aux_i = moe_lib.moe_apply(p["moe"], hn, cfg, shard=self.shard)
            aux = aux + aux_i
        else:
            out = mlp(p["mlp"], hn, cfg.mlp)
        h = h + out
        return self.shard(h, ("batch", None, None)), aux

    def _ssm_block(self, p, h):
        hn = rmsnorm(p["ln"], h, self.cfg.norm_eps)
        out = ssm_lib.mamba2_forward(p["ssm"], hn, self.cfg, impl=self.attn_impl
                                     if self.attn_impl.startswith("pallas")
                                     else "xla")
        return self.shard(h + out, ("batch", None, None))

    def _shared_attn_block(self, p, h, positions):
        cfg = self.cfg
        hb = cfg.hybrid
        sub = cfg.with_(num_heads=hb.shared_attn_heads,
                        num_kv_heads=hb.shared_attn_kv_heads,
                        head_dim=cfg.d_model // hb.shared_attn_heads)
        hn = rmsnorm(p["ln1"], h, cfg.norm_eps)
        h = h + attn.gqa_train(p["attn"], hn, positions, sub, impl=self.attn_impl)
        if "mlp" in p:
            hn = rmsnorm(p["ln2"], h, cfg.norm_eps)
            h = h + mlp(p["mlp"], hn, cfg.mlp)
        return self.shard(h, ("batch", None, None))

    # --------------------------------------------------------------- embed
    def _embed_tokens(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.num_codebooks:                           # (B, K, S)
            h = None
            for k in range(cfg.num_codebooks):
                e = embed(params["embed"][k], tokens[:, k])
                h = e if h is None else h + e
        else:
            h = embed(params["embed"], tokens)          # (B, S, d)
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)  # gemma-style scale
        if cfg.num_image_tokens and "image_embeds" in batch:
            img = batch["image_embeds"].astype(h.dtype) @ params["img_proj"]
            h = jnp.concatenate([img, h[:, cfg.num_image_tokens:]], axis=1)
        return self.shard(h, ("batch", None, None))

    def _logits(self, params, h):
        cfg = self.cfg
        hf = h.astype(jnp.float32)
        if cfg.num_codebooks:
            logits = jnp.einsum("bsd,dkv->bskv", hf,
                                params["head"].astype(jnp.float32))
        elif cfg.tie_embeddings:
            logits = hf @ params["embed"].astype(jnp.float32).T
        else:
            logits = hf @ params["head"].astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:  # mask pad slots out of softmax
            valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
            logits = jnp.where(valid, logits, -1e30)
        return logits

    # -------------------------------------------------------------- backbone
    def backbone(self, params, h, positions):
        """Token embeddings -> final hidden states. Returns (h, aux_loss)."""
        cfg = self.cfg
        aux0 = jnp.zeros((), jnp.float32)

        if cfg.family in ("dense", "audio", "vlm"):
            if cfg.local_global_pattern:
                pat = cfg.local_global_pattern
                Pn = len(pat)

                def period_body(carry, p):
                    hh, aux = carry
                    for i, kind in enumerate(pat):
                        pi = jax.tree_util.tree_map(lambda x: x[i], p)
                        hh, aux = self._dense_block(pi, hh, positions, kind, aux)
                    return (hh, aux), None

                body = maybe_remat(period_body, cfg.remat)
                (h, aux), _ = jax.lax.scan(body, (h, aux0), params["periods"])
                if "tail" in params:
                    n_tail = cfg.num_layers % Pn

                    def tail_body(carry, p):
                        hh, aux = carry
                        hh, aux = self._dense_block(p, hh, positions,
                                                    pat[0], aux)
                        return (hh, aux), None

                    (h, aux), _ = jax.lax.scan(maybe_remat(tail_body, cfg.remat),
                                               (h, aux), params["tail"])
                return h, aux
            kind = "L" if cfg.sliding_window else "G"

            def body(carry, p):
                hh, aux = carry
                hh, aux = self._dense_block(p, hh, positions, kind, aux)
                return (hh, aux), None

            (h, aux), _ = jax.lax.scan(maybe_remat(body, cfg.remat), (h, aux0),
                                       params["blocks"])
            return h, aux

        if cfg.family == "moe":
            aux = aux0
            if "dense_blocks" in params:
                def dbody(carry, p):
                    hh, aux = carry
                    hh, aux = self._dense_block(p, hh, positions, "G", aux)
                    return (hh, aux), None
                (h, aux), _ = jax.lax.scan(maybe_remat(dbody, cfg.remat),
                                           (h, aux), params["dense_blocks"])

            def mbody(carry, p):
                hh, aux = carry
                hh, aux = self._dense_block(p, hh, positions, "G", aux)
                return (hh, aux), None

            (h, aux), _ = jax.lax.scan(maybe_remat(mbody, cfg.remat), (h, aux),
                                       params["moe_blocks"])
            return h, aux

        if cfg.family == "ssm":
            def body(hh, p):
                return self._ssm_block(p, hh), None
            (h), _ = jax.lax.scan(maybe_remat(body, cfg.remat), h,
                                  params["blocks"])
            return h, aux0

        if cfg.family == "hybrid":
            shared = params["shared_attn"]
            P = cfg.hybrid.shared_attn_period

            def period(hh, p):
                hh = self._shared_attn_block(shared, hh, positions)
                for i in range(P):
                    pi = jax.tree_util.tree_map(lambda x: x[i], p)
                    hh = self._ssm_block(pi, hh)
                return hh, None

            h, _ = jax.lax.scan(maybe_remat(period, cfg.remat), h,
                                params["mamba"])
            return h, aux0
        raise ValueError(cfg.family)

    # ------------------------------------------------------------------ train
    def loss_fn(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        S = tokens.shape[-1]
        B = tokens.shape[0]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h = self._embed_tokens(params, batch)
        h, aux = self.backbone(params, h, positions)
        h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
        logits = self._logits(params, h)
        logits = self.shard(logits, ("batch", None, "vocab") if logits.ndim == 3
                            else ("batch", None, None, "vocab"))
        targets = batch["targets"]
        mask = batch.get("loss_mask")
        if cfg.num_codebooks:       # (B,S,K,V) vs targets (B,K,S)
            t = jnp.moveaxis(targets, 1, 2)
            m = mask[..., None] if mask is not None else None
            ce = softmax_cross_entropy(logits, t, jnp.broadcast_to(
                m, t.shape) if m is not None else None)
        else:
            ce = softmax_cross_entropy(logits, targets, mask)
        loss = ce
        metrics = {"ce": ce}
        if cfg.moe is not None:
            loss = loss + 0.01 * aux
            metrics["aux"] = aux
        if cfg.mtp_depth and "mtp" in params:
            mtp_loss = self._mtp_loss(params, h, batch)
            loss = loss + 0.3 * mtp_loss
            metrics["mtp"] = mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, h, batch):
        """DeepSeek-V3 multi-token prediction (depth 1, simplified): at
        position i combine h_i with emb(t_{i+1}) to predict t_{i+2}."""
        cfg = self.cfg
        p = params["mtp"]
        tokens, targets = batch["tokens"], batch["targets"]
        e_next = embed(params["embed"], tokens[:, 1:])
        h_in = jnp.concatenate([
            rmsnorm(p["ln"], h[:, :-1], cfg.norm_eps), e_next], axis=-1)
        h_in = (h_in @ p["proj"]).astype(h.dtype)
        B, S1 = tokens.shape[0], tokens.shape[1] - 1
        positions = jnp.broadcast_to(jnp.arange(S1, dtype=jnp.int32), (B, S1))
        hm, _ = self._dense_block(p["block"], h_in, positions, "G",
                                  jnp.zeros((), jnp.float32))
        logits = self._logits(params, rmsnorm(params["final_ln"], hm,
                                              cfg.norm_eps))
        t = targets[:, 1:]
        mask = batch.get("loss_mask")
        m = mask[:, 1:] if mask is not None else None
        return softmax_cross_entropy(logits, t, m)

    # ---------------------------------------------------------------- caches
    def cache_specs(self, batch: int, max_len: int):
        """ParamSpec pytree describing the decode cache (dry-run friendly)."""
        cfg = self.cfg
        dt = cfg.dtype
        seq_ax = "seq" if cfg.seq_shard_attn else None
        bx = "batch"

        def kv(n_layers_stack, T):
            shape = tuple(n_layers_stack) + (batch, T, cfg.num_kv_heads,
                                             cfg.head_dim)
            axes = (None,) * len(n_layers_stack) + (bx, seq_ax, "heads", None)
            return {"k": ParamSpec(shape, axes, init="zeros", dtype=dt),
                    "v": ParamSpec(shape, axes, init="zeros", dtype=dt)}

        if cfg.family in ("dense", "audio", "vlm"):
            W = min(cfg.sliding_window or max_len, max_len)
            if cfg.local_global_pattern:
                pat = cfg.local_global_pattern
                Pn = len(pat)
                n_per, n_tail = divmod(cfg.num_layers, Pn)
                nL = sum(1 for k in pat if k == "L")
                nG = Pn - nL
                out = {"periods_local": kv((n_per, nL), W),
                       "periods_global": kv((n_per, nG), max_len)}
                if n_tail:
                    out["tail"] = kv((n_tail,), W if pat[0] == "L" else max_len)
                return out
            T = W if cfg.sliding_window else max_len
            return {"layers": kv((cfg.num_layers,), T)}
        if cfg.family == "moe":
            m = cfg.mla
            nd = cfg.moe.first_dense_layers
            L = cfg.num_layers
            if cfg.attention == "mla":
                def mla_cache(n):
                    return {
                        "ckv": ParamSpec((n, batch, max_len, m.kv_lora_rank),
                                         (None, bx, seq_ax, None), init="zeros",
                                         dtype=dt),
                        "kr": ParamSpec((n, batch, max_len, m.rope_head_dim),
                                        (None, bx, seq_ax, None), init="zeros",
                                        dtype=dt),
                    }
                out = {"moe_layers": mla_cache(L - nd)}
                if nd:
                    out["dense_layers"] = mla_cache(nd)
                return out
            out = {"moe_layers": kv((L - nd,), max_len)}
            if nd:
                out["dense_layers"] = kv((nd,), max_len)
            return out
        if cfg.family == "ssm":
            s = cfg.ssm
            conv_dim = cfg.expand_dim + 2 * s.n_groups * s.d_state
            return {
                "state": ParamSpec((cfg.num_layers, batch, cfg.ssm_heads,
                                    s.d_state, s.head_dim),
                                   (None, bx, "heads", None, None),
                                   init="zeros", dtype="float32"),
                "conv": ParamSpec((cfg.num_layers, batch, s.conv_kernel - 1,
                                   conv_dim),
                                  (None, bx, None, "model"), init="zeros",
                                  dtype=dt),
            }
        if cfg.family == "hybrid":
            s = cfg.ssm
            hb = cfg.hybrid
            P = hb.shared_attn_period
            n_per = cfg.num_layers // P
            conv_dim = cfg.expand_dim + 2 * s.n_groups * s.d_state
            hd = cfg.d_model // hb.shared_attn_heads
            return {
                "attn_k": ParamSpec((n_per, batch, max_len,
                                     hb.shared_attn_kv_heads, hd),
                                    (None, bx, seq_ax, "heads", None),
                                    init="zeros", dtype=dt),
                "attn_v": ParamSpec((n_per, batch, max_len,
                                     hb.shared_attn_kv_heads, hd),
                                    (None, bx, seq_ax, "heads", None),
                                    init="zeros", dtype=dt),
                "state": ParamSpec((n_per, P, batch, cfg.ssm_heads, s.d_state,
                                    s.head_dim),
                                   (None, None, bx, "heads", None, None),
                                   init="zeros", dtype="float32"),
                "conv": ParamSpec((n_per, P, batch, s.conv_kernel - 1, conv_dim),
                                  (None, None, bx, None, "model"),
                                  init="zeros", dtype=dt),
            }
        raise ValueError(cfg.family)

    def init_cache(self, batch: int, max_len: int):
        return materialize(self.cache_specs(batch, max_len),
                           jax.random.PRNGKey(0))

    # ------------------------------------------------------------------ decode
    def decode_step(self, params, cache, tokens, pos):
        """One token for the whole batch. tokens (B,) or (B,K); pos () int32.
        Returns (logits, new_cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        if cfg.num_codebooks:
            h = None
            for k in range(cfg.num_codebooks):
                e = embed(params["embed"][k], tokens[:, k][:, None])
                h = e if h is None else h + e
        else:
            h = embed(params["embed"], tokens[:, None])     # (B,1,d)
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)

        def dense_step(p, hh, ck, cv, kind):
            window = cfg.sliding_window if kind == "L" else 0
            hn = rmsnorm(p["ln1"], hh, cfg.norm_eps)
            a, ck, cv = attn.gqa_decode(p["attn"], hn, ck, cv, pos, cfg,
                                        window=window, impl=self.attn_impl)
            hh = hh + a
            hn = rmsnorm(p["ln2"], hh, cfg.norm_eps)
            if "moe" in p:
                out, _ = moe_lib.moe_apply(p["moe"], hn, cfg, shard=self.shard)
            else:
                out = mlp(p["mlp"], hn, cfg.mlp)
            return hh + out, ck, cv

        def mla_step(p, hh, ckv, kr):
            hn = rmsnorm(p["ln1"], hh, cfg.norm_eps)
            a, ckv, kr = attn.mla_decode(p["attn"], hn, ckv, kr, pos, cfg)
            hh = hh + a
            hn = rmsnorm(p["ln2"], hh, cfg.norm_eps)
            if "moe" in p:
                out, _ = moe_lib.moe_apply(p["moe"], hn, cfg, shard=self.shard)
            else:
                out = mlp(p["mlp"], hn, cfg.mlp)
            return hh + out, ckv, kr

        if cfg.family in ("dense", "audio", "vlm"):
            if cfg.local_global_pattern:
                h, cache = self._decode_pattern(params, cache, h, pos, dense_step)
            else:
                kind = "L" if cfg.sliding_window else "G"

                def body(hh, xs):
                    p, ck, cv = xs
                    hh, ck, cv = dense_step(p, hh, ck, cv, kind)
                    return hh, (ck, cv)

                h, (ck, cv) = jax.lax.scan(
                    body, h, (params["blocks"], cache["layers"]["k"],
                              cache["layers"]["v"]))
                cache = {"layers": {"k": ck, "v": cv}}
        elif cfg.family == "moe":
            new_cache = {}
            if "dense_blocks" in params:
                if cfg.attention == "mla":
                    def dbody(hh, xs):
                        p, ckv, kr = xs
                        hh, ckv, kr = mla_step(p, hh, ckv, kr)
                        return hh, (ckv, kr)
                    h, (ckv, kr) = jax.lax.scan(
                        dbody, h, (params["dense_blocks"],
                                   cache["dense_layers"]["ckv"],
                                   cache["dense_layers"]["kr"]))
                    new_cache["dense_layers"] = {"ckv": ckv, "kr": kr}
                else:
                    def dbody(hh, xs):
                        p, ck, cv = xs
                        hh, ck, cv = dense_step(p, hh, ck, cv, "G")
                        return hh, (ck, cv)
                    h, (ck, cv) = jax.lax.scan(
                        dbody, h, (params["dense_blocks"],
                                   cache["dense_layers"]["k"],
                                   cache["dense_layers"]["v"]))
                    new_cache["dense_layers"] = {"k": ck, "v": cv}
            if cfg.attention == "mla":
                def mbody(hh, xs):
                    p, ckv, kr = xs
                    hh, ckv, kr = mla_step(p, hh, ckv, kr)
                    return hh, (ckv, kr)
                h, (ckv, kr) = jax.lax.scan(
                    mbody, h, (params["moe_blocks"],
                               cache["moe_layers"]["ckv"],
                               cache["moe_layers"]["kr"]))
                new_cache["moe_layers"] = {"ckv": ckv, "kr": kr}
            else:
                def mbody(hh, xs):
                    p, ck, cv = xs
                    hh, ck, cv = dense_step(p, hh, ck, cv, "G")
                    return hh, (ck, cv)
                h, (ck, cv) = jax.lax.scan(
                    mbody, h, (params["moe_blocks"], cache["moe_layers"]["k"],
                               cache["moe_layers"]["v"]))
                new_cache["moe_layers"] = {"k": ck, "v": cv}
            cache = new_cache
        elif cfg.family == "ssm":
            def body(hh, xs):
                p, st, cs = xs
                hn = rmsnorm(p["ln"], hh, cfg.norm_eps)
                out, st, cs = ssm_lib.mamba2_decode_step(p["ssm"], hn, st, cs,
                                                         cfg)
                return hh + out, (st, cs)

            h, (st, cs) = jax.lax.scan(body, h, (params["blocks"],
                                                 cache["state"], cache["conv"]))
            cache = {"state": st, "conv": cs}
        elif cfg.family == "hybrid":
            h, cache = self._decode_hybrid(params, cache, h, pos)
        h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
        logits = self._logits(params, h)[:, 0]
        return logits, cache

    def _decode_pattern(self, params, cache, h, pos, dense_step):
        cfg = self.cfg
        pat = cfg.local_global_pattern

        def period_body(hh, xs):
            p, lk, lv, gk, gv = xs
            li = gi = 0
            lk_n, lv_n, gk_n, gv_n = lk, lv, gk, gv
            for i, kind in enumerate(pat):
                pi = jax.tree_util.tree_map(lambda x: x[i], p)
                if kind == "L":
                    hh, ck, cv = dense_step(pi, hh, lk_n[li], lv_n[li], "L")
                    lk_n = lk_n.at[li].set(ck)
                    lv_n = lv_n.at[li].set(cv)
                    li += 1
                else:
                    hh, ck, cv = dense_step(pi, hh, gk_n[gi], gv_n[gi], "G")
                    gk_n = gk_n.at[gi].set(ck)
                    gv_n = gv_n.at[gi].set(cv)
                    gi += 1
            return hh, (lk_n, lv_n, gk_n, gv_n)

        h, (lk, lv, gk, gv) = jax.lax.scan(
            period_body, h,
            (params["periods"], cache["periods_local"]["k"],
             cache["periods_local"]["v"], cache["periods_global"]["k"],
             cache["periods_global"]["v"]))
        new_cache = {"periods_local": {"k": lk, "v": lv},
                     "periods_global": {"k": gk, "v": gv}}
        if "tail" in params:
            def tail_body(hh, xs):
                p, ck, cv = xs
                hh, ck, cv = dense_step(p, hh, ck, cv, pat[0])
                return hh, (ck, cv)
            h, (tk, tv) = jax.lax.scan(
                tail_body, h, (params["tail"], cache["tail"]["k"],
                               cache["tail"]["v"]))
            new_cache["tail"] = {"k": tk, "v": tv}
        return h, new_cache

    def _decode_hybrid(self, params, cache, h, pos):
        cfg = self.cfg
        hb = cfg.hybrid
        P = hb.shared_attn_period
        shared = params["shared_attn"]
        sub = cfg.with_(num_heads=hb.shared_attn_heads,
                        num_kv_heads=hb.shared_attn_kv_heads,
                        head_dim=cfg.d_model // hb.shared_attn_heads)

        def period_body(hh, xs):
            p, ak, av, st, cs = xs
            hn = rmsnorm(shared["ln1"], hh, cfg.norm_eps)
            a, ak, av = attn.gqa_decode(shared["attn"], hn, ak, av, pos, sub,
                                        impl=self.attn_impl)
            hh = hh + a
            if "mlp" in shared:
                hn = rmsnorm(shared["ln2"], hh, cfg.norm_eps)
                hh = hh + mlp(shared["mlp"], hn, cfg.mlp)
            st_n, cs_n = st, cs
            for i in range(P):
                pi = jax.tree_util.tree_map(lambda x: x[i], p)
                hn = rmsnorm(pi["ln"], hh, cfg.norm_eps)
                out, sti, csi = ssm_lib.mamba2_decode_step(
                    pi["ssm"], hn, st_n[i], cs_n[i], cfg)
                st_n = st_n.at[i].set(sti)
                cs_n = cs_n.at[i].set(csi)
                hh = hh + out
            return hh, (ak, av, st_n, cs_n)

        h, (ak, av, st, cs) = jax.lax.scan(
            period_body, h, (params["mamba"], cache["attn_k"],
                             cache["attn_v"], cache["state"], cache["conv"]))
        return h, {"attn_k": ak, "attn_v": av, "state": st, "conv": cs}

    # ----------------------------------------------------------------- prefill
    def prefill(self, params, batch):
        """Forward over a prompt, returning (last-token logits, cache of len S).

        Uses the training backbone for hidden states (identical math) and a
        second pass of cheap projections for the cache; decode then continues
        from position S.  (Lowered for the prefill_* dry-run cells.)
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape[0], tokens.shape[-1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h = self._embed_tokens(params, batch)
        h, caches = self._backbone_with_cache(params, h, positions)
        h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
        logits = self._logits(params, h[:, -1:])[:, 0]
        return logits, caches

    def _backbone_with_cache(self, params, h, positions):
        cfg = self.cfg

        def dense_prefill(p, hh, kind):
            window = cfg.sliding_window if kind == "L" else 0
            hn = rmsnorm(p["ln1"], hh, cfg.norm_eps)
            if cfg.attention == "mla":
                a, kvc = attn.mla_prefill(p["attn"], hn, positions, cfg,
                                          impl=self.attn_impl)
            else:
                a, kvc = attn.gqa_prefill(p["attn"], hn, positions, cfg,
                                          window=window, impl=self.attn_impl)
            hh = hh + a
            hn = rmsnorm(p["ln2"], hh, cfg.norm_eps)
            if "moe" in p:
                out, _ = moe_lib.moe_apply(p["moe"], hn, cfg, shard=self.shard)
            else:
                out = mlp(p["mlp"], hn, cfg.mlp)
            return hh + out, kvc

        if cfg.family in ("dense", "audio", "vlm") and not cfg.local_global_pattern:
            kind = "L" if cfg.sliding_window else "G"

            def body(hh, p):
                hh, (k, v) = dense_prefill(p, hh, kind)
                return hh, (k, v)

            h, (k, v) = jax.lax.scan(body, h, params["blocks"])
            return h, {"layers": {"k": k, "v": v}}
        if cfg.family in ("dense", "audio", "vlm"):
            pat = cfg.local_global_pattern

            def pbody(hh, p):
                lks, lvs, gks, gvs = [], [], [], []
                for i, kind in enumerate(pat):
                    pi = jax.tree_util.tree_map(lambda x: x[i], p)
                    hh, (k, v) = dense_prefill(pi, hh, kind)
                    (lks if kind == "L" else gks).append(k)
                    (lvs if kind == "L" else gvs).append(v)
                return hh, (jnp.stack(lks), jnp.stack(lvs),
                            jnp.stack(gks), jnp.stack(gvs))

            h, (lk, lv, gk, gv) = jax.lax.scan(pbody, h, params["periods"])
            out = {"periods_local": {"k": lk, "v": lv},
                   "periods_global": {"k": gk, "v": gv}}
            if "tail" in params:
                def tbody(hh, p):
                    hh, (k, v) = dense_prefill(p, hh, pat[0])
                    return hh, (k, v)
                h, (tk, tv) = jax.lax.scan(tbody, h, params["tail"])
                out["tail"] = {"k": tk, "v": tv}
            return h, out
        if cfg.family == "moe":
            out = {}
            if "dense_blocks" in params:
                def dbody(hh, p):
                    hh, kvc = dense_prefill(p, hh, "G")
                    return hh, kvc
                h, kvc = jax.lax.scan(dbody, h, params["dense_blocks"])
                out["dense_layers"] = ({"ckv": kvc[0], "kr": kvc[1]}
                                       if cfg.attention == "mla"
                                       else {"k": kvc[0], "v": kvc[1]})

            def mbody(hh, p):
                hh, kvc = dense_prefill(p, hh, "G")
                return hh, kvc

            h, kvc = jax.lax.scan(mbody, h, params["moe_blocks"])
            out["moe_layers"] = ({"ckv": kvc[0], "kr": kvc[1]}
                                 if cfg.attention == "mla"
                                 else {"k": kvc[0], "v": kvc[1]})
            return h, out
        if cfg.family == "ssm":
            K = cfg.ssm.conv_kernel

            def body(hh, p):
                hn = rmsnorm(p["ln"], hh, cfg.norm_eps)
                out, st, conv_tail = ssm_lib_prefill(p["ssm"], hn, cfg,
                                                     self.attn_impl)
                return hh + out, (st, conv_tail)

            h, (st, conv) = jax.lax.scan(body, h, params["blocks"])
            return h, {"state": st, "conv": conv}
        if cfg.family == "hybrid":
            hb = cfg.hybrid
            P = hb.shared_attn_period
            shared = params["shared_attn"]
            sub = cfg.with_(num_heads=hb.shared_attn_heads,
                            num_kv_heads=hb.shared_attn_kv_heads,
                            head_dim=cfg.d_model // hb.shared_attn_heads)

            def period(hh, p):
                hn = rmsnorm(shared["ln1"], hh, cfg.norm_eps)
                a, (ak, av) = attn.gqa_prefill(shared["attn"], hn, positions,
                                               sub, impl=self.attn_impl)
                hh = hh + a
                if "mlp" in shared:
                    hn = rmsnorm(shared["ln2"], hh, cfg.norm_eps)
                    hh = hh + mlp(shared["mlp"], hn, cfg.mlp)
                sts, convs = [], []
                for i in range(P):
                    pi = jax.tree_util.tree_map(lambda x: x[i], p)
                    hn = rmsnorm(pi["ln"], hh, cfg.norm_eps)
                    out, st, ct = ssm_lib_prefill(pi["ssm"], hn, cfg,
                                                  self.attn_impl)
                    hh = hh + out
                    sts.append(st)
                    convs.append(ct)
                return hh, (ak, av, jnp.stack(sts), jnp.stack(convs))

            h, (ak, av, st, conv) = jax.lax.scan(period, h, params["mamba"])
            return h, {"attn_k": ak, "attn_v": av, "state": st, "conv": conv}
        raise ValueError(cfg.family)


def ssm_lib_prefill(p, hn, cfg, attn_impl):
    """Mamba2 prefill: forward + (final ssm state, conv tail)."""
    s = cfg.ssm
    zxbcdt = hn @ p["in_proj"]
    z, x, Bm, Cm, dt = ssm_lib._split_proj(zxbcdt, cfg)
    xbc_raw = jnp.concatenate([x, Bm, Cm], axis=-1)
    K = s.conv_kernel
    conv_tail = jnp.pad(xbc_raw, ((0, 0), (max(0, K - 1 - xbc_raw.shape[1]), 0),
                                  (0, 0)))[:, -(K - 1):]
    xbc = ssm_lib._causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    d_in, G, N, nh = cfg.expand_dim, s.n_groups, s.d_state, cfg.ssm_heads
    xh = xbc[..., :d_in].reshape(*hn.shape[:2], nh, s.head_dim)
    Bh = xbc[..., d_in:d_in + G * N].reshape(*hn.shape[:2], G, N)
    Ch = xbc[..., d_in + G * N:].reshape(*hn.shape[:2], G, N)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_final = ssm_lib.ssd_chunked(xh, dtf, A, Bh, Ch, chunk=s.chunk_size)
    y = y + xh * p["D"][:, None].astype(xh.dtype)
    y = y.reshape(*hn.shape[:2], d_in)
    y = ssm_lib._gated_norm(p["norm"], y, z, cfg.norm_eps)
    return y @ p["out_proj"], h_final, conv_tail


def build_model(cfg: ModelConfig, shard_fn: Callable = Identity,
                attn_impl: str = "xla") -> Model:
    return Model(cfg, shard_fn=shard_fn, attn_impl=attn_impl)
