"""Mixture-of-Experts FFN with sort-based, capacity-bounded dispatch.

TPU-native adaptation (DESIGN.md §2): instead of a dense (tokens × experts ×
capacity) one-hot dispatch einsum — whose memory explodes at DeepSeek scale
(256 experts) — tokens are *sorted by expert id* and scattered into a compact
(E, C, d) buffer, computed with one stacked einsum per FFN matrix (MXU
friendly), and combined back with top-k router weights.  All shapes static,
fully differentiable (sorting indices are constants of the backward pass).

Expert weights carry the "expert"→model logical axis, so pjit shards experts
across the `model` mesh axis (EP); GSPMD inserts the token all-to-alls at the
scatter/gather boundaries.

Routers: `softmax` (standard, granite) and `sigmoid` (DeepSeek-V3 style:
sigmoid affinities, top-k, weights renormalized over the selected set).
Aux load-balance loss follows Switch/DeepSeek conventions.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .param import ParamSpec


def moe_specs(cfg, stack: Tuple[int, ...] = ()) -> Dict[str, ParamSpec]:
    ax = (None,) * len(stack)
    m = cfg.moe
    d, E, f = cfg.d_model, m.num_experts, m.d_expert
    specs = {
        "router": ParamSpec(stack + (d, E), ax + (None, None), dtype="float32"),
        "wi": ParamSpec(stack + (E, d, f), ax + ("expert", "fsdp", None), dtype=cfg.dtype),
        "wg": ParamSpec(stack + (E, d, f), ax + ("expert", "fsdp", None), dtype=cfg.dtype),
        "wo": ParamSpec(stack + (E, f, d), ax + ("expert", None, "fsdp"), dtype=cfg.dtype),
    }
    if m.num_shared:
        fs = f * m.num_shared
        specs["shared_wi"] = ParamSpec(stack + (d, fs), ax + ("fsdp", "model"),
                                       dtype=cfg.dtype)
        specs["shared_wg"] = ParamSpec(stack + (d, fs), ax + ("fsdp", "model"),
                                       dtype=cfg.dtype)
        specs["shared_wo"] = ParamSpec(stack + (fs, d), ax + ("model", "fsdp"),
                                       dtype=cfg.dtype)
    return specs


def _route(params, x2d: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (top-k expert ids (T,k), weights (T,k) in x dtype, aux loss)."""
    m = cfg.moe
    logits = (x2d.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    if m.router == "sigmoid":                     # DeepSeek-V3
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, m.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, m.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load balance: E * sum_e mean_tokens(frac_e) * mean(prob_e)
    E = m.num_experts
    onehot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    frac = onehot.mean(axis=0)
    aux = E * jnp.sum(frac * probs.mean(axis=0))
    return idx, w, aux


def moe_apply(params, x: jax.Array, cfg,
              shard=lambda x, axes=None: x) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).

    ``shard`` pins the dispatch intermediates: the expert-sorted token table
    is sharded along the sorted (expert-major) axis onto the `model` mesh
    axis, so the scatter into the (E, C, d) buffer is the EP all-to-all and
    nothing token-sized is ever replicated.
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    x2d = shard(x.reshape(T, d), ("batch", None))
    idx, w, aux = _route(params, x2d, cfg)

    k, E = m.top_k, m.num_experts
    cap = int((T * k / E) * m.capacity_factor)
    cap = max(8, -(-cap // 8) * 8)                     # round up to 8

    flat_e = idx.reshape(T * k)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    flat_w = w.reshape(T * k)
    order = jnp.argsort(flat_e)                        # stable
    se = shard(flat_e[order], ("expert",))
    st = shard(flat_tok[order], ("expert",))
    sw = shard(flat_w[order], ("expert",))
    # position of each assignment within its expert's queue
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = shard(jnp.arange(T * k) - starts[se], ("expert",))
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    gathered = shard(x2d[st] * keep[:, None].astype(x2d.dtype),
                     ("expert", None))
    buf = shard(jnp.zeros((E, cap, d), x2d.dtype).at[se, pos_c].add(gathered),
                ("expert", None, None))

    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
    h = shard(jax.nn.silu(g) * h, ("expert", None, None))
    out_buf = shard(jnp.einsum("ecf,efd->ecd", h, params["wo"]),
                    ("expert", None, None))

    y = out_buf[se, pos_c] * (keep.astype(x2d.dtype) * sw.astype(x2d.dtype))[:, None]
    y = shard(y, ("expert", None))
    out = shard(jnp.zeros((T, d), x2d.dtype).at[st].add(y), ("batch", None))

    if m.num_shared:
        sh = jnp.einsum("td,df->tf", x2d, params["shared_wi"])
        sg = jnp.einsum("td,df->tf", x2d, params["shared_wg"])
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(sg) * sh,
                               params["shared_wo"])
    return out.reshape(B, S, d), aux
