"""Parameter specification system.

Every model declares its parameters as a pytree of :class:`ParamSpec`
(shape + logical axis names + init).  From one spec tree we derive:

* ``materialize(specs, key)``  — real arrays (smoke tests / examples),
* ``abstract(specs)``          — ShapeDtypeStructs (multi-pod dry-run: no
                                 allocation for 671B-param configs),
* ``shardings(specs, mesh)``   — NamedShardings via logical->mesh axis rules.

Logical axes (MaxText-style):
    "batch"   activations' batch            -> ("pod", "data")
    "fsdp"    params' ZeRO-3 shard axis     -> ("pod", "data")
    "model"   tensor-parallel axis          -> "model"  (heads / ff / experts / vocab)
    "seq"     sequence-parallel axis        -> "data" (long-context decode caches)
    None      replicated
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis per dim
    init: str = "normal"              # normal | zeros | ones | ssm_a | ssm_dt
    scale: float = 1.0
    dtype: str = "bfloat16"
    fan_in: Optional[int] = None      # explicit fan-in for normal init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "model": "model",
    "seq": "data",
    "expert": "model",
    "heads": "model",
    "vocab": "model",
    "ff": "model",
}


def logical_to_spec(axes: Sequence[Optional[str]],
                    rules: Optional[Dict[str, Any]] = None,
                    mesh: Optional[Mesh] = None) -> P:
    rules = rules or DEFAULT_RULES
    out = []
    used: set = set()

    def mesh_axes_of(entry) -> Tuple[str, ...]:
        if entry is None:
            return ()
        return tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)

    for a in axes:
        entry = rules.get(a) if a is not None else None
        mesh_axes = tuple(m for m in mesh_axes_of(entry)
                          if (mesh is None or m in mesh.axis_names) and m not in used)
        used.update(mesh_axes)
        if not mesh_axes:
            out.append(None)
        elif len(mesh_axes) == 1:
            out.append(mesh_axes[0])
        else:
            out.append(tuple(mesh_axes))
    return P(*out)


def tree_map_specs(fn: Callable[[ParamSpec], Any], specs) -> Any:
    return jax.tree_util.tree_map(
        fn, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def abstract(specs, dtype_override: Optional[str] = None):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(dtype_override or s.dtype)),
        specs)


def shardings(specs, mesh: Mesh, rules: Optional[Dict[str, Any]] = None):
    return tree_map_specs(
        lambda s: NamedSharding(mesh, logical_to_spec(s.axes, rules, mesh)), specs)


def pspecs(specs, rules: Optional[Dict[str, Any]] = None, mesh: Optional[Mesh] = None):
    return tree_map_specs(lambda s: logical_to_spec(s.axes, rules, mesh), specs)


def materialize(specs, key: jax.Array, dtype_override: Optional[str] = None):
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        dt = jnp.dtype(dtype_override or s.dtype)
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dt))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dt))
        elif s.init == "normal":
            fan_in = s.fan_in or (s.shape[-2] if len(s.shape) >= 2
                                  else max(s.shape[-1], 1))
            std = s.scale / np.sqrt(fan_in)
            out.append((jax.random.normal(k, s.shape, jnp.float32) * std).astype(dt))
        elif s.init == "ssm_a":
            # mamba2 A init: -uniform(1, 16) in log space, per head
            u = jax.random.uniform(k, s.shape, jnp.float32, 1.0, 16.0)
            out.append(jnp.log(u).astype(jnp.float32))  # A_log kept fp32
        elif s.init == "ssm_dt":
            u = jax.random.uniform(k, s.shape, jnp.float32, 1e-3, 1e-1)
            out.append(jnp.log(jnp.expm1(u)).astype(jnp.float32))
        else:
            raise ValueError(f"unknown init {s.init!r}")
    return jax.tree_util.tree_unflatten(treedef, out)


def count_params(specs) -> int:
    leaves = jax.tree_util.tree_leaves(
        tree_map_specs(lambda s: int(np.prod(s.shape)), specs))
    return int(sum(leaves))


def param_bytes(specs) -> int:
    leaves = jax.tree_util.tree_leaves(tree_map_specs(
        lambda s: int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize, specs))
    return int(sum(leaves))
