"""Mamba2 layer via SSD (state-space duality, arXiv:2405.21060).

Recurrence (per head h, scalar decay a_t = exp(dt_t * A_h)):

    H_t = a_t * H_{t-1} + dt_t * B_t ⊗ x_t          H ∈ R^{N×P}
    y_t = C_t · H_t + D_h * x_t

Training uses the chunked SSD decomposition: the sequence is split into
chunks of Q tokens; within a chunk the recurrence is a (Q×Q) masked-decay
matmul (MXU work), across chunks a length-S/Q scan carries the (N×P) state.
The same decomposition is what the Pallas kernel (kernels/ssd_scan) tiles
into VMEM; this module is the XLA path and the oracle's structure.

Decode is the O(1) recurrence step on a carried (nh, P, N) state plus a
(K-1)-deep causal-conv cache — why SSM archs run the long_500k cell.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .param import ParamSpec


# ------------------------------------------------------------------- specs
def ssm_specs(cfg, stack: Tuple[int, ...] = ()) -> Dict[str, ParamSpec]:
    ax = (None,) * len(stack)
    s = cfg.ssm
    d = cfg.d_model
    d_in = cfg.expand_dim
    nh = cfg.ssm_heads
    G, N = s.n_groups, s.d_state
    conv_dim = d_in + 2 * G * N
    proj_out = 2 * d_in + 2 * G * N + nh   # z, x, B, C, dt
    return {
        "in_proj": ParamSpec(stack + (d, proj_out), ax + ("fsdp", "model"),
                             dtype=cfg.dtype),
        "conv_w": ParamSpec(stack + (s.conv_kernel, conv_dim),
                            ax + (None, "model"), init="normal", dtype=cfg.dtype),
        "conv_b": ParamSpec(stack + (conv_dim,), ax + ("model",), init="zeros",
                            dtype=cfg.dtype),
        "A_log": ParamSpec(stack + (nh,), ax + ("model",), init="ssm_a",
                           dtype="float32"),
        "D": ParamSpec(stack + (nh,), ax + ("model",), init="ones", dtype="float32"),
        "dt_bias": ParamSpec(stack + (nh,), ax + ("model",), init="ssm_dt",
                             dtype="float32"),
        "norm": ParamSpec(stack + (d_in,), ax + ("model",), init="ones",
                          dtype="float32"),
        "out_proj": ParamSpec(stack + (d_in, d), ax + ("model", "fsdp"),
                              dtype=cfg.dtype),
    }


def _split_proj(zxbcdt: jax.Array, cfg):
    s = cfg.ssm
    d_in, G, N, nh = cfg.expand_dim, s.n_groups, s.d_state, cfg.ssm_heads
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in:2 * d_in]
    Bm = zxbcdt[..., 2 * d_in:2 * d_in + G * N]
    Cm = zxbcdt[..., 2 * d_in + G * N:2 * d_in + 2 * G * N]
    dt = zxbcdt[..., 2 * d_in + 2 * G * N:]
    return z, x, Bm, Cm, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifts (kernel K small). xbc (B,S,C)."""
    K = w.shape[0]
    out = xbc * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[K - 1 - i]
    return jax.nn.silu(out + b)


def _gated_norm(scale: jax.Array, y: jax.Array, z: jax.Array,
                eps: float) -> jax.Array:
    out_dtype = z.dtype  # z comes straight from the (bf16) projection
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale).astype(out_dtype)


def _segsum(a: jax.Array) -> jax.Array:
    """L[i, j] = sum_{j < m <= i} a_m for i >= j else -inf.  a (..., Q)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # (..., i, j): sum (j, i]
    ii = jnp.arange(Q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


# ---------------------------------------------------------------- SSD core
def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """SSD over chunks.

    x  (B, S, nh, P)    dt (B, S, nh)    A (nh,) negative
    Bm (B, S, G, N)     Cm (B, S, G, N)
    -> y (B, S, nh, P), final_state (B, nh, N, P)
    """
    Bsz, S, nh, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    hg = nh // G                                        # heads per group
    xc = x.reshape(Bsz, nc, Q, nh, P)
    dtc = dt.reshape(Bsz, nc, Q, nh)
    Bc = Bm.reshape(Bsz, nc, Q, G, N)
    Cc = Cm.reshape(Bsz, nc, Q, G, N)

    a = dtc * A                                          # (B,nc,Q,nh) decay logs (<=0)
    a_h = jnp.moveaxis(a, -1, 2)                         # (B,nc,nh,Q)
    L = jnp.exp(_segsum(a_h))                            # (B,nc,nh,Q,Q)

    # intra-chunk (the quadratic-but-tiny part; MXU matmuls)
    scores_g = jnp.einsum("bcign,bcjgn->bcgij", Cc, Bc)  # (B,nc,G,Q,Q)
    scores = jnp.repeat(scores_g, hg, axis=2)            # (B,nc,nh,Q,Q)
    M = scores * L * jnp.moveaxis(dtc, -1, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M, xc)

    # per-chunk summarized state:  states[c] = Σ_j exp(a_sum - cumsum_j) dt_j B_j ⊗ x_j
    a_cum = jnp.cumsum(a_h, axis=-1)                      # (B,nc,nh,Q)
    a_tot = a_cum[..., -1]                                # (B,nc,nh)
    decay_out = jnp.exp(a_tot[..., None] - a_cum)         # (B,nc,nh,Q)
    wts = decay_out * jnp.moveaxis(dtc, -1, 2)            # (B,nc,nh,Q)
    Bh = jnp.repeat(Bc, hg, axis=3)                       # (B,nc,Q,nh,N)
    states = jnp.einsum("bchj,bcjhn,bcjhp->bchnp", wts, Bh, xc)

    # inter-chunk state scan
    h0 = (jnp.zeros((Bsz, nh, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_body(h, inp):
        st, atot = inp                                    # (B,nh,N,P), (B,nh)
        h_new = h * jnp.exp(atot)[..., None, None] + st.astype(jnp.float32)
        return h_new, h                                   # emit state BEFORE chunk

    (h_final, h_prevs) = jax.lax.scan(
        scan_body, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(a_tot, 1, 0)))
    h_prev = jnp.moveaxis(h_prevs, 0, 1)                  # (B,nc,nh,N,P)

    # inter-chunk contribution:  y_inter[i] = exp(a_cum_i) * C_i · h_prev
    decay_in = jnp.exp(a_cum)                             # (B,nc,nh,Q)
    Ch = jnp.repeat(Cc, hg, axis=3)                       # (B,nc,Q,nh,N)
    y_inter = jnp.einsum("bcihn,bchnp,bchi->bcihp", Ch,
                         h_prev.astype(Ch.dtype),
                         decay_in.astype(Ch.dtype))
    y = (y_intra + y_inter).reshape(Bsz, S, nh, P)
    return y.astype(x.dtype), h_final


def ssd_reference(x, dt, A, Bm, Cm, init_state=None):
    """Naive per-token scan oracle (tests compare chunked + kernel to this)."""
    Bsz, S, nh, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hg = nh // G
    Bh = jnp.repeat(Bm, hg, axis=2)
    Ch = jnp.repeat(Cm, hg, axis=2)
    h0 = (jnp.zeros((Bsz, nh, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, bt, ct = inp                              # (B,nh,P),(B,nh),(B,nh,N)x2
        decay = jnp.exp(dtt * A)                           # (B,nh)
        h = h * decay[..., None, None] + jnp.einsum(
            "bhn,bhp,bh->bhnp", bt, xt, dtt).astype(jnp.float32)
        y = jnp.einsum("bhn,bhnp->bhp", ct, h.astype(ct.dtype))
        return h, y

    h_final, ys = jax.lax.scan(
        step, h0, (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
                   jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final


# ------------------------------------------------------------- layer fwd
def mamba2_forward(params, u: jax.Array, cfg, *, impl: str = "xla",
                   init_state=None, return_state: bool = False):
    """Full Mamba2 layer: in_proj -> conv -> SSD -> gated norm -> out_proj."""
    s = cfg.ssm
    zxbcdt = u @ params["in_proj"]
    z, x, Bm, Cm, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    d_in = cfg.expand_dim
    G, N, nh = s.n_groups, s.d_state, cfg.ssm_heads
    x = xbc[..., :d_in].reshape(*u.shape[:2], nh, s.head_dim)
    Bm = xbc[..., d_in:d_in + G * N].reshape(*u.shape[:2], G, N)
    Cm = xbc[..., d_in + G * N:].reshape(*u.shape[:2], G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, h_final = ssd_ops.ssd(x, dt, A, Bm, Cm, chunk=s.chunk_size,
                                 interpret=(impl == "pallas_interpret"))
    else:
        y, h_final = ssd_chunked(x, dt, A, Bm, Cm, chunk=s.chunk_size,
                                 init_state=init_state)
    y = y + x * params["D"][:, None].astype(x.dtype)
    y = y.reshape(*u.shape[:2], d_in)
    y = _gated_norm(params["norm"], y, z, cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        return out, h_final
    return out


def mamba2_decode_step(params, u: jax.Array, ssm_state: jax.Array,
                       conv_state: jax.Array, cfg):
    """One-token decode. u (B,1,d); ssm_state (B,nh,N,P);
    conv_state (B,K-1,conv_dim). Returns (out, new_ssm_state, new_conv_state)."""
    s = cfg.ssm
    zxbcdt = u @ params["in_proj"]
    z, x, Bm, Cm, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)            # (B,1,conv_dim)
    window = jnp.concatenate([conv_state, xbc], axis=1)    # (B,K,conv_dim)
    conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"]) + params["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None]              # (B,1,conv_dim)
    new_conv_state = window[:, 1:]
    d_in, G, N, nh = cfg.expand_dim, s.n_groups, s.d_state, cfg.ssm_heads
    xt = conv_out[..., :d_in].reshape(-1, nh, s.head_dim)
    Bt = conv_out[..., d_in:d_in + G * N].reshape(-1, G, N)
    Ct = conv_out[..., d_in + G * N:].reshape(-1, G, N)
    hg = nh // G
    Bt = jnp.repeat(Bt, hg, axis=1)
    Ct = jnp.repeat(Ct, hg, axis=1)
    dtt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dtt * A)                                # (B,nh)
    new_state = ssm_state * decay[..., None, None] + jnp.einsum(
        "bhn,bhp,bh->bhnp", Bt, xt, dtt.astype(xt.dtype)).astype(ssm_state.dtype)
    y = jnp.einsum("bhn,bhnp->bhp", Ct, new_state.astype(Ct.dtype))
    y = y + xt * params["D"][:, None].astype(xt.dtype)
    y = y.reshape(-1, 1, d_in)
    y = _gated_norm(params["norm"], y, z, cfg.norm_eps)
    return y @ params["out_proj"], new_state, new_conv_state
