from .adamw import AdamW, apply_updates, cosine_schedule, global_norm
from .grad_compress import (compress_grads, compression_ratio,
                            init_error_feedback)

__all__ = ["AdamW", "apply_updates", "compress_grads", "compression_ratio",
           "cosine_schedule", "global_norm", "init_error_feedback"]
