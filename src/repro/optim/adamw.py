"""AdamW with global-norm clipping and configurable moment dtype.

Self-contained (no optax in the container).  Moments can be kept in
bfloat16 (``moment_dtype="bfloat16"``) to fit very large models — the
deepseek-v3 config uses this (see EXPERIMENTS.md memory table).  State is a
plain dict pytree ({"step", "m", "v"}) so abstract lowering, sharding and
checkpointing all share one structure; moments reuse the parameters' logical
axes, so optimizer state is ZeRO-sharded wherever params are.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"

    def init(self, params) -> Dict[str, Any]:
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def state_specs(self, param_specs) -> Dict[str, Any]:
        """ParamSpec pytree for the optimizer state (dry-run / checkpoint)."""
        from repro.models.param import ParamSpec, tree_map_specs
        remap = lambda s: ParamSpec(s.shape, s.axes, init="zeros",
                                    dtype=self.moment_dtype)
        return {"step": ParamSpec((), (), init="zeros", dtype="int32"),
                "m": tree_map_specs(remap, param_specs),
                "v": tree_map_specs(remap, param_specs)}

    def update(self, grads, state: Dict[str, Any], params
               ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9)) \
            if self.clip_norm else jnp.float32(1.0)
        mdt = jnp.dtype(self.moment_dtype)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32) * scale
            m_new = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * gf
            v_new = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * gf * gf
            mh = m_new / (1 - self.b1 ** step.astype(jnp.float32))
            vh = v_new / (1 - self.b2 ** step.astype(jnp.float32))
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # no decay on norms/scalars
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (-self.learning_rate(step) * delta).astype(p.dtype), \
                m_new.astype(mdt), v_new.astype(mdt)

        triples = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                         params)
        is_triple = lambda x: isinstance(x, tuple) and len(x) == 3 \
            and all(isinstance(t, jax.Array) for t in x)
        pick = lambda i: jax.tree_util.tree_map(lambda t: t[i], triples,
                                                is_leaf=is_triple)
        return pick(0), {"step": step, "m": pick(1), "v": pick(2)}, \
            {"grad_norm": gnorm}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return lr
