"""Int8 gradient compression with error feedback.

For cross-pod data parallelism the gradient all-reduce over DCI is the
scarce resource; int8 quantization cuts those bytes 2x (bf16) / 4x (f32).
Per-leaf symmetric quantization with an error-feedback residual keeps the
optimizer trajectory unbiased (Seide et al. / 1-bit Adam lineage).

Two integration points:
* `compress_grads` / state-carried residual — drop-in around the optimizer
  (works under pjit; models the numerics of a quantized all-reduce);
* `quantized_psum` in distributed/collectives.py — the explicit shard_map
  collective used on real multi-pod meshes (int8 payload on the wire).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(x)) + 1e-12
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, error_fb):
    """-> (dequantized grads as seen post-allreduce, new error residuals)."""
    def per_leaf(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize(gf)
        deq = _dequantize(q, scale)
        return deq.astype(g.dtype), gf - deq

    flat = jax.tree_util.tree_map(per_leaf, grads, error_fb)
    new_g = jax.tree_util.tree_map(lambda t: t[0], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e


def compression_ratio(tree, from_dtype: str = "bfloat16") -> float:
    nbytes_in = sum(l.size * jnp.dtype(from_dtype).itemsize
                    for l in jax.tree_util.tree_leaves(tree))
    nbytes_out = sum(l.size + 4 for l in jax.tree_util.tree_leaves(tree))
    return nbytes_in / nbytes_out
