"""Fallback for ``hypothesis`` when it is not installed.

The test suite uses a small, well-defined subset of the hypothesis API:

    @settings(max_examples=N, deadline=None)
    @given(st.integers(a, b), st.floats(a, b), st.booleans(),
           st.lists(st.tuples(...), min_size=., max_size=.),
           st.sampled_from([...]))
    def test_foo(x, y, ...): ...

When the real package is importable we re-export it untouched.  Otherwise
this module provides a deterministic stand-in: each decorated test runs
``max_examples`` times with values drawn from a PRNG seeded by the test name,
with the first example forced to every strategy's minimal value (empty lists,
lower bounds) so boundary cases are always exercised.  No shrinking, no
database — just seeded example generation, which is enough to keep the
property suites meaningful and reproducible everywhere.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

except ImportError:
    import zlib

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """A value generator: ``draw(rng)`` random, ``minimal()`` boundary."""

        def __init__(self, draw, minimal):
            self._draw = draw
            self._minimal = minimal

        def draw(self, rng):
            return self._draw(rng)

        def minimal(self):
            return self._minimal()

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                lambda: int(min_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            lo, hi = float(min_value), float(max_value)
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)),
                             lambda: lo)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)),
                             lambda: False)

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: items[int(rng.integers(len(items)))],
                             lambda: items[0])

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            hi = max_size if max_size is not None else min_size + 10

            def draw(rng):
                n = int(rng.integers(min_size, hi + 1))
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(
                draw, lambda: [elements.minimal() for _ in range(min_size)])

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems),
                             lambda: tuple(e.minimal() for e in elems))

    strategies = _Strategies()

    class settings:  # noqa: N801 - mirrors the hypothesis API
        def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
            self.max_examples = int(max_examples)

        def __call__(self, fn):
            fn._compat_max_examples = self.max_examples
            return fn

    def given(*strats):
        def decorate(fn):
            # The wrapper takes NO parameters: pytest must not try to resolve
            # the strategy-supplied arguments as fixtures.  (For the same
            # reason we do not set __wrapped__ — inspect.signature follows it.)
            def wrapper():
                n = getattr(wrapper, "_compat_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
                for i in range(n):
                    if i == 0:
                        vals = [s.minimal() for s in strats]
                    else:
                        vals = [s.draw(rng) for s in strats]
                    fn(*vals)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            return wrapper

        return decorate
