"""Hostile-storage hardening: fault injection, retry/hedged fetches, and
the failure-visibility contract (ISSUE 6).

Covers the satellite checklist: seeded determinism, retry-exhaustion
raising ``StorageError``, hedge first-responder-wins consuming exactly one
result, torn-read detection, stream parity under injected faults, the
flock-based cross-process ``LocalProvider.cas``, and the EWMA taint
exclusion.
"""

import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import repro.core as dl
import repro.core.fetch as fetchlib
from repro.core.fetch import FetchEngine, RetryPolicy
from repro.core.scheduler import CostModel
from repro.core.storage import (FaultPolicy, MemoryProvider, RetryExhausted,
                                SimulatedS3Provider, StorageError,
                                StorageTimeout, TornReadError,
                                TransientStorageError)


def _faulty_s3(base=None, **rates):
    fp = FaultPolicy(seed=rates.pop("seed", 7), **rates)
    return SimulatedS3Provider(base or MemoryProvider(), time_scale=0,
                               fault_policy=fp)


# ------------------------------------------------------------ fault policy
def _op_trace(provider, keys):
    """Outcome per sequential read op: payload length or exception type."""
    trace = []
    for k in keys:
        try:
            trace.append(len(provider.get(k)))
        except TransientStorageError as e:
            trace.append(type(e).__name__)
    return trace


def test_fault_policy_seeded_determinism():
    keys = [f"k{i % 4}" for i in range(200)]
    traces, stats = [], []
    for _ in range(2):
        base = MemoryProvider()
        for k in set(keys):
            base.put(k, b"v" * 64)
        s3 = _faulty_s3(base, seed=123, timeout_rate=0.1, error_rate=0.1,
                        straggle_rate=0.1, torn_rate=0.1)
        traces.append(_op_trace(s3, keys))
        stats.append({k: v for k, v in s3.stats.items()
                      if k.startswith("faults_")})
    assert traces[0] == traces[1]
    assert stats[0] == stats[1]
    assert stats[0]["faults_injected"] > 0
    # every read-plane fault kind appears (the trace never writes, so the
    # write-plane counters stay zero) and the kinds sum to the total
    per_kind = {k: v for k, v in stats[0].items() if k != "faults_injected"}
    read_plane = [v for k, v in per_kind.items()
                  if not k.startswith(("faults_put", "faults_cas"))]
    assert all(v > 0 for v in read_plane)
    assert sum(per_kind.values()) == stats[0]["faults_injected"]


def test_fault_policy_caps_consecutive_hard_faults_per_key():
    s3 = _faulty_s3(seed=1, timeout_rate=1.0)  # every draw wants a timeout
    s3.base.put("k", b"payload")
    with pytest.raises(StorageTimeout):
        s3.get("k")
    with pytest.raises(StorageTimeout):
        s3.get("k")
    # liveness cap: the third consecutive read of the key must succeed
    assert s3.get("k") == b"payload"
    assert s3.stats["faults_timeout"] == 2


def test_transient_error_is_not_a_missing_key():
    assert not issubclass(TransientStorageError, StorageError)
    assert not issubclass(TransientStorageError, KeyError)
    assert issubclass(RetryExhausted, StorageError)
    # get_or_none: missing key -> None, but transient faults are retried
    s3 = _faulty_s3(seed=2, error_rate=1.0)
    assert s3.get_or_none("absent") is None
    s3.base.put("k", b"v")
    assert s3.get_or_none("k") == b"v"  # 2 faults, then the cap clears it


def test_torn_read_detected_and_retried():
    s3 = _faulty_s3(seed=3, torn_rate=1.0)
    s3.base.put("k", b"x" * 256)
    with pytest.raises(TornReadError):
        s3.get("k")  # provider surfaces the short read as typed transient
    s3_fresh = _faulty_s3(seed=3, torn_rate=1.0)
    s3_fresh.base.put("k", b"x" * 256)
    eng = FetchEngine(s3_fresh)
    assert eng.fetch_full("k") == b"x" * 256  # retried through the tears
    assert eng.stats["errors_transient"] == 2
    assert s3_fresh.stats["faults_torn"] == 2


# ------------------------------------------------------------ engine retry
def test_retry_exhaustion_raises_storage_error():
    # cap above the attempt budget: faults never stop -> exhaustion
    fp = FaultPolicy(seed=4, error_rate=1.0, max_consecutive_per_key=99)
    s3 = SimulatedS3Provider(MemoryProvider(), time_scale=0, fault_policy=fp)
    s3.base.put("k", b"v")
    eng = FetchEngine(s3, retry=RetryPolicy(max_attempts=3,
                                            backoff_base_s=0.001))
    with pytest.raises(StorageError) as exc_info:
        eng.fetch_full("k")
    assert isinstance(exc_info.value, RetryExhausted)
    assert eng.stats["errors_transient"] == 3
    assert eng.stats["retries"] == 2
    assert eng.stats["errors_permanent"] == 1
    # the root cause rides the exception chain
    assert isinstance(exc_info.value.__cause__, TransientStorageError)


def test_ranged_reads_retry_transients():
    s3 = _faulty_s3(seed=5, error_rate=1.0)
    s3.base.put("k", bytes(range(200)))
    eng = FetchEngine(s3)
    out = eng.fetch_ranges("k", [(10, 20), (150, 160)])
    assert out[0] == bytes(range(10, 20))
    assert out[1] == bytes(range(150, 160))
    assert eng.stats["errors_transient"] > 0


def test_nonstorage_exception_in_prefetch_reraises():
    """A decode bug (non-storage exception) must re-raise to the reader —
    never masquerade as a cache miss."""
    gate = threading.Event()

    class BuggyProvider(MemoryProvider):
        def get(self, key):
            gate.wait(timeout=5)
            raise ValueError("decode bug, not a storage problem")

    provider = BuggyProvider()   # strong ref: the engine only holds a weakref
    eng = FetchEngine(provider)
    fut = eng.prefetch("k")
    threading.Timer(0.05, gate.set).start()
    with pytest.raises(ValueError):
        eng.wait_inflight("k")      # blocked in flight, then the bug lands
    with pytest.raises(ValueError):
        fut.result(timeout=5)
    time.sleep(0.1)                 # let the done-callback run
    assert eng.stats["prefetch_failures"] == 1
    assert eng.stats["inflight_fallbacks"] == 0  # bugs are not fallbacks


def test_exhausted_prefetch_falls_back_counted():
    """A prefetch that burns its retry budget resolves to None for racing
    readers (they fall back to direct I/O) and is visibly counted."""
    gate = threading.Event()

    class FaultyProvider(MemoryProvider):
        def get(self, key):
            gate.wait(timeout=5)
            raise TransientStorageError("injected throttle")

    provider = FaultyProvider()  # strong ref: the engine only holds a weakref
    eng = FetchEngine(provider,
                      retry=RetryPolicy(max_attempts=2,
                                        backoff_base_s=0.001))
    fut = eng.prefetch("k")
    threading.Timer(0.05, gate.set).start()
    assert eng.wait_inflight("k") is None   # RetryExhausted -> fallback
    assert eng.stats["inflight_fallbacks"] == 1
    with pytest.raises(StorageError):
        fut.result(timeout=5)
    time.sleep(0.1)
    assert eng.stats["prefetch_failures"] == 1
    assert eng.stats["errors_permanent"] == 1


# ---------------------------------------------------------------- hedging
class _StragglerOnce(MemoryProvider):
    """First get of ``slow_key`` blocks until released; later gets fast."""

    def __init__(self, slow_key):
        super().__init__()
        self.slow_key = slow_key
        self.release = threading.Event()
        self.calls = []
        self._call_lock = threading.Lock()

    def get(self, key):
        with self._call_lock:
            self.calls.append(key)
            nth = self.calls.count(key)
        if key == self.slow_key and nth == 1:
            self.release.wait(timeout=10)
        return super().get(key)


def test_hedge_first_responder_wins_consumes_one_result():
    p = _StragglerOnce("slow")
    p.put("slow", b"S" * 100)
    p.put("fast", b"F" * 100)
    eng = FetchEngine(p, retry=RetryPolicy(hedge_multiplier=2.0,
                                           hedge_min_s=0.05))
    # establish a clean-wall baseline so hedging is armed
    eng.prefetch("fast").result(timeout=5)
    assert eng.detector.baseline is not None
    fut = eng.prefetch("slow")
    blob = fut.result(timeout=10)   # hedge fires at ~50ms and wins
    assert blob == b"S" * 100
    assert eng.stats["hedges"] == 1
    assert eng.stats["hedge_wins"] == 1
    assert eng.stats["stragglers"] == 1
    assert eng.detector.mitigations >= 1  # the detector saw the straggler
    p.release.set()                 # unblock the losing primary
    time.sleep(0.1)
    # exactly one result was consumed: the resident blob is the winner's,
    # and exactly two physical requests went out (primary + hedge)
    assert eng.resident("slow") == b"S" * 100
    assert p.calls.count("slow") == 2


def test_no_hedge_without_baseline():
    p = _StragglerOnce("slow")
    p.put("slow", b"S")
    eng = FetchEngine(p, retry=RetryPolicy(hedge_min_s=0.05))
    fut = eng.prefetch("slow")      # no baseline yet -> no hedge ever
    time.sleep(0.2)
    assert not fut.done()
    assert eng.stats["hedges"] == 0
    p.release.set()
    assert fut.result(timeout=5) == b"S"


# ------------------------------------------------------------- EWMA taint
def test_fault_timings_excluded_from_latency_ewma():
    eng = FetchEngine(MemoryProvider())   # unseeded: EWMA-learned
    assert not eng.est.seeded
    lat0, bw0 = eng.est.latency_s, eng.est.bandwidth_bps
    eng._observe(1, 0, 1 << 20, 5.0, clean=False)  # a straggling request
    assert eng.est.latency_s == lat0      # tainted: never folded
    assert eng.est.bandwidth_bps == bw0
    eng._observe(1, 0, 1 << 20, 5.0, clean=True)
    assert eng.est.latency_s != lat0      # clean: folded


def test_cost_model_taint_counter():
    cm = CostModel()
    cm.observe("unit", 0.010, 0.001)
    io0, cpu0 = cm.estimate("unit")
    cm.observe("unit", 9.0, 9.0, clean=False)
    assert cm.estimate("unit") == (io0, cpu0)
    assert cm.counters["tainted_unit"] == 1


# ------------------------------------------------------------ stream parity
def _clustered_dataset(base):
    ds = dl.Dataset(base)
    ds.create_tensor("val", dtype="float32", min_chunk_size=1 << 11,
                     max_chunk_size=1 << 12)
    ds.create_tensor("lab", htype="class_label")
    rng = np.random.default_rng(11)
    for band in range(8):
        lo = band * 100.0
        vals = rng.uniform(lo, lo + 90.0, size=100).astype(np.float32)
        for i, v in enumerate(vals):
            ds.append({"val": v, "lab": np.int64(band * 100 + i)})
    ds.commit("chaos fixture")
    return ds


def _run_query_and_stream(storage):
    ds = dl.Dataset(storage)
    view = ds.query("SELECT * FROM dataset WHERE MIN(val) > 580",
                    engine="numpy")
    idx = view.indices.tolist()
    loader = ds.dataloader(batch_size=32, shuffle=False, num_workers=2,
                           seed=0)
    labs, vals = [], []
    for batch in loader:
        labs.extend(int(v) for v in batch["lab"])
        vals.append(np.asarray(batch["val"]))
    return idx, labs, np.concatenate(vals).tobytes()


def test_stream_parity_under_injected_faults():
    """The acceptance gate in miniature: same query + loader results,
    byte-identical, with and without seeded faults."""
    base = MemoryProvider()
    _clustered_dataset(base)
    clean = _run_query_and_stream(
        SimulatedS3Provider(base, time_scale=0))
    s3 = _faulty_s3(base, seed=20260807, timeout_rate=0.04, error_rate=0.04,
                    straggle_rate=0.04, torn_rate=0.03)
    faulted = _run_query_and_stream(s3)
    assert clean[0] == faulted[0]          # identical selected rows
    assert clean[1] == faulted[1]          # identical stream order
    assert clean[2] == faulted[2]          # byte-identical payloads
    assert s3.stats["faults_injected"] > 0
    stats = fetchlib.engine_stats_for(s3)
    assert stats["errors_transient"] > 0   # faults were absorbed, visibly


# --------------------------------------------------------- cross-process cas
def test_local_cas_serializes_across_processes(tmp_path):
    """Two processes cas-increment one counter; every increment must land
    (the old threading.Lock serialized only within one process)."""
    import os
    root = str(tmp_path / "store")
    src = os.path.abspath(os.path.join(os.path.dirname(dl.__file__),
                                       "..", ".."))
    n_iters = 40
    script = f"""
from repro.core.storage import LocalProvider
p = LocalProvider({root!r})
for _ in range({n_iters}):
    while True:
        cur = p.get_or_none("counter")
        new = str(int(cur or b"0") + 1).encode()
        if p.cas("counter", new, cur):
            break
"""
    env = dict(os.environ, PYTHONPATH=src)
    procs = [subprocess.Popen([sys.executable, "-c", script], env=env)
             for _ in range(2)]
    for pr in procs:
        assert pr.wait(timeout=120) == 0
    p = dl.LocalProvider(root)
    assert int(p.get("counter")) == 2 * n_iters


def test_cas_lockfiles_hidden_from_list_keys(tmp_path):
    p = dl.LocalProvider(str(tmp_path / "store"))
    p.put("a", b"1")
    assert p.cas("b", b"2", None)
    assert p.list_keys() == ["a", "b"]


# ------------------------------------------------------------- write plane
def test_put_verified_detects_and_heals_torn_uploads():
    s3 = _faulty_s3(seed=5, put_torn_rate=1.0)
    # a raw put tears SILENTLY: success reported, only a prefix durable
    s3.put("raw", b"0123456789")
    assert s3.base.get("raw") == b"01234"
    # put_verified catches the short object and re-puts until whole
    s3.put_verified("ok", b"0123456789")
    assert s3.get("ok") == b"0123456789"
    assert s3.stats["faults_put_torn"] >= 1
    assert s3.stats["wasted_upload_bytes"] > 0
    assert s3.stats["put_requests"] >= 3  # 1 raw + >=2 verified attempts


def test_put_5xx_leaves_nothing_durable_and_is_retriable():
    s3 = _faulty_s3(seed=6, put_error_rate=1.0)
    with pytest.raises(TransientStorageError):
        s3.put("k", b"payload")
    assert not s3.base.exists("k")  # failed upload: nothing became visible
    s3.put_verified("k", b"payload")  # retry budget outlives the streak cap
    assert s3.get("k") == b"payload"
    assert s3.stats["faults_put_5xx"] >= 1


def test_cas_5xx_fires_before_applying():
    s3 = _faulty_s3(seed=9, cas_error_rate=1.0)
    with pytest.raises(TransientStorageError):
        s3.cas("m", b"v1", None)
    assert not s3.base.exists("m")  # transient cas: nothing applied
    from repro.core.storage import retry_transient
    assert retry_transient(lambda: s3.cas("m", b"v1", None)) is True
    assert s3.get("m") == b"v1"
    assert s3.stats["faults_cas_5xx"] >= 1
    assert s3.stats["cas_conflicts"] == 0  # faults are not contention


def test_commit_round_trip_under_write_faults():
    """End-to-end torn-upload round-trip: a dataset written entirely under
    injected put/cas faults reads back byte-identical."""
    s3 = _faulty_s3(seed=11, put_torn_rate=0.25, put_error_rate=0.15,
                    cas_error_rate=0.15)
    ds = dl.Dataset(s3)
    ds.create_tensor("t", dtype="float32", min_chunk_size=256,
                     max_chunk_size=512)
    for i in range(40):
        ds["t"].append(np.full(16, i, np.float32))
    ds.commit("written under write chaos")
    st = s3.stats
    assert st["put_requests"] > 0
    assert st["faults_put_torn"] > 0
    assert st["wasted_upload_bytes"] > 0
    r = dl.Dataset(s3)
    assert len(r["t"]) == 40
    for i in range(40):
        np.testing.assert_array_equal(r["t"][i], np.full(16, i, np.float32))
