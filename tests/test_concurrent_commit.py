"""Concurrent committers: optimistic rebase-and-retry commits (ISSUE 7).

Property coverage for the transactional write path: non-overlapping
commits both land (cross-branch adoption and same-branch relocation with
chunk grafting — zero re-uploads), overlapping same-branch commits get
exactly one winner and a typed ``CommitContendedError`` for the loser,
N-way threaded committers all land, and a crash mid-publish leaves a
readable head plus GC-collectable orphans.
"""

import threading

import numpy as np
import pytest

import repro.core as dl
from repro.core.manifest import ManifestConflict
from repro.core.version_control import (COMMIT_REBASE_ATTEMPTS,
                                        CommitContendedError)


def _mk(storage, tensors=("a", "b")):
    ds = dl.Dataset(storage)
    for t in tensors:
        ds.create_tensor(t, dtype="float32", min_chunk_size=256,
                         max_chunk_size=512)
    ds.commit("init")
    return ds


def _rows(ds, t):
    return [ds[t][i] for i in range(len(ds[t]))]


# ------------------------------------------------- same-branch, disjoint sets
def test_same_branch_disjoint_tensors_both_land_with_graft():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    _mk(s3)
    a = dl.Dataset(s3)
    b = dl.Dataset(s3)
    for i in range(6):
        a["a"].append(np.full(8, i, np.float32))
        b["b"].append(np.full(8, 100 + i, np.float32))
    a.commit("writer A: tensor a")
    wasted_before = s3.stats["wasted_upload_bytes"]
    b.commit("writer B: tensor b")  # loses the CAS -> rebase + relocation
    st = b.vc.commit_stats
    assert st["rebases"] >= 1
    assert st["relocations"] >= 1
    assert st["grafted_chunks"] >= 1
    assert st["contended"] == 0
    # grafting means the loser re-publishes metadata only: no chunk bytes
    # were uploaded twice (no faults injected -> waste must stay zero)
    assert s3.stats["wasted_upload_bytes"] == wasted_before == 0
    # a fresh reader sees BOTH writers' appends
    r = dl.Dataset(s3)
    assert len(r["a"]) == 6 and len(r["b"]) == 6
    for i in range(6):
        np.testing.assert_array_equal(r["a"][i], np.full(8, i, np.float32))
        np.testing.assert_array_equal(r["b"][i],
                                      np.full(8, 100 + i, np.float32))
    # and the grafted chunks are NOT orphans: GC keeps every byte
    rep = r.maintenance().gc_orphans(dry_run=True)
    assert rep.details["orphan_chunk_bytes"] == 0


def test_relocated_commit_survives_gc_sweep():
    """Grafted chunks live in the old head's directory; a destructive GC
    sweep must keep them (reachability is (tensor, name)-based)."""
    storage = dl.MemoryProvider()
    _mk(storage)
    a = dl.Dataset(storage)
    b = dl.Dataset(storage)
    a["a"].append(np.full(8, 1.0, np.float32))
    b["b"].append(np.full(8, 2.0, np.float32))
    a.commit("A")
    b.commit("B")
    r = dl.Dataset(storage)
    r.maintenance().gc_orphans(dry_run=False)
    r2 = dl.Dataset(storage)
    np.testing.assert_array_equal(r2["a"][0], np.full(8, 1.0, np.float32))
    np.testing.assert_array_equal(r2["b"][0], np.full(8, 2.0, np.float32))


# ---------------------------------------------- same-branch, overlapping sets
def test_same_branch_overlap_exactly_one_winner():
    storage = dl.MemoryProvider()
    _mk(storage)
    a = dl.Dataset(storage)
    b = dl.Dataset(storage)
    a["a"].append(np.full(8, 1.0, np.float32))
    b["a"].append(np.full(8, 2.0, np.float32))
    a.commit("winner")
    with pytest.raises(CommitContendedError) as ei:
        b.commit("loser")
    # typed error is still a ManifestConflict (callers catching the PR-4
    # contract keep working)
    assert isinstance(ei.value, ManifestConflict)
    assert b.vc.commit_stats["contended"] >= 1
    r = dl.Dataset(storage)
    assert len(r["a"]) == 1
    np.testing.assert_array_equal(r["a"][0], np.full(8, 1.0, np.float32))


# ------------------------------------------------------------- cross-branch
def test_cross_branch_commits_both_land_without_relocation():
    storage = dl.MemoryProvider()
    ds = _mk(storage)
    ds.checkout("side", create=True)  # publish the branch serially
    a = dl.Dataset(storage)
    a.checkout("main")                # opens bind to the last current branch
    b = dl.Dataset(storage)
    b.checkout("side")
    a["a"].append(np.full(8, 1.0, np.float32))
    b["a"].append(np.full(8, 2.0, np.float32))  # same tensor: fine x-branch
    a.commit("on main")
    b.commit("on side")  # stale pointer -> rebase adopts, head untouched
    assert b.vc.commit_stats["rebases"] >= 1
    assert b.vc.commit_stats["relocations"] == 0
    r = dl.Dataset(storage)
    r.checkout("main")
    np.testing.assert_array_equal(r["a"][0], np.full(8, 1.0, np.float32))
    r.checkout("side")
    np.testing.assert_array_equal(r["a"][0], np.full(8, 2.0, np.float32))


def test_four_threaded_committers_all_land():
    storage = dl.MemoryProvider()
    ds = dl.Dataset(storage)
    ds.create_tensor("t", dtype="float32", min_chunk_size=256,
                     max_chunk_size=512)
    ds.commit("init")
    n = 4
    for i in range(n):
        ds.checkout(f"w{i}", create=True)  # serial branch setup
    handles = []
    for i in range(n):
        h = dl.Dataset(storage)
        h.checkout(f"w{i}")
        handles.append(h)
    barrier = threading.Barrier(n)
    errors = []

    def run(i, h):
        try:
            barrier.wait()
            for j in range(3):
                h["t"].append(np.full(8, i * 100 + j, np.float32))
                h.commit(f"w{i} c{j}")
        except Exception as e:  # noqa: BLE001 - surfaced via assert below
            errors.append((i, e))

    threads = [threading.Thread(target=run, args=(i, h))
               for i, h in enumerate(handles)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    # no lost appends: every branch holds exactly its writer's rows
    for i in range(n):
        r = dl.Dataset(storage)
        r.checkout(f"w{i}")
        assert len(r["t"]) == 3
        for j in range(3):
            np.testing.assert_array_equal(
                r["t"][j], np.full(8, i * 100 + j, np.float32))


# ------------------------------------------------------------ bounded retries
def test_commit_gives_up_after_bounded_rebases(monkeypatch):
    storage = dl.MemoryProvider()
    _mk(storage)
    w = dl.Dataset(storage)
    w["a"].append(np.full(8, 1.0, np.float32))
    # every publish attempt is beaten by an interleaved foreign commit on
    # ANOTHER branch (so each rebase adopts and retries, never contends on
    # tensors) -- the loop must terminate in a typed error, not spin
    spoiler = dl.Dataset(storage)
    spoiler.checkout("noise", create=True)
    import repro.core.manifest as mlib
    real = mlib.Manifest.commit_update
    busy = []

    def beaten(self, *args, **kwargs):
        # each rebase swaps w.vc.manifest for a fresh object, so key the
        # spoiling on the writer's CURRENT manifest, and never recurse
        # into the spoiler's own publish
        if self is not w.vc.manifest or busy:
            return real(self, *args, **kwargs)
        busy.append(1)
        try:
            spoiler["b"].append(np.full(8, 0.0, np.float32))
            spoiler.commit("spoiler")
        finally:
            busy.pop()
        return real(self, *args, **kwargs)

    monkeypatch.setattr(mlib.Manifest, "commit_update", beaten)
    with pytest.raises(CommitContendedError):
        w.commit("never lands")
    assert w.vc.commit_stats["rebases"] >= COMMIT_REBASE_ATTEMPTS


# ------------------------------------------------------------ crash recovery
class _Crash(RuntimeError):
    pass


def test_crash_mid_publish_leaves_readable_head_and_gc_orphans(monkeypatch):
    storage = dl.MemoryProvider()
    _mk(storage)
    w = dl.Dataset(storage)
    w["a"].append(np.full(8, 7.0, np.float32))

    def dying_cas(key, data, expected):
        raise _Crash("process died mid-publish")

    real_cas = storage.cas
    monkeypatch.setattr(storage, "cas", dying_cas)
    with pytest.raises(_Crash):
        w.commit("doomed")
    monkeypatch.setattr(storage, "cas", real_cas)
    del w  # the writer is gone; only its loose objects remain
    # the published head never moved: a fresh reader is unaffected
    r = dl.Dataset(storage)
    assert len(r["a"]) == 0
    # the crashed publish left orphans (its child-node files and/or the
    # unreferenced manifest segment); a destructive sweep reclaims them
    # and the dataset stays byte-identical
    rep = r.maintenance().gc_orphans(dry_run=False)
    assert rep.details["orphans"] >= 1
    assert rep.details["bytes_reclaimed"] > 0
    r2 = dl.Dataset(storage)
    assert len(r2["a"]) == 0
    assert r2.tensor_names == ["a", "b"]
