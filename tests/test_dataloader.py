"""Streaming dataloader (C5) + materialization (C4) + linked tensors."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core as dl
from repro.core.dataloader import DeepLakeLoader
from repro.core.linked import LinkRegistry, resolving_transform
from repro.core.materialize import materialize
from repro.core.scheduler import CostModel, MemoryBudget, SmartScheduler
from repro.core.views import DatasetView


def _image_ds(n=120, remote=False, chunk=64 << 10):
    rng = np.random.default_rng(5)
    store = dl.chain(dl.MemoryProvider(),
                     dl.SimulatedS3Provider(time_scale=0),
                     capacity_bytes=8 << 20) if remote else dl.MemoryProvider()
    ds = dl.Dataset(store)
    ds.create_tensor("images", htype="image", dtype="uint8",
                     sample_compression="zlib", min_chunk_size=chunk // 2,
                     max_chunk_size=chunk)
    ds.create_tensor("labels", htype="class_label")
    imgs = [rng.integers(0, 255, (24, 24, 3), dtype=np.uint8) for _ in range(n)]
    for i in range(n):
        ds.append({"images": imgs[i], "labels": np.int64(i)})
    ds.commit("data")
    return ds, imgs


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 8), st.integers(1, 64), st.integers(1, 33),
       st.booleans())
def test_loader_is_exact_permutation(workers, shuffle_buffer, batch, shuffle):
    """No sample dropped or duplicated, for any worker/buffer/batch combo."""
    ds, _ = _image_ds(n=67)
    loader = ds.dataloader(batch_size=batch, shuffle=shuffle,
                           shuffle_buffer=shuffle_buffer, num_workers=workers,
                           tensors=["labels"], seed=1)
    seen = [int(x) for b in loader for x in b["labels"]]
    if shuffle:
        assert sorted(seen) == list(range(67))
    else:
        assert seen == list(range(67))


def test_loader_value_integrity_under_shuffle():
    ds, imgs = _image_ds(n=60)
    loader = ds.dataloader(batch_size=16, shuffle=True, num_workers=6, seed=2)
    for b in loader:
        for j in range(len(b["labels"])):
            np.testing.assert_array_equal(b["images"][j],
                                          imgs[int(b["labels"][j])])


def test_loader_epochs_reshuffle():
    ds, _ = _image_ds(n=50)
    loader = ds.dataloader(batch_size=10, shuffle=True, num_workers=3, seed=3)
    e1 = [int(x) for b in loader for x in b["labels"]]
    e2 = [int(x) for b in loader for x in b["labels"]]
    assert e1 != e2 and sorted(e1) == sorted(e2) == list(range(50))


def test_loader_transform_runs_in_workers():
    ds, imgs = _image_ds(n=30)
    tf = lambda s: {**s, "images": s["images"].astype(np.float32) / 255.0}
    loader = ds.dataloader(batch_size=8, num_workers=4, transform=tf)
    b = next(iter(loader))
    assert b["images"].dtype == np.float32
    assert float(b["images"].max()) <= 1.0


def test_loader_worker_error_surfaces():
    ds, _ = _image_ds(n=20)

    def bad(sample):
        raise RuntimeError("boom")

    loader = ds.dataloader(batch_size=4, num_workers=2, transform=bad)
    with pytest.raises(RuntimeError, match="boom"):
        list(loader)


def test_loader_remote_chunk_efficiency():
    """Chunk-grouped plan: each chunk fetched ~once per epoch even shuffled."""
    ds, _ = _image_ds(n=120, remote=True)
    s3 = ds.storage.base
    loader = ds.dataloader(batch_size=16, shuffle=True, num_workers=4, seed=0)
    _ = [b for b in loader]
    nchunks = ds.images.num_chunks + ds.labels.num_chunks
    # LRU+grouping: chunk fetches stay within a small multiple of the chunk
    # count (+ a VC-metadata allowance: meta/encoder/chunk_set reads)
    assert s3.stats["requests"] <= 4 * nchunks + 40


def test_loader_drop_last_and_len():
    ds, _ = _image_ds(n=25)
    full = ds.dataloader(batch_size=10)
    drop = ds.dataloader(batch_size=10, drop_last=True)
    assert len(full) == 3 and len(drop) == 2
    assert sum(len(b["labels"]) for b in drop) == 20


# ----------------------------------------------------------------- scheduler
def test_memory_budget_blocks_and_releases():
    mb = MemoryBudget(100)
    assert mb.acquire(60)
    assert not mb.acquire(60, timeout=0.05)   # would exceed
    mb.release(60)
    assert mb.acquire(60)
    assert mb.block_events >= 1


def test_smart_scheduler_priority_order():
    cm = CostModel()
    cm.observe("heavy", io_s=0.1, cpu_s=1.0)
    cm.observe("light", io_s=0.1, cpu_s=0.001)
    s = SmartScheduler(cm)
    s.submit("late", needed_at=10.0, klass="light")
    s.submit("soon-light", needed_at=1.0, klass="light")
    s.submit("soon-heavy", needed_at=1.0, klass="heavy")
    s.close()
    assert s.take() == "soon-heavy"   # same deadline: CPU-heaviest first
    assert s.take() == "soon-light"
    assert s.take() == "late"


# ------------------------------------------------------------- materialize
def test_materialize_restores_locality_and_values():
    ds, imgs = _image_ds(n=90)
    view = ds.query("SELECT * FROM dataset WHERE labels % 9 == 0")
    out = materialize(view, tensors=["images", "labels"])
    assert len(out) == len(view)
    mv = DatasetView.full(out)
    assert mv.chunk_locality("images") >= view.chunk_locality("images")
    np.testing.assert_array_equal(out.images[1], imgs[9])
    assert out.storage.get_or_none("lineage.json") is not None


def test_materialize_derived_columns():
    ds, _ = _image_ds(n=20)
    v = ds.query("SELECT MEAN(images) AS m, labels FROM dataset LIMIT 5")
    out = materialize(v)
    assert "m" in out.tensor_names
    assert len(out["m"]) == 5


# ------------------------------------------------------------------- links
def test_linked_tensor_roundtrip_and_materialize():
    reg = LinkRegistry()
    ext = dl.MemoryProvider()
    reg.register("ext", ext)
    rng = np.random.default_rng(6)
    ds = dl.dataset()
    ds.create_tensor("limg", htype="link[image]")
    arrs = []
    for i in range(6):
        a = rng.integers(0, 255, (8, 8, 3), dtype=np.uint8)
        arrs.append(a)
        reg.put_array(f"ext://i{i}.npy", a)
        ds.limg.append(f"ext://i{i}.npy")
    tf = resolving_transform(["limg"], reg)
    loader = ds.dataloader(batch_size=3, tensors=["limg"], transform=tf,
                           num_workers=2)
    got = [x for b in loader for x in b["limg"]]
    for g, a in zip(got, arrs):
        np.testing.assert_array_equal(g, a)
    out = materialize(DatasetView.full(ds), registry=reg)
    np.testing.assert_array_equal(out.limg[4], arrs[4])
    assert not out["limg"].is_link


# ------------------------------------------------- pipeline-aware shuffle
def _group_order(loader, plan):
    """First-visit order of primary-tensor chunk ordinals in a plan."""
    enc = loader.view._base_tensor(loader._primary_tensor()).encoder
    seen, order = set(), []
    for pos in plan:
        k = enc.chunk_ord_of(int(loader.view.indices[pos]))
        if k not in seen:
            seen.add(k)
            order.append(k)
    return order


def _evict_engine(ds):
    eng = dl.engine_for(ds.storage)
    for name in ds.tensor_names:
        t = ds._tensor(name)
        for nm in t.encoder.chunk_names():
            eng.discard(t._chunk_key(nm))


def test_warm_first_shuffle_cold_plan_is_seeded_baseline():
    """On a cold engine every has_blob probe misses, so the pipeline-aware
    reorder is the identity: the plan is exactly the seed+epoch shuffle
    and repeat calls are deterministic."""
    ds, _ = _image_ds(n=120, remote=True, chunk=8 << 10)
    loader = ds.dataloader(shuffle=True, seed=5)
    _evict_engine(ds)
    p1 = loader._plan(np.random.default_rng(42))
    _evict_engine(ds)
    p2 = loader._plan(np.random.default_rng(42))
    assert p1 == p2


def test_warm_first_shuffle_prefers_resident_groups():
    """Warming a late group of the first window moves it to the window's
    front — while the epoch still visits exactly the same samples and
    groups (local reorder only, sample set unchanged)."""
    ds, _ = _image_ds(n=120, remote=True, chunk=8 << 10)
    loader = ds.dataloader(shuffle=True, seed=5)
    _evict_engine(ds)
    cold = loader._plan(np.random.default_rng(9))
    cold_groups = _group_order(loader, cold)
    assert len(cold_groups) >= 3
    window = cold_groups[: DeepLakeLoader.WARM_WINDOW]
    target = window[-1]                      # last group of the first window
    eng = dl.engine_for(ds.storage)
    t = loader.view._base_tensor(loader._primary_tensor())
    eng.prefetch(t._chunk_key(t.encoder.name_of(target))).result(timeout=5)
    warm = loader._plan(np.random.default_rng(9))
    warm_groups = _group_order(loader, warm)
    assert warm_groups[0] == target          # warm group served first
    assert sorted(warm) == sorted(cold)      # same epoch sample set
    assert set(warm_groups[: len(window)]) == set(window)  # window-local
