"""System behaviour: checkpoint/restore (incl. elastic), fault tolerance,
straggler mitigation, gradient compression, end-to-end training loop, and a
multi-device shard_map collective (subprocess with host devices)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as dl
from repro.checkpoint import CheckpointManager
from repro.distributed import (FailureInjector, HostFailure,
                               StragglerDetector, run_resilient)
from repro.launch.train import Trainer, TrainJob
from repro.optim import (AdamW, compress_grads, cosine_schedule,
                         init_error_feedback)


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_pytree():
    mgr = CheckpointManager(dl.MemoryProvider(), async_save=False)
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                        "b": jnp.ones((4,), jnp.bfloat16)},
             "opt": {"step": jnp.int32(7)}}
    mgr.save(state, step=7)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    out = mgr.restore(like)
    np.testing.assert_array_equal(out["params"]["w"], state["params"]["w"])
    assert out["params"]["b"].dtype == jnp.bfloat16
    assert int(out["opt"]["step"]) == 7


def test_checkpoint_async_and_retention():
    mgr = CheckpointManager(dl.MemoryProvider(), keep=2, async_save=True)
    state = {"w": jnp.zeros((64,))}
    for s in (1, 2, 3):
        mgr.save({"w": jnp.full((64,), float(s))}, step=s)
    mgr.wait()
    assert mgr.latest_step() == 3
    assert mgr.saved_steps == [2, 3]
    like = {"w": jax.ShapeDtypeStruct((64,), jnp.float32)}
    out = mgr.restore(like, step=3)
    np.testing.assert_array_equal(out["w"], np.full((64,), 3.0))
    # checkpoints are Deep Lake commits: time-travel metadata exists
    assert any(n.message.startswith("step=") for n in mgr.ds.log())


def test_checkpoint_versioned_history_is_deeplake():
    mgr = CheckpointManager(dl.MemoryProvider(), async_save=False, keep=5)
    mgr.save({"w": jnp.zeros((8,))}, step=1)
    mgr.save({"w": jnp.ones((8,))}, step=2)
    # raw rows live in the 'leaves' tensor of a normal dataset
    assert "leaves" in mgr.ds.tensor_names
    assert len(mgr.ds["leaves"]) == 2


# ---------------------------------------------------------- fault tolerance
def test_straggler_detector_flags_and_mitigates():
    events = []
    det = StragglerDetector(threshold=2.0, patience=2,
                            on_straggler=lambda s, t, b: events.append(s))
    for s in range(10):
        det.observe(s, 0.1)
    fired = [det.observe(10, 0.5), det.observe(11, 0.5)]
    assert fired == [False, True]
    assert det.mitigations == 1 and events == [11]
    assert det.flagged_steps == [10, 11]


def test_failure_injector_fires_once():
    inj = FailureInjector(fail_at_steps=(3,))
    inj.check(2)
    with pytest.raises(HostFailure):
        inj.check(3)
    inj.check(3)  # second pass: already failed once, proceeds


def test_run_resilient_restarts():
    attempts = []

    def make_runner(_):
        def run():
            attempts.append(1)
            if len(attempts) < 3:
                raise HostFailure("boom")
            return 42
        return run

    out = run_resilient(make_runner, max_restarts=5)
    assert out == {"final_step": 42, "restarts": 2}


# ----------------------------------------------------- gradient compression
def test_grad_compression_error_feedback_converges():
    grads = {"w": jnp.asarray(np.random.default_rng(0)
                              .standard_normal((256,)), jnp.float32)}
    fb = init_error_feedback(grads)
    acc_raw = np.zeros((256,))
    acc_cmp = np.zeros((256,))
    for _ in range(50):
        g, fb = compress_grads(grads, fb)
        acc_raw += np.asarray(grads["w"])
        acc_cmp += np.asarray(g["w"])
    # error feedback: accumulated compressed grads track the true sum
    rel = np.abs(acc_cmp - acc_raw).max() / np.abs(acc_raw).max()
    assert rel < 0.02, rel


# -------------------------------------------------------------- end-to-end
def test_trainer_loss_decreases_and_checkpoints():
    job = TrainJob(arch="gemma-2b", steps=12, global_batch=4, seq_len=64,
                   checkpoint_every=6, num_docs=16, log_every=100)
    t = Trainer(job)
    out = t.run(restore=False)
    assert out["final_step"] == 12
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]
    assert t.ckpt.latest_step() == 12


def test_trainer_restores_after_failure():
    job = TrainJob(arch="gemma-2b", steps=10, global_batch=4, seq_len=64,
                   checkpoint_every=2, num_docs=16, fail_at=(5,),
                   log_every=100)
    ckpt = CheckpointManager(dl.MemoryProvider(), keep=3)
    t1 = Trainer(job, ckpt=ckpt)
    with pytest.raises(HostFailure):
        t1.run(restore=False)
    assert ckpt.latest_step() >= 4
    # restarted job: the transient fault doesn't re-fire (real-world restart)
    import dataclasses as dc
    job2 = dc.replace(job, fail_at=())
    t2 = Trainer(job2, ckpt=ckpt, data_ds=t1.data_ds)
    out = t2.run(restore=True)          # resumes from checkpoint
    assert out["final_step"] == 10
    first_resumed = out["history"][0]["step"] if out["history"] else 10
    assert first_resumed >= 4           # at most checkpoint_every recomputed


def test_trainer_with_tql_filter_and_compression():
    job = TrainJob(arch="granite-moe-1b-a400m", steps=4, global_batch=2,
                   seq_len=64, grad_compress=True, num_docs=12,
                   tql_filter="SELECT * FROM dataset WHERE doc_id % 2 == 0",
                   log_every=100)
    out = Trainer(job).run(restore=False)
    assert np.isfinite(out["final_loss"])


def test_serve_generates_tokens():
    from repro.launch.serve import Server, ServeJob
    job = ServeJob(arch="gemma-2b", batch=2, prompt_len=8, max_new_tokens=6)
    srv = Server(job)
    prompts = np.random.default_rng(0).integers(
        0, srv.cfg.vocab_size, (2, 8)).astype(np.int32)
    out = srv.generate(prompts)
    assert out.shape == (2, 14)
    assert (out[:, :8] == prompts).all()
    assert (out[:, 8:] < srv.cfg.vocab_size).all()
    # greedy decode is deterministic
    out2 = Server(job).generate(prompts)
    np.testing.assert_array_equal(out, out2)


# ---------------------------------------------- multi-device collective path
MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.collectives import make_quantized_allreduce
    from repro.launch.mesh import make_local_mesh

    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    ar = make_quantized_allreduce(mesh, axis_name="pod")
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 16)),
                    jnp.float32)
    out = ar({"g": x})["g"]
    # out_specs P(None, ...) collapses the pod axis: (4, 16) mean over pods
    want = np.asarray(x).reshape(2, 4, 16).mean(axis=0)
    got = np.asarray(out)
    assert got.shape == want.shape, (got.shape, want.shape)
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 0.05, err
    # elastic restore across meshes: save on 8-dev mesh, load on 4-dev view
    import repro.core as dl
    from repro.checkpoint import CheckpointManager
    from jax.sharding import NamedSharding
    mgr = CheckpointManager(dl.MemoryProvider(), async_save=False)
    big = jnp.asarray(np.arange(64, dtype=np.float32).reshape(8, 8))
    sharded = jax.device_put(big, NamedSharding(mesh, P(("pod", "data"), None)))
    mgr.save({"w": sharded}, step=1)
    mesh2 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
    sh2 = {"w": NamedSharding(mesh2, P("data", None))}
    out2 = mgr.restore({"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
                       shardings=sh2)
    np.testing.assert_array_equal(np.asarray(out2["w"]), np.asarray(big))
    assert out2["w"].sharding.num_devices == 4
    print("MULTIDEV_OK")
""")


def test_quantized_allreduce_and_elastic_restore_multidevice():
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MULTIDEV_OK" in r.stdout
