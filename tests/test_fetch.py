"""Coalesced batch I/O engine (core/fetch.py) + Tensor.read_batch.

Covers the PR-2 contract: cost-model-derived coalescing threshold, full-GET
vs. ranged decision, in-flight prefetch dedup, cancellation, and — the
acceptance criterion — at most one coalesced request per chunk per tensor
on the hot read paths, byte-identical to per-sample reads.
"""

import threading
import time

import numpy as np
import pytest

import repro.core as dl
from repro.core import fetch
from repro.core.fetch import (CostEstimator, FetchEngine,
                              cache_capacity_above, provider_cost_params)


# ---------------------------------------------------------------- estimator
def test_estimator_seeds_from_provider_chain():
    s3 = dl.SimulatedS3Provider(time_scale=0, latency_s=0.02,
                                bandwidth_bps=1e6)
    lru = dl.LRUCacheProvider(s3, capacity_bytes=1 << 20)
    est = CostEstimator(lru)   # walks the chain down to the S3 tier
    assert est.seeded
    assert est.latency_s == 0.02
    assert est.gap_threshold() == int(0.02 * 1e6)
    assert provider_cost_params(lru) == (0.02, 1e6)
    assert cache_capacity_above(lru) == 1 << 20
    assert cache_capacity_above(s3) == 0


def test_estimator_learns_from_observations():
    mem = dl.MemoryProvider()
    est = CostEstimator(mem)
    assert not est.seeded
    for _ in range(50):
        est.observe_request(nbytes=1 << 20, seconds=0.05)
    assert est.latency_s > 1e-4         # pulled up from the local prior
    assert est.gap_threshold() > 0


def test_full_get_vs_ranged_decision():
    s3 = dl.SimulatedS3Provider(time_scale=0, latency_s=0.01,
                                bandwidth_bps=1e6)  # 10KB gap threshold
    est = CostEstimator(s3)
    # one tiny span out of a huge object: ranged wins
    assert not est.full_get_is_cheaper(n_spans=1, needed_bytes=1 << 10,
                                       object_bytes=1 << 24)
    # needing nearly everything: the single full GET wins (the bytes saved
    # by 4 ranged requests no longer pay for their 3 extra round-trips)
    assert est.full_get_is_cheaper(n_spans=4, needed_bytes=990_000,
                                   object_bytes=1_000_000)
    # an uncached header adds a round-trip to the ranged plan
    assert est.full_get_is_cheaper(n_spans=1, needed_bytes=0,
                                   object_bytes=5_000, extra_requests=1)


# ------------------------------------------------------------------- engine
def test_fetch_ranges_equals_per_range_reads():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    s3.put("k", bytes(range(200)))
    eng = FetchEngine(s3)
    ranges = [(10, 20), (20, 30), (150, 300), (5, 5), (90, 40)]
    want = [s3.get_range("k", s, e) for s, e in ranges]
    s3.reset_stats()
    assert eng.fetch_ranges("k", ranges) == want
    assert s3.stats["coalesced_requests"] >= 1
    with fetch.coalescing_disabled():
        assert not fetch.coalescing_enabled()
        assert eng.fetch_ranges("k", ranges) == want
    assert fetch.coalescing_enabled()


def test_prefetch_dedups_inflight_keys():
    release = threading.Event()

    class SlowProvider(dl.MemoryProvider):
        def __init__(self):
            super().__init__()
            self.gets = 0

        def get(self, key):
            self.gets += 1
            release.wait(timeout=5)
            return super().get(key)

    p = SlowProvider()
    p.put("chunk", b"x" * 100)
    eng = FetchEngine(p)
    f1 = eng.prefetch("chunk")
    f2 = eng.prefetch("chunk")      # while in flight: same future
    assert f1 is f2
    release.set()
    assert f1.result(timeout=5) == b"x" * 100
    assert p.gets == 1
    # completed prefetch parks the blob: later fetches are free
    assert eng.resident("chunk") == b"x" * 100
    assert eng.fetch_full("chunk") == b"x" * 100
    assert p.gets == 1


def test_prefetch_cancellation_is_safe():
    gate = threading.Event()

    class GatedProvider(dl.MemoryProvider):
        def get(self, key):
            gate.wait(timeout=5)
            return super().get(key)

    p = GatedProvider()
    for i in range(32):
        p.put(f"k{i}", b"v" * 8)
    eng = FetchEngine(p, max_workers=1)
    futs = [eng.prefetch(f"k{i}") for i in range(32)]
    cancelled = eng.cancel_pending()
    assert cancelled > 0            # queued-but-not-started futures dropped
    gate.set()
    # a cancelled in-flight future is never trusted: readers fall back
    for i in range(32):
        assert eng.fetch_full(f"k{i}") == b"v" * 8
    eng.close()
    del futs


def test_engine_for_is_per_provider():
    a, b = dl.MemoryProvider(), dl.MemoryProvider()
    assert fetch.engine_for(a) is fetch.engine_for(a)
    assert fetch.engine_for(a) is not fetch.engine_for(b)


def test_engine_registry_releases_collected_providers():
    """The per-provider registry must not leak engines (resident blobs,
    pools) once the provider's last external reference is gone."""
    import gc
    import weakref as wr

    p = dl.MemoryProvider()
    eng_ref = wr.ref(fetch.engine_for(p))
    assert eng_ref() is not None
    del p
    gc.collect()
    assert eng_ref() is None


def test_cancel_pending_is_owner_scoped():
    """One consumer's teardown must never cancel another's prefetches."""
    gate = threading.Event()

    class GatedProvider(dl.MemoryProvider):
        def get(self, key):
            gate.wait(timeout=5)
            return super().get(key)

    p = GatedProvider()
    for i in range(8):
        p.put(f"k{i}", b"v")
    eng = FetchEngine(p, max_workers=1)
    owner_a, owner_b = object(), object()
    [eng.prefetch(f"k{i}", owner=owner_a) for i in range(4)]
    b_futs = [eng.prefetch(f"k{i + 4}", owner=owner_b) for i in range(4)]
    cancelled = eng.cancel_pending(owner=owner_a)
    assert cancelled >= 3                  # queued A-futures dropped
    assert all(not f.cancelled() for f in b_futs)
    gate.set()
    for f in b_futs:
        assert f.result(timeout=5) == b"v"
    eng.close()


def test_resident_store_is_byte_bounded():
    p = dl.MemoryProvider()
    eng = FetchEngine(p, resident_bytes=100)
    for i in range(10):
        p.put(f"k{i}", bytes(40))
        eng.prefetch(f"k{i}").result(timeout=5)
    with eng._lock:
        assert eng._resident_size <= 100


# --------------------------------------------------------------- read_batch
def _chunked_ds(storage=None, n=300, chunk=1 << 11):
    rng = np.random.default_rng(3)
    ds = dl.Dataset(storage)
    ds.create_tensor("x", dtype="float32", min_chunk_size=chunk // 2,
                     max_chunk_size=chunk)
    ds.create_tensor("lab", htype="class_label")
    vals = [rng.standard_normal(32).astype(np.float32) for _ in range(n)]
    for i, v in enumerate(vals):
        ds.append({"x": v, "lab": np.int64(i % 7)})
    return ds, vals


def test_read_batch_matches_per_sample_reads():
    ds, vals = _chunked_ds()
    ds.commit("c")
    t = ds._tensor("x")
    assert t.num_chunks > 3
    for idx in ([0], [299, 0, 150, 150, -1], list(range(300)),
                list(range(299, -1, -1)), []):
        got = t.read_batch(idx)
        want = [t.read(int(i)) for i in idx]
        assert len(got) == len(want)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)


def test_read_batch_covers_open_builder_tail():
    ds, vals = _chunked_ds(n=40)   # no commit: tail lives in the builder
    t = ds._tensor("x")
    got = t.read_batch(np.arange(40))
    for g, w in zip(got, vals):
        np.testing.assert_array_equal(g, w)


def test_read_batch_ragged_and_forced_modes():
    ds = dl.Dataset()
    ds.create_tensor("r", dtype="float32", min_chunk_size=512,
                     max_chunk_size=1024)
    rows = [np.arange(i + 1, dtype=np.float32) for i in range(50)]
    for r in rows:
        ds.append({"r": r})
    ds.commit("c")
    t = ds._tensor("r")
    for mode in (None, True, False):
        got = t.read_batch(np.arange(50), ranged=mode)
        for g, w in zip(got, rows):
            np.testing.assert_array_equal(g, w)


def test_read_batch_tiled_samples():
    ds = dl.Dataset()
    ds.create_tensor("img", dtype="uint8", min_chunk_size=1 << 10,
                     max_chunk_size=1 << 12)
    small = np.ones((8, 8), np.uint8)
    big = np.arange(120 * 120, dtype=np.uint8).reshape(120, 120)  # tiled
    ds.append({"img": small})
    ds.append({"img": big})
    ds.commit("c")
    t = ds._tensor("img")
    got = t.read_batch([0, 1])
    np.testing.assert_array_equal(got[0], small)
    np.testing.assert_array_equal(got[1], big)


def test_read_batch_out_of_range_raises():
    ds, _ = _chunked_ds(n=10)
    ds.commit("c")
    with pytest.raises(IndexError):
        ds._tensor("x").read_batch([0, 10])


def test_read_batch_one_coalesced_request_per_chunk():
    """Acceptance: batch reads issue <= 1 coalesced request per chunk per
    tensor (down from one per sample), byte-identical results."""
    base = dl.MemoryProvider()
    ds, vals = _chunked_ds(storage=base)
    ds.commit("c")
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    remote = dl.Dataset(s3)
    t = remote._tensor("x")
    nchunks = t.num_chunks
    t._chunk_key(t.encoder.name_of(0))  # warm the VC chunk-set memo
    s3.reset_stats()
    got = t.read_batch(np.arange(300))
    for g, w in zip(got, vals):
        np.testing.assert_array_equal(g, w)
    assert s3.stats["requests"] <= nchunks
    # the per-sample pattern for comparison: >= one request per sample
    with fetch.coalescing_disabled():
        s3.reset_stats()
        t2 = dl.Dataset(s3)._tensor("x")
        t2.read_batch(np.arange(300))
        per_sample = s3.stats["requests"]
    assert per_sample >= 300
    assert nchunks * 3 <= per_sample


def test_sparse_read_through_lru_chain_stays_ranged():
    """An LRU tier above the remote biases toward cache-filling full GETs,
    but never unconditionally: a one-shot sparse read of a chunk whose
    transfer dwarfs the round-trip must stay ranged."""
    base = dl.MemoryProvider()
    ds, vals = _chunked_ds(storage=base, n=300, chunk=1 << 15)
    ds.commit("c")
    s3 = dl.SimulatedS3Provider(base, time_scale=0, latency_s=0.002,
                                bandwidth_bps=1e6)
    chained = dl.Dataset(dl.chain(dl.MemoryProvider(), s3,
                                  capacity_bytes=256 << 20))
    t = chained._tensor("x")
    chunk_bytes = max(base.num_bytes(t._chunk_key(n))
                      for n in t.encoder.chunk_names())
    s3.reset_stats()
    got = t.read_batch([0])
    np.testing.assert_array_equal(got[0], vals[0])
    assert s3.stats["bytes_down"] < chunk_bytes
    # dense reads through the same chain amortize into full cache fills
    s3.reset_stats()
    all_ = t.read_batch(np.arange(300))
    for g, w in zip(all_, vals):
        np.testing.assert_array_equal(g, w)
    assert s3.stats["requests"] <= t.num_chunks + 2  # +VC chunk-set reads


def test_sparse_read_batch_uses_ranged_requests():
    """A few samples out of big chunks must NOT fetch whole chunks."""
    base = dl.MemoryProvider()
    ds, vals = _chunked_ds(storage=base, n=300, chunk=1 << 15)
    ds.commit("c")
    # bandwidth-dominated regime: skipping unneeded bytes beats saving a
    # round-trip, so the cost model must pick ranged reads
    s3 = dl.SimulatedS3Provider(base, time_scale=0, latency_s=1e-5,
                                bandwidth_bps=1e6)
    remote = dl.Dataset(s3)
    t = remote._tensor("x")
    chunk_bytes = max(s3.base.num_bytes(t._chunk_key(n))
                      for n in t.encoder.chunk_names())
    s3.reset_stats()
    got = t.read_batch([0])
    np.testing.assert_array_equal(got[0], vals[0])
    assert s3.stats["bytes_down"] < chunk_bytes  # header probe + one range


def test_discard_abandons_inflight_prefetch():
    """A writer's discard() racing an in-flight prefetch must prevent the
    completed fetch from re-admitting (now stale) bytes."""
    gate = threading.Event()

    class GatedProvider(dl.MemoryProvider):
        def get(self, key):
            gate.wait(timeout=5)
            return super().get(key)

    p = GatedProvider()
    p.put("k", b"old")
    eng = FetchEngine(p)
    fut = eng.prefetch("k")
    eng.discard("k")          # writer rewrote the key while fetch in flight
    p.put("k", b"new-bytes")
    gate.set()
    try:
        fut.result(timeout=5)  # may still deliver pre-rewrite bytes...
    except Exception:
        pass
    time.sleep(0.1)            # let the done-callback run
    assert eng.resident("k") is None      # ...but never admits them
    assert eng.fetch_full("k") == b"new-bytes"
    eng.close()


def test_reflushed_open_chunk_invalidates_resident_blob():
    """Regression: the open chunk is rewritten under the SAME key on every
    flush; a resident blob parked by an earlier prefetch must be discarded
    or later readers see a stale (shorter) chunk."""
    base = dl.MemoryProvider()
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    ds = dl.Dataset(s3)
    ds.create_tensor("x", dtype="float32")
    for i in range(5):
        ds.append({"x": np.full(4, i, np.float32)})
    ds.flush()
    t = ds._tensor("x")
    key = t._chunk_key(t.encoder.name_of(0))
    fetch.engine_for(s3).prefetch(key).result(timeout=5)  # park the 5-sample blob
    for i in range(5, 10):
        ds.append({"x": np.full(4, i, np.float32)})
    ds.flush()                                            # same key, 10 samples
    reader = dl.Dataset(s3)                               # shares the engine
    got = reader._tensor("x").read_batch(np.arange(10))
    for i, g in enumerate(got):
        np.testing.assert_array_equal(g, np.full(4, i, np.float32))


# ------------------------------------------------------- TQL + loader wiring
def test_tql_verify_tail_one_request_per_chunk():
    """The verify-heavy selective query fetches each verify chunk with one
    request (prefetch in verdict order), identical result set."""
    base = dl.MemoryProvider()
    rng = np.random.default_rng(11)
    ds = dl.Dataset(base)
    ds.create_tensor("val", dtype="float32", min_chunk_size=1 << 11,
                     max_chunk_size=1 << 12)
    for i in range(1000):
        band = i // 125
        ds.append({"val": rng.standard_normal(16).astype(np.float32)
                   + np.float32(50 * band)})
    ds.commit("c")
    q = "SELECT * FROM dataset WHERE MIN(val) > 330"
    expect = ds.query(q, use_stats=False).indices.tolist()

    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    remote = dl.Dataset(s3)
    nchunks = remote._tensor("val").num_chunks
    s3.reset_stats()
    view = remote.query(q, engine="numpy", use_stats=True)
    assert view.indices.tolist() == expect
    # every request during WHERE is a whole-chunk fetch of a verify chunk
    # (never one per sample); bound: one request per chunk of the tensor
    assert s3.stats["requests"] <= nchunks
    assert s3.stats["requests"] < len(expect)


def test_loader_coalesced_requests_and_stats():
    base = dl.MemoryProvider()
    ds, vals = _chunked_ds(storage=base)
    ds.commit("c")
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    remote = dl.Dataset(s3)
    loader = remote.dataloader(batch_size=32, num_workers=4, seed=0)
    s3.reset_stats()
    labs = [int(x) for b in loader for x in b["lab"]]
    assert labs == [i % 7 for i in range(300)]
    assert s3.stats["requests"] < 300       # far fewer than one per sample
    assert loader.stats.io_requests > 0
    assert loader.stats.bytes_fetched > 0


def test_loader_memory_timeout_resubmits_unit(monkeypatch):
    """Regression (unit-drop bug): a MemoryBudget.acquire timeout must NOT
    lose the unit — it is resubmitted and sequential iteration completes."""
    from repro.core.scheduler import MemoryBudget

    ds, _ = _chunked_ds(storage=None, n=64)
    ds.commit("c")
    loader = ds.dataloader(batch_size=8, num_workers=2, unit_size=8, seed=0)

    real_acquire = MemoryBudget.acquire
    failed = {"n": 0}

    def flaky_acquire(self, nbytes, timeout=None):
        if failed["n"] < 3:     # first few attempts time out immediately
            failed["n"] += 1
            return False
        return real_acquire(self, nbytes, timeout=timeout)

    monkeypatch.setattr(MemoryBudget, "acquire", flaky_acquire)
    out: list = []
    err: list = []

    def run():
        try:
            out.extend(int(x) for b in loader for x in b["lab"])
        except Exception as e:  # pragma: no cover - surfaced by main thread
            err.append(e)

    th = threading.Thread(target=run, daemon=True)
    th.start()
    th.join(timeout=30)
    assert not th.is_alive(), "loader hung: dropped unit never re-fetched"
    assert not err
    assert out == [i % 7 for i in range(64)]
    assert failed["n"] == 3


# ------------------------------------------------------- stats snapshot
def test_stats_snapshot_consistent_under_threads():
    """`stats_snapshot` must never expose a torn view: every counter in a
    snapshot reflects the same set of completed requests, so with unique
    same-size full-object reads `bytes == requests * K` holds in EVERY
    snapshot taken while reader threads are mutating the stats."""
    K = 1024
    n_threads, per_thread = 4, 60
    mem = dl.MemoryProvider()
    for i in range(n_threads * per_thread):
        mem.put(f"blob/{i}", bytes(K))
    engine = FetchEngine(mem)

    stop = threading.Event()
    bad: list = []

    def reader(tid: int) -> None:
        for j in range(per_thread):
            engine.fetch_full(f"blob/{tid * per_thread + j}")

    def observer() -> None:
        while not stop.is_set():
            s = engine.stats_snapshot()
            if s["bytes"] != s["requests"] * K:
                bad.append({k: s[k] for k in ("requests", "ranges", "bytes")})

    obs = threading.Thread(target=observer)
    readers = [threading.Thread(target=reader, args=(i,))
               for i in range(n_threads)]
    obs.start()
    for t in readers:
        t.start()
    for t in readers:
        t.join()
    stop.set()
    obs.join()

    assert not bad, f"torn snapshots observed: {bad[:3]}"
    final = engine.stats_snapshot()
    assert final["requests"] == n_threads * per_thread
    assert final["bytes"] == n_threads * per_thread * K


# --------------------------------------------------- multi-object batching
def test_fetch_many_batches_into_one_request():
    """A tile fan-out of N whole objects costs ONE provider round on a
    batching provider (PR-9 multi-object batching), byte-identical to the
    per-object path."""
    s3 = dl.SimulatedS3Provider(time_scale=0)
    expect = {}
    for i in range(6):
        expect[f"tile{i}"] = bytes([i]) * 128
        s3.put(f"tile{i}", expect[f"tile{i}"])
    eng = FetchEngine(s3)
    s3.reset_stats()
    counters = {}
    out = eng.fetch_many(list(expect), counters=counters)
    assert out == expect
    assert counters["requests"] == 1
    assert s3.stats["requests"] == 1
    assert s3.stats["batched_objects"] == 6
    # the A/B switch still forces the old per-object path
    s3.reset_stats()
    with fetch.coalescing_disabled():
        out2 = eng.fetch_many(list(expect), counters=(c2 := {}))
    assert out2 == expect
    assert c2["requests"] == 6
    assert s3.stats["requests"] == 6
    eng.close()


def test_fetch_many_transient_batch_falls_back_per_key():
    """A transient anywhere in the batch must cost at most one wasted
    round: the engine retries per key, never re-reads the whole batch."""
    class FlakyBatch(dl.SimulatedS3Provider):
        batch_calls = 0

        def get_many(self, keys):
            type(self).batch_calls += 1
            raise dl.TransientStorageError("batch round lost")

    p = FlakyBatch(time_scale=0)
    expect = {f"k{i}": bytes([i]) * 64 for i in range(4)}
    for k, v in expect.items():
        p.put(k, v)
    eng = FetchEngine(p)
    out = eng.fetch_many(list(expect), counters=(c := {}))
    assert out == expect
    assert FlakyBatch.batch_calls == 1       # exactly one wasted round
    assert c["requests"] == 4                # then per-key convergence
    assert eng.stats_snapshot()["errors_transient"] >= 1
    eng.close()


def test_fetch_many_serves_resident_blobs_for_free():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    for i in range(4):
        s3.put(f"b{i}", b"z" * 32)
    eng = FetchEngine(s3)
    eng.prefetch("b0").result(timeout=5)
    eng.prefetch("b1").result(timeout=5)
    s3.reset_stats()
    out = eng.fetch_many([f"b{i}" for i in range(4)], counters=(c := {}))
    assert set(out) == {f"b{i}" for i in range(4)}
    assert c["requests"] == 1                # one batch for the two misses
    assert s3.stats["batched_objects"] == 2
    eng.close()
