"""Validation of the exact HLO roofline analyzer (launch/hlo_analysis.py):
agreement with cost_analysis on scan-free programs, exact trip-count
multiplication on scans, slice-aware traffic, collective extraction."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_hlo
from repro.launch.roofline import extract_cost


def test_matmul_flops_match_cost_analysis():
    a = jnp.zeros((256, 512), jnp.float32)
    b = jnp.zeros((512, 128), jnp.float32)
    c = jax.jit(lambda a, b: (a @ b).sum()).lower(a, b).compile()
    got = analyze(c.as_text())
    want = 2 * 256 * 512 * 128
    assert abs(got.flops - want) / want < 0.05


def test_scan_flops_multiplied_by_trip_count():
    def g(xs):
        def body(c, x):
            return jnp.tanh(c @ x), ()
        c1, _ = jax.lax.scan(body, jnp.zeros((128, 128), jnp.float32), xs)
        return c1.sum()

    xs = jnp.zeros((24, 128, 128), jnp.float32)
    c = jax.jit(g).lower(xs).compile()
    got = analyze(c.as_text())
    want = 24 * 2 * 128 ** 3
    assert abs(got.flops - want) / want < 0.1
    # cost_analysis counts the body once — the failure mode we fix
    ca = extract_cost(c)[0]
    assert ca < want / 2


@pytest.mark.xfail(strict=False, reason="slice-aware HBM traffic bound is XLA-layout dependent; overcounts on this jax build's remat lowering")
def test_remat_train_step_flops_in_expected_band():
    L, T, D, F = 8, 512, 256, 1024

    def loss(params, x):
        def body(h, p):
            return jnp.tanh(h @ p["wi"]) @ p["wo"], ()
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = jax.lax.scan(body, x, params)
        return jnp.sum(h * h)

    params = {"wi": jnp.zeros((L, D, F), jnp.bfloat16),
              "wo": jnp.zeros((L, F, D), jnp.bfloat16)}
    x = jnp.zeros((T, D), jnp.bfloat16)
    c = jax.jit(jax.grad(loss)).lower(params, x).compile()
    got = analyze(c.as_text())
    fwd = L * 2 * (2 * T * D * F)
    # full-remat train = fwd + recompute + 2x grads ~ [3x, 4.5x] fwd
    assert 3.0 <= got.flops / fwd <= 4.5
    # traffic sane: params ~17MB, activations ~50MB; slice-aware accounting
    # must stay far below the naive 'full stacked buffer per trip' blow-up
    assert got.hbm_bytes < 600e6


def test_parse_hlo_structures():
    a = jnp.zeros((64, 64), jnp.float32)
    c = jax.jit(lambda a: jnp.tanh(a @ a).sum()).lower(a).compile()
    comps, entry = parse_hlo(c.as_text())
    assert entry is not None and entry in comps
    assert any(op.opcode == "dot" for comp in comps.values()
               for op in comp.ops)


MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    import sys
    sys.path.insert(0, "src")
    from repro.launch.hlo_analysis import analyze

    mesh = jax.make_mesh((8,), ("d",))
    x = jnp.zeros((1024, 256), jnp.float32)
    w = jnp.zeros((256, 256), jnp.float32)
    def f(x, w):
        return (x @ w).sum()
    with mesh:
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "d")),
                                     NamedSharding(mesh, P("d", None)))
                    ).lower(x, w).compile()
    got = analyze(c.as_text())
    assert got.collective_bytes > 0, "contracting-dim sharding needs a reduce"
    assert got.collective_by_kind, got.collective_by_kind
    print("HLO_COLLECTIVES_OK")
""")


def test_collectives_detected_on_sharded_program():
    r = subprocess.run([sys.executable, "-c", MULTIDEV], capture_output=True,
                       text=True, timeout=300, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "HLO_COLLECTIVES_OK" in r.stdout


DRYRUN_CELL = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", "granite-moe-1b-a400m", "--shape", "decode_32k",
               "--mesh", "single", "--tag", "pytest"]


def test_dryrun_cell_end_to_end():
    """One real dry-run cell: lower+compile on 256 host devices, JSON out."""
    import json
    import os
    from pathlib import Path
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(DRYRUN_CELL, capture_output=True, text=True,
                       timeout=900, cwd="/root/repo", env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    out = Path("/root/repo/experiments/dryrun/"
               "granite-moe-1b-a400m__decode_32k__single__pytest.json")
    d = json.loads(out.read_text())
    assert d["status"] == "OK"
    assert d["chips"] == 256
    assert d["roofline"]["flops_per_device"] > 0
    assert d["memory_analysis"]["alias_bytes"] > 0   # cache donation aliased
    out.unlink()
