"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import ref_decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import ref_attention
from repro.kernels.fused_preprocess import fused_preprocess
from repro.kernels.fused_preprocess.ref import ref_preprocess
from repro.kernels.ssd_scan import ssd
from repro.kernels.ssd_scan.ref import ref_ssd

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,D,window,bq,bk", [
    (2, 256, 4, 2, 64, 0, 128, 128),
    (1, 512, 8, 1, 128, 0, 128, 256),    # MQA
    (2, 256, 4, 4, 64, 96, 64, 64),      # sliding window
    (1, 384, 6, 2, 32, 0, 128, 128),     # non-pow2 heads, padded seq
])
def test_flash_attention_sweep(dtype, B, S, H, Hkv, D, window, bq, bk, rng):
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), dtype)
    got = flash_attention(q, k, v, True, window, None, bq, bk, True)
    want = ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_grad_matches_ref(rng):
    q = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)

    def f_kern(q_):
        return flash_attention(q_, k, v, True, 0, None, 64, 64, True).sum()

    def f_ref(q_):
        return ref_attention(q_, k, v, causal=True).sum()

    np.testing.assert_allclose(np.asarray(jax.grad(f_kern)(q)),
                               np.asarray(jax.grad(f_ref)(q)),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,D,T,pos,window,bt", [
    (2, 4, 2, 64, 512, 100, 0, 128),
    (1, 8, 8, 128, 1024, 1023, 0, 256),
    (2, 4, 1, 64, 256, 300, 256, 64),    # ring buffer window
    (1, 2, 2, 32, 128, 0, 0, 128),       # first token
])
def test_decode_attention_sweep(dtype, B, H, Hkv, D, T, pos, window, bt, rng):
    q = jnp.asarray(rng.standard_normal((B, H, D)), dtype)
    ck = jnp.asarray(rng.standard_normal((B, T, Hkv, D)), dtype)
    cv = jnp.asarray(rng.standard_normal((B, T, Hkv, D)), dtype)
    got = decode_attention(q, ck, cv, pos=jnp.int32(pos), window=window,
                           block_t=bt, interpret=True)
    want = ref_decode_attention(q, ck, cv, pos=pos, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,S,nh,P,G,N,Q", [
    (2, 128, 4, 32, 1, 16, 32),
    (1, 256, 8, 64, 2, 32, 64),
    (2, 64, 2, 16, 1, 8, 64),            # single chunk
    (1, 96, 4, 32, 4, 16, 32),           # groups == heads/1
])
def test_ssd_sweep(dtype, B, S, nh, P, G, N, Q, rng):
    x = jnp.asarray(rng.standard_normal((B, S, nh, P)) * 0.5, dtype)
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (B, S, nh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (nh,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, G, N)) * 0.3, dtype)
    Cm = jnp.asarray(rng.standard_normal((B, S, G, N)) * 0.3, dtype)
    y, st = ssd(x, dt, A, Bm, Cm, Q, True)
    yw, stw = ref_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(stw), atol=2e-4,
                               rtol=2e-4)


def test_ssd_chunked_xla_matches_ref(rng):
    """The XLA-path chunked formulation == naive recurrence (same math the
    kernel tiles)."""
    from repro.models.ssm import ssd_chunked
    x = jnp.asarray(rng.standard_normal((2, 128, 4, 32)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (2, 128, 4)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (4,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((2, 128, 1, 16)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((2, 128, 1, 16)) * 0.3, jnp.float32)
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    y2, s2 = ref_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_ssd_grads_finite(rng):
    x = jnp.asarray(rng.standard_normal((1, 64, 2, 16)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (1, 64, 2)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (2,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((1, 64, 1, 8)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((1, 64, 1, 8)) * 0.3, jnp.float32)
    g = jax.grad(lambda x_: ssd(x_, dt, A, Bm, Cm, 32, True)[0].sum())(x)
    assert np.isfinite(np.asarray(g)).all()


@pytest.mark.parametrize("crop", [(0, 0, 32, 32), (8, 16, 32, 32),
                                  (1, 1, 30, 30)])
def test_fused_preprocess_sweep(crop, rng):
    imgs = jnp.asarray(rng.integers(0, 255, (3, 64, 64, 3)), jnp.uint8)
    mean, std = (0.48, 0.45, 0.41), (0.23, 0.22, 0.23)
    got = fused_preprocess(imgs, crop, mean, std, True)
    want = ref_preprocess(imgs, crop, mean, std)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    assert got.dtype == jnp.float32


def test_xla_blockwise_attention_matches_ref(rng):
    """The XLA train path (masked blocks) and the pair-scan variant both
    match the oracle — the §Perf optimization is a pure refactor."""
    from repro.models.attention import blockwise_attention
    q = jnp.asarray(rng.standard_normal((2, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), jnp.float32)
    want = ref_attention(q, k, v, causal=True, scale=0.25)
    got_masked = blockwise_attention(q, k, v, scale=0.25, causal=True,
                                     q_block=64, kv_block=64)
    got_pairs = blockwise_attention(q, k, v, scale=0.25, causal=True,
                                    q_block=64, kv_block=64, pairs=True)
    np.testing.assert_allclose(np.asarray(got_masked), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(got_pairs), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
