"""Maintenance engine: stats backfill, manifest compaction, orphan GC.

Acceptance properties (ISSUE 3):

* a backfilled pre-stats dataset produces byte-identical query results and
  the SAME prune verdicts as a natively-written one;
* GC never deletes a chunk reachable from any commit, across randomized
  commit/branch histories (property test);
* compaction collapses delta-segment chains back to the 2-request cold
  open.

Also covers the exact-tiled-stats satellite: tile descriptors no longer
force the planner into 'verify'.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.core as dl
from repro.core import manifest as manifestlib
from repro.core.manifest import MANIFEST_KEY, SEGMENT_PREFIX

QUERIES = (
    "SELECT * FROM dataset WHERE lab == 3",
    "SELECT * FROM dataset WHERE MEAN(x) > 45",
    "SELECT * FROM dataset WHERE MIN(x) > 1000",
    "SELECT * FROM dataset WHERE lab >= 0",
)


def _build(storage=None, n=200):
    rng = np.random.default_rng(11)
    ds = dl.Dataset(storage)
    ds.create_tensor("x", dtype="float32", min_chunk_size=512,
                     max_chunk_size=1024)
    ds.create_tensor("lab", htype="class_label", min_chunk_size=128,
                     max_chunk_size=256)
    for i in range(n):
        band = i // 25
        ds.append({"x": (rng.standard_normal(8).astype(np.float32)
                         + np.float32(band * 10)),
                   "lab": np.int64(band)})
    ds.commit("fixture")
    return ds


def _make_prestats(base):
    """Rewind a manifest-native dataset to the pre-stats, pre-manifest
    format: no pointer, no segments, no chunk_stats sidecars."""
    base.delete(MANIFEST_KEY)
    for key in list(base.list_keys(SEGMENT_PREFIX)):
        base.delete(key)
    for key in list(base.list_keys()):
        if key.endswith("chunk_stats.json"):
            base.delete(key)


# ------------------------------------------------------------- stats backfill
def test_backfill_restores_native_prune_verdicts():
    native_base = dl.MemoryProvider()
    native = _build(native_base)
    native_plans = {}
    native_results = {}
    for q in QUERIES:
        v = native.query(q, use_stats=True)
        native_plans[q] = v.scan_plan
        native_results[q] = (v.indices.tolist(),
                            [a.tolist() for a in v.tensor("x").numpy()]
                            if len(v) else [])

    # same data, pre-stats format
    pre_base = dl.MemoryProvider()
    _build(pre_base)
    _make_prestats(pre_base)
    pre = dl.Dataset(pre_base)
    assert pre.manifest is None
    for q in QUERIES:
        v = pre.query(q, use_stats=True)
        if v.scan_plan is not None:
            assert v.scan_plan["rows_pruned"] == 0      # nothing to prune on
            assert v.scan_plan["stats_coverage"] == 0.0
        assert v.indices.tolist() == native_results[q][0]

    report = pre.maintenance().backfill_stats()
    assert report.details["chunks_backfilled"] > 0
    for q in QUERIES:
        v = pre.query(q, use_stats=True)
        # identical verdict partition AND identical results
        for k in ("rows_pruned", "rows_sure", "rows_verify",
                  "chunks_pruned"):
            assert v.scan_plan[k] == native_plans[q][k], (q, k)
        assert v.scan_plan["stats_coverage"] == 1.0
        assert v.indices.tolist() == native_results[q][0]
        got = [a.tolist() for a in v.tensor("x").numpy()] if len(v) else []
        assert got == native_results[q][1]


def test_backfill_is_idempotent_and_dry_run_writes_nothing():
    base = dl.MemoryProvider()
    _build(base, n=50)
    _make_prestats(base)
    ds = dl.Dataset(base)
    dry = ds.maintenance().backfill_stats(dry_run=True)
    assert dry.details["chunks_backfilled"] > 0
    assert not any(k.endswith("chunk_stats.json") for k in base.list_keys())
    ds.maintenance().backfill_stats()
    again = ds.maintenance().backfill_stats()
    assert again.details["chunks_backfilled"] == 0


def test_backfill_survives_reopen_and_commit():
    base = dl.MemoryProvider()
    _build(base, n=100)
    _make_prestats(base)
    ds = dl.Dataset(base)
    ds.maintenance().backfill_stats()
    ds.commit("post backfill")          # adopts a manifest too
    fresh = dl.Dataset(base)
    v = fresh.query("SELECT * FROM dataset WHERE lab == 1", use_stats=True)
    assert v.scan_plan["rows_pruned"] > 0
    assert v.indices.tolist() == list(range(25, 50))


# ------------------------------------------------------ exact tiled stats
def test_tiled_samples_keep_exact_stats():
    ds = dl.Dataset()
    ds.create_tensor("img", dtype="float32", min_chunk_size=1 << 10,
                     max_chunk_size=1 << 12)
    big = np.full((64, 64), 7.0, np.float32)        # 16KB raw -> tiled
    big[0, 0] = 3.0
    ds.img.append(big)
    ds.img.append(np.full((64, 64), 9.0, np.float32))
    ds.flush()
    st_ = ds.img.chunk_stats_of(0)
    assert st_ is not None and st_.exact
    assert st_.lo <= 3.0 and st_.hi >= 9.0
    # and the planner can now prune on tiled tensors
    ds.commit("tiled")
    on = ds.query("SELECT * FROM dataset WHERE MAX(img) > 100",
                  use_stats=True)
    assert len(on) == 0 and on.scan_plan["rows_pruned"] == 2


def test_tiled_stats_bound_lossy_roundtrip():
    ds = dl.Dataset()
    ds.create_tensor("img", dtype="float32", sample_compression="quant8",
                     min_chunk_size=1 << 10, max_chunk_size=1 << 12)
    rng = np.random.default_rng(3)
    arr = rng.uniform(-5, 5, (80, 80)).astype(np.float32)
    ds.img.append(arr)
    ds.flush()
    st_ = ds.img.chunk_stats_of(0)
    assert st_ is not None and st_.exact
    decoded = ds.img.read(0)            # what queries actually see
    assert st_.lo <= float(decoded.min())
    assert st_.hi >= float(decoded.max())


def test_backfilled_tiled_stats_match_native():
    base = dl.MemoryProvider()
    ds = dl.Dataset(base)
    ds.create_tensor("img", dtype="float32", min_chunk_size=1 << 10,
                     max_chunk_size=1 << 12)
    for v in (2.0, 11.0):
        ds.img.append(np.full((64, 64), v, np.float32))
    ds.commit("tiled")
    native = ds.img.chunk_stats_of(0)
    _make_prestats(base)
    pre = dl.Dataset(base)
    assert pre.img.chunk_stats_of(0) is None
    pre.maintenance().backfill_stats()
    pre2 = dl.Dataset(base)
    back = pre2.img.chunk_stats_of(0)
    assert back is not None and back.exact == native.exact is True
    assert back.lo == native.lo and back.hi == native.hi
    assert back.n_elements == native.n_elements


# --------------------------------------------------------------- compaction
def test_compaction_collapses_delta_chain(monkeypatch):
    monkeypatch.setattr(manifestlib, "AUTO_CONSOLIDATE_BYTES", 0)
    base = dl.MemoryProvider()
    ds = _build(base, n=30)
    for i in range(3):
        ds.x.append(np.full(8, float(i), np.float32))
        ds.commit(f"delta {i}")
    assert len(ds.manifest.segments) > 1
    report = ds.maintenance().compact_manifest()
    assert len(ds.manifest.segments) == 1
    assert report.details["nodes_folded"] == len(ds.vc.commits)
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    ds2 = dl.Dataset(s3)
    assert len(ds2.x) == 33 and len(ds2.lab) == 30
    assert s3.stats["requests"] <= 2


def test_delta_chain_auto_checkpoints(monkeypatch):
    monkeypatch.setattr(manifestlib, "AUTO_CONSOLIDATE_BYTES", 0)
    base = dl.MemoryProvider()
    ds = _build(base, n=20)
    for i in range(manifestlib.MAX_DELTA_SEGMENTS + 2):
        ds.x.append(np.full(8, float(i), np.float32))
        ds.commit(f"c{i}")
    assert len(ds.manifest.segments) <= manifestlib.MAX_DELTA_SEGMENTS


def test_compaction_adopts_legacy_and_readopts_stale():
    base = dl.MemoryProvider()
    ds = _build(base, n=40)
    ds.x.append(np.zeros(8, np.float32))
    ds.flush()                                  # head goes stale
    assert not ds.manifest.covers(ds.commit_id)
    ds.maintenance().compact_manifest()
    assert ds.manifest.covers(ds.commit_id)     # re-adopted from loose
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    ds2 = dl.Dataset(s3)
    assert len(ds2.x) == 41
    assert s3.stats["requests"] <= 2


# ------------------------------------------------------------------- GC
def _snapshot_all_commits(ds):
    """{(commit, tensor, row) -> value list} across the full tree."""
    out = {}
    for nid, node in ds.vc.commits.items():
        if not node.committed:
            continue
        for t in ds.vc.schema_tensors(nid):
            bound = ds.tensor_at(t, nid)
            for i in range(len(bound)):
                out[(nid, t, i)] = bound.read(i).tolist()
    return out


def test_gc_removes_planted_orphans_only():
    base = dl.MemoryProvider()
    ds = _build(base, n=60)
    nid = ds.commit_id
    base.put(f"versions/{nid}/tensors/x/chunks/cdeadbeef0000", b"orphan")
    base.put("versions/ffffffffffffffff/tensors/x/chunks/c123", b"dead node")
    base.put(f"{SEGMENT_PREFIX}seg-99999999-aaaaaaaa.json", b"{}")
    before = _snapshot_all_commits(ds)
    dry = ds.maintenance().gc_orphans(dry_run=True)
    assert len(dry.actions) >= 3
    assert base.exists(f"versions/{nid}/tensors/x/chunks/cdeadbeef0000")
    report = ds.maintenance().gc_orphans(dry_run=False)
    assert set(dry.actions) == set(report.actions)
    assert not base.exists(f"versions/{nid}/tensors/x/chunks/cdeadbeef0000")
    assert not base.exists("versions/ffffffffffffffff/tensors/x/chunks/c123")
    assert _snapshot_all_commits(ds) == before


def test_gc_keeps_deleted_tensors_chunks_reachable_from_history():
    ds = _build(n=30)
    ds.create_tensor("y", dtype="int64")
    ds.y.extend([np.int64(i) for i in range(30)])
    cid = ds.commit("with y")
    ds.delete_tensor("y")
    ds.commit("without y")
    ds.maintenance().gc_orphans(dry_run=False)
    old = ds.tensor_at("y", cid)
    assert [int(old.read(i)) for i in range(3)] == [0, 1, 2]


def test_gc_collects_uncommitted_deleted_tensor():
    base = dl.MemoryProvider()
    ds = _build(base, n=20)
    ds.create_tensor("tmp", dtype="int64", min_chunk_size=64,
                     max_chunk_size=128)
    ds.tmp.extend([np.int64(i) for i in range(20)])
    ds.flush()
    ds.delete_tensor("tmp")             # never committed: chunks orphaned
    ds.flush()
    report = ds.maintenance().gc_orphans(dry_run=False)
    assert any("/tensors/tmp/chunks/" in k for k in report.actions)
    assert not any("/tensors/tmp/chunks/" in k
                   for k in base.list_keys("versions/"))


@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["append", "update", "branch",
                                           "commit"]),
                          st.integers(0, 9), st.integers(-50, 50)),
                min_size=1, max_size=10))
def test_gc_never_deletes_reachable_chunks_property(script):
    """Random commit/branch/edit histories: after a full GC sweep, every
    sample of every tensor at every commit reads back unchanged."""
    ds = dl.Dataset()
    ds.create_tensor("x", dtype="int64", min_chunk_size=128,
                     max_chunk_size=256)
    for i in range(10):
        ds.x.append(np.full(4, i, np.int64))
    ds.commit("base")
    n_branches = 0
    for op, idx, val in script:
        if op == "append":
            ds.x.append(np.full(4, val, np.int64))
        elif op == "update":
            ds.x[idx % len(ds.x)] = np.full(4, val, np.int64)
        elif op == "branch" and n_branches < 3:
            ds.checkout(f"b{n_branches}", create=True)
            n_branches += 1
        elif op == "commit":
            ds.commit(f"edit {idx}")
    ds.flush()
    before = _snapshot_all_commits(ds)
    ds.maintenance().gc_orphans(dry_run=False)
    assert _snapshot_all_commits(ds) == before
    # head still readable and writable afterwards
    ds.x.append(np.full(4, 99, np.int64))
    assert int(ds.x[len(ds.x) - 1][0]) == 99


def test_runner_runs_all_jobs():
    ds = _build(n=30)
    reports = ds.maintenance().run(dry_run=True)
    assert [r.job for r in reports] == ["backfill_stats", "compact_manifest",
                                       "gc_orphans"]
    assert all(r.dry_run for r in reports)
    with pytest.raises(ValueError):
        ds.maintenance().run(jobs=("nope",))
