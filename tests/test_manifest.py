"""Dataset manifest: one-GET cold opens, CAS protocol, legacy fallback.

Covers the consolidated-manifest subsystem (core/manifest.py): pointer +
segment layout, request budgets on cold `Dataset` opens across
SimulatedS3Provider / LRU / Local for both manifest and legacy layouts,
write-ahead staleness, optimistic-concurrency conflicts, and byte-for-byte
equivalence between the manifest and loose per-file read paths.
"""

import json

import numpy as np
import pytest

import repro.core as dl
from repro.core.manifest import (MANIFEST_KEY, SEGMENT_PREFIX, Manifest,
                                 ManifestConflict)


class CountingProvider(dl.StorageProvider):
    """Transparent wrapper counting the physical requests a cold open
    issues against providers that carry no stats of their own (Local,
    the base under an LRU tier)."""

    def __init__(self, base):
        self.base = base
        self.kind = base.kind
        self.counts = {"requests": 0, "meta_requests": 0}

    def get(self, key):
        data = self.base.get(key)
        self.counts["requests"] += 1
        return data

    def get_range(self, key, start, end):
        data = self.base.get_range(key, start, end)
        self.counts["requests"] += 1
        return data

    def get_ranges(self, key, ranges):
        data = self.base.get_ranges(key, ranges)
        self.counts["requests"] += 1
        return data

    def get_many(self, keys):
        out = self.base.get_many(keys)
        self.counts["requests"] += len(out)
        return out

    def put(self, key, data):
        self.base.put(key, data)

    def cas(self, key, data, expected):
        return self.base.cas(key, data, expected)

    def delete(self, key):
        self.base.delete(key)

    def exists(self, key):
        self.counts["meta_requests"] += 1
        return self.base.exists(key)

    def list_keys(self, prefix=""):
        self.counts["meta_requests"] += 1
        return self.base.list_keys(prefix)

    def num_bytes(self, key):
        self.counts["meta_requests"] += 1
        return self.base.num_bytes(key)

    def reset(self):
        for k in self.counts:
            self.counts[k] = 0


def _build(storage=None, n=60, tensors=3):
    ds = dl.Dataset(storage)
    names = [f"t{i}" for i in range(tensors)]
    for name in names:
        ds.create_tensor(name, dtype="float32", min_chunk_size=512,
                         max_chunk_size=1024)
    for i in range(n):
        ds.append({name: np.full(8, i + j, np.float32)
                   for j, name in enumerate(names)})
    ds.commit("fixture")
    return ds


def strip_manifest(storage):
    """Turn a manifest-native dataset into the legacy per-file layout
    (the loose files are always complete, so this is safe)."""
    storage.delete(MANIFEST_KEY)
    for key in list(storage.list_keys(SEGMENT_PREFIX)):
        storage.delete(key)


def _cold_open(storage):
    """A cold open: construct the Dataset and bind every tensor's state."""
    ds = dl.Dataset(storage)
    for t in ds.tensor_names:
        assert len(ds[t]) > 0
    return ds


# --------------------------------------------------------------- CAS primitive
@pytest.mark.parametrize("make", [
    lambda tmp: dl.MemoryProvider(),
    lambda tmp: dl.LocalProvider(str(tmp)),
    lambda tmp: dl.SimulatedS3Provider(time_scale=0),
    lambda tmp: dl.LRUCacheProvider(dl.MemoryProvider()),
], ids=["memory", "local", "s3", "lru"])
def test_cas_semantics(make, tmp_path):
    p = make(tmp_path)
    assert p.cas("k", b"v1", None) is True          # create-if-absent
    assert p.cas("k", b"v1b", None) is False        # exists now
    assert p.cas("k", b"v2", b"v1") is True         # swap on match
    assert p.cas("k", b"v3", b"v1") is False        # stale expectation
    assert p.get("k") == b"v2"


def test_cas_charged_on_s3():
    s3 = dl.SimulatedS3Provider(time_scale=0)
    s3.cas("k", b"v", None)
    assert s3.stats["cas_requests"] == 1
    assert s3.stats["requests"] == 1


# ------------------------------------------------------------ manifest layout
def test_manifest_native_dataset_layout():
    base = dl.MemoryProvider()
    ds = _build(base)
    ptr = json.loads(base.get(MANIFEST_KEY).decode())
    assert ptr["format"] == "deeplake-repro-manifest-v3"
    assert ptr["vc"]["branches"]["main"] == ds.commit_id
    assert len(ptr["segments"]) >= 1
    seg = json.loads(base.get(ptr["segments"][0]).decode())
    # the newest segment covers the sealed commit and the fresh head
    sealed = ds.vc.current.parent
    assert sealed in seg["nodes"] and ds.commit_id in seg["nodes"]
    node = seg["nodes"][ds.commit_id]
    assert sorted(node["schema"]) == ["t0", "t1", "t2"]
    for t in node["schema"]:
        assert set(node["tensors"][t]) == set(dl.VersionControl.ALL_STATE_FILES)


def test_manifest_covers_clean_head_and_stales_on_write():
    base = dl.MemoryProvider()
    ds = _build(base)
    m = ds.manifest
    assert m.covers(ds.commit_id)
    ds.t0.append(np.zeros(8, np.float32))
    ds.flush()
    # write-ahead invalidation: the pointer's stale list holds the head
    ptr = json.loads(base.get(MANIFEST_KEY).decode())
    assert ds.commit_id in ptr["stale"]
    assert not m.covers(ds.commit_id)
    # a fresh open falls back to loose files and sees the new row
    ds2 = dl.Dataset(base)
    assert len(ds2.t0) == len(ds.t0) == 61


# --------------------------------------------------- cold-open request budgets
def test_cold_open_budget_s3_manifest_vs_legacy():
    base = dl.MemoryProvider()
    _build(base, tensors=3)

    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    ds = _cold_open(s3)
    manifest_stats = dict(s3.stats)
    # the acceptance budget: <= 3 storage requests, no metadata probes
    assert manifest_stats["requests"] <= 3
    assert manifest_stats["meta_requests"] == 0
    # the manifest's own open accounting agrees with the provider's
    assert ds.manifest.open_stats["requests"] == manifest_stats["requests"]

    strip_manifest(base)
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    _cold_open(s3)
    legacy_stats = dict(s3.stats)
    # legacy layout: ds_meta + vc info + schema + per-tensor state files
    assert legacy_stats["requests"] >= 2 + 4 * 3
    assert legacy_stats["requests"] > 3 * manifest_stats["requests"]


def test_cold_open_budget_local():
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        local = dl.LocalProvider(tmp)
        _build(local, tensors=3)
        counting = CountingProvider(local)
        _cold_open(counting)
        manifest_requests = counting.counts["requests"]
        assert manifest_requests <= 3
        strip_manifest(local)
        counting.reset()
        _cold_open(counting)
        assert counting.counts["requests"] > manifest_requests


def test_cold_open_budget_lru():
    base = dl.MemoryProvider()
    _build(base, tensors=3)
    counting = CountingProvider(base)
    lru = dl.LRUCacheProvider(counting)
    _cold_open(lru)
    first = counting.counts["requests"]
    assert first <= 3
    # second cold open through the same warm LRU tier: zero base requests
    counting.reset()
    _cold_open(lru)
    assert counting.counts["requests"] == 0


def test_cold_open_data_identical_manifest_vs_legacy():
    base = dl.MemoryProvider()
    _build(base, n=40, tensors=2)
    via_manifest = _cold_open(base)
    rows_m = [via_manifest.read_row(i) for i in range(len(via_manifest))]
    strip_manifest(base)
    via_legacy = _cold_open(base)
    rows_l = [via_legacy.read_row(i) for i in range(len(via_legacy))]
    for a, b in zip(rows_m, rows_l):
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


# -------------------------------------------------------- adoption + fallback
def test_legacy_dataset_adopts_manifest_on_commit():
    base = dl.MemoryProvider()
    _build(base)
    strip_manifest(base)
    ds = dl.Dataset(base)
    assert ds.manifest is None          # legacy open: per-file path
    ds.t0.append(np.ones(8, np.float32))
    ds.commit("adopt")
    assert ds.manifest is not None
    assert base.exists(MANIFEST_KEY)
    # and the next cold open is cheap again
    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    _cold_open(s3)
    assert s3.stats["requests"] <= 3


def test_time_travel_and_branches_via_manifest():
    base = dl.MemoryProvider()
    ds = _build(base, n=20, tensors=1)
    c0 = ds.vc.current.parent           # the sealed fixture commit
    ds.checkout("exp", create=True)
    ds.t0[0] = np.full(8, -5, np.float32)
    ds.commit("exp edit")
    ds.checkout("main")
    # fresh open: everything resolves through the manifest catalog
    ds2 = dl.Dataset(base)
    assert sorted(ds2.branches) == ["exp", "main"]
    np.testing.assert_array_equal(
        ds2.tensor_at("t0", c0).read(0), np.zeros(8, np.float32))
    ds2.checkout("exp")
    np.testing.assert_array_equal(ds2.t0[0], np.full(8, -5, np.float32))


# ----------------------------------------------------- optimistic concurrency
def test_concurrent_commit_conflicts():
    base = dl.MemoryProvider()
    _build(base, n=10, tensors=1)
    a = dl.Dataset(base)
    b = dl.Dataset(base)
    a.t0.append(np.full(8, 1, np.float32))
    a.commit("a wins")
    b.t0.append(np.full(8, 2, np.float32))
    with pytest.raises(ManifestConflict):
        b.commit("b loses")
    # the winner's history is intact for a fresh reader
    fresh = dl.Dataset(base)
    assert [n.message for n in fresh.log()][0] == "a wins"


def test_loser_can_reopen_and_retry():
    base = dl.MemoryProvider()
    _build(base, n=10, tensors=1)
    a = dl.Dataset(base)
    b = dl.Dataset(base)
    a.t0.append(np.full(8, 1, np.float32))
    a.commit("a")
    b.t0.append(np.full(8, 2, np.float32))
    with pytest.raises(ManifestConflict):
        b.commit("b")
    retry = dl.Dataset(base)            # re-open: fresh catalog
    retry.t0.append(np.full(8, 2, np.float32))
    retry.commit("b retried")
    assert len(dl.Dataset(base).t0) == 12


def test_readonly_handle_flush_is_noop_after_foreign_commit():
    """A handle with nothing to publish must neither conflict with nor
    roll back another writer's commit when it flushes."""
    base = dl.MemoryProvider()
    _build(base, n=10, tensors=1)
    reader = dl.Dataset(base)
    writer = dl.Dataset(base)
    writer.t0.append(np.full(8, 7, np.float32))
    writer.commit("writer wins")
    reader.flush()                      # no changes: must not raise
    reader.checkout("main")             # re-syncs from... no: still stale view
    # the loose legacy mirror still shows the writer's head, not the
    # reader's stale snapshot
    info = json.loads(base.get("version_control_info.json").decode())
    assert info["branches"]["main"] == writer.commit_id
    assert len(dl.Dataset(base).t0) == 11


def test_stale_handle_with_changes_conflicts_without_rollback():
    base = dl.MemoryProvider()
    _build(base, n=10, tensors=1)
    a = dl.Dataset(base)
    b = dl.Dataset(base)
    a.t0.append(np.full(8, 1, np.float32))
    a.commit("a")
    # b's first attempt to publish real vc changes hits the fence
    with pytest.raises(ManifestConflict):
        b.checkout("side", create=True)
    info = json.loads(base.get("version_control_info.json").decode())
    assert info["branches"]["main"] == a.commit_id   # a's tree survives


def test_manifest_create_race_resolves_to_loader():
    base = dl.MemoryProvider()
    m1 = Manifest.create(base)
    m2 = Manifest.create(base)          # loses the create race, loads
    assert m1.generation == m2.generation == 0
    assert base.exists(MANIFEST_KEY)
