"""Manifest format-v3 compatibility: v3 readers open v1 and v2 manifests
(fallback binds / sketch-less records degrade to verify, never fail), and
backfill + compaction lift membership sketches into the manifest so
plan-at-open regains sketch verdicts on legacy datasets.
"""

import json

import numpy as np

import repro.core as dl
from repro.core.manifest import (COMPAT_FORMATS, FORMAT, MANIFEST_KEY,
                                 SEGMENT_PREFIX)
from repro.core.tql import execute_query
from repro.core.views import DatasetView


def _build(storage=None, n=180):
    ds = dl.Dataset(storage)
    ds.create_tensor("lab", htype="class_label", min_chunk_size=128,
                     max_chunk_size=256)
    ds.create_tensor("x", dtype="float32", min_chunk_size=512,
                     max_chunk_size=1024)
    rng = np.random.default_rng(5)
    for i in range(n):
        band = i // 30
        ds.append({"lab": np.int64(band * 2),
                   "x": (rng.standard_normal(8).astype(np.float32)
                         + np.float32(band * 10))})
    ds.commit("fixture")
    return ds


def _rewrite_as(base, marker, strip_stats=False, strip_sketches=False):
    """Rewrite the persisted manifest as an older format in place."""
    ptr = json.loads(base.get(MANIFEST_KEY).decode())
    ptr["format"] = marker
    for seg_key in ptr["segments"]:
        seg = json.loads(base.get(seg_key).decode())
        seg["format"] = marker
        for node in seg["nodes"].values():
            if strip_stats:
                node.pop("stats", None)
            elif strip_sketches:
                for cs in node.get("stats", {}).values():
                    for rec in cs.get("chunks", []):
                        if rec:
                            for f in ("sketched", "dom", "dct", "bloom"):
                                rec.pop(f, None)
        base.put(seg_key, json.dumps(seg).encode())
    base.put(MANIFEST_KEY, json.dumps(ptr).encode())


def test_format_markers():
    assert FORMAT == "deeplake-repro-manifest-v3"
    assert "deeplake-repro-manifest-v1" in COMPAT_FORMATS
    assert "deeplake-repro-manifest-v2" in COMPAT_FORMATS


def test_v3_reader_opens_v2_manifest_sketchless_records_verify():
    """v2 manifests (column stats, no sketches) load; bounds still prune,
    membership probes degrade to verify, results identical."""
    base = dl.MemoryProvider()
    ds = _build(base)
    expect = execute_query(ds, "SELECT * FROM dataset WHERE lab == 3")
    _rewrite_as(base, "deeplake-repro-manifest-v2", strip_sketches=True)
    ds2 = dl.Dataset(base)
    assert ds2.vc.column_stats("lab") is not None  # scan index still served
    got = execute_query(ds2, "SELECT * FROM dataset WHERE lab == 3")
    assert got.indices.tolist() == expect.indices.tolist() == []
    plan = got.scan_plan
    assert plan["chunks_sketchless"] > 0 and plan["sketch_coverage"] < 1.0
    # the odd-value gap needs the sketch: without it some rows verify
    assert plan["rows_verify"] > 0
    assert plan["stats_coverage"] == 1.0  # bounds themselves are intact


def test_v3_reader_opens_v1_manifest_fallback_binds():
    base = dl.MemoryProvider()
    ds = _build(base)
    expect = execute_query(ds, "SELECT * FROM dataset WHERE MIN(x) > 35")
    _rewrite_as(base, "deeplake-repro-manifest-v1", strip_stats=True)
    ds2 = dl.Dataset(base)
    assert ds2.manifest is not None
    assert ds2.vc.column_stats("lab") is None      # v1: no scan index
    got = execute_query(ds2, "SELECT * FROM dataset WHERE MIN(x) > 35")
    assert got.indices.tolist() == expect.indices.tolist()
    # the bind fallback reads the (sketch-bearing) loose sidecar, so
    # membership pruning still works end to end
    v = execute_query(ds2, "SELECT * FROM dataset WHERE lab == 3")
    assert len(v) == 0 and v.scan_plan["rows_verify"] == 0


def test_backfill_and_compaction_lift_sketches_to_plan_at_open():
    """Legacy dataset (no manifest, sketch-less sidecars): backfill lifts
    the sketches, compaction publishes them, and a cold open then gets
    membership prune verdicts with zero tensor binds and zero requests."""
    base = dl.MemoryProvider()
    _build(base)
    base.delete(MANIFEST_KEY)
    for key in list(base.list_keys(SEGMENT_PREFIX)):
        base.delete(key)
    for key in list(base.list_keys()):
        if key.endswith("chunk_stats.json"):
            doc = json.loads(base.get(key).decode())
            for rec in doc.get("chunks", {}).values():
                for f in ("sketched", "dom", "dct", "bloom"):
                    rec.pop(f, None)
            base.put(key, json.dumps(doc).encode())
    legacy = dl.Dataset(base)
    report = legacy.maintenance().backfill_stats()
    assert report.details["sketches_lifted"] > 0
    legacy.maintenance().compact_manifest()

    s3 = dl.SimulatedS3Provider(base, time_scale=0)
    cold = dl.Dataset(s3)
    open_requests = s3.stats["requests"]
    assert open_requests <= 3
    view = DatasetView.full(cold)
    v = execute_query(view, "SELECT * FROM view WHERE lab IN [1, 5]")
    assert len(v) == 0 and v.scan_plan["rows_verify"] == 0
    assert v.scan_plan["sketch_coverage"] == 1.0
    assert s3.stats["requests"] == open_requests, \
        "sketch planning issued storage requests"
    assert view._bound == {} and cold._tensors == {}, \
        "sketch planning bound a tensor"
