"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes + no NaNs; plus
decode/prefill consistency with the train-path logits."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cell_is_runnable, get_arch, \
    reduce_for_smoke
from repro.launch.steps import init_state, make_train_step
from repro.models import build_model, count_params
from repro.models.layers import rmsnorm
from repro.optim import AdamW, cosine_schedule

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.num_codebooks:
        tokens = rng.integers(0, cfg.vocab_size,
                              (B, cfg.num_codebooks, S)).astype(np.int32)
    else:
        tokens = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens), "targets": jnp.asarray(tokens),
             "loss_mask": jnp.ones((B, S), np.float32)}
    if cfg.num_image_tokens:
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_image_tokens, 1024)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduce_for_smoke(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0
    # one optimizer step decreases nothing catastrophic + stays finite
    opt = AdamW(cosine_schedule(1e-3, 2, 10))
    step = jax.jit(make_train_step(model, opt))
    state = init_state(model, opt, jax.random.PRNGKey(1))
    state, m2 = step(state, batch)
    assert np.isfinite(float(m2["loss"]))
    assert np.isfinite(float(m2["grad_norm"]))
    for leaf in jax.tree_util.tree_leaves(state["params"]):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_and_prefill_match_forward(arch):
    cfg = reduce_for_smoke(get_arch(arch))
    if cfg.moe:  # dropless capacity so train path == decode path exactly
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, S = 2, 24
    batch = _batch(cfg, B=B, S=S, seed=3)
    # vlm decode path: pure-text mode (no image splice)
    batch.pop("image_embeds", None)
    tokens = np.asarray(batch["tokens"])
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h = model._embed_tokens(params, batch)
    h, _ = model.backbone(params, h, positions)
    h = rmsnorm(params["final_ln"], h, cfg.norm_eps)
    want = np.asarray(model._logits(params, h))[:, -1]

    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    for t in range(S):
        tok = jnp.asarray(tokens[:, t] if not cfg.num_codebooks
                          else tokens[:, :, t])
        logits, cache = step(params, cache, tok, jnp.int32(t))
    got = np.asarray(logits)
    scale = np.max(np.abs(want)) + 1e-9
    assert np.max(np.abs(got - want)) / scale < 2e-2, arch

    logits_p, _ = jax.jit(model.prefill)(params, batch)
    assert np.max(np.abs(np.asarray(logits_p) - want)) / scale < 2e-2, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_param_specs_exact(arch):
    """FULL configs: spec-tree construction only (no allocation) + the spec'd
    dimensions match the assigned table."""
    cfg = get_arch(arch)
    model = build_model(cfg)
    n = count_params(model.param_specs())
    expected_min = {
        "starcoder2-3b": 2.5e9, "qwen2-72b": 6e10, "gemma-2b": 2e9,
        "gemma3-27b": 2.2e10, "musicgen-medium": 1.2e9,
        "phi-3-vision-4.2b": 3.4e9, "deepseek-v3-671b": 6.2e11,
        "granite-moe-1b-a400m": 1.0e9, "mamba2-1.3b": 1.1e9,
        "zamba2-2.7b": 2.2e9,
    }[arch]
    assert n >= expected_min, (arch, n)
    assert n <= expected_min * 1.45, (arch, n)


def test_moe_capacity_drops_are_bounded():
    """Default cf=1.25 drops few tokens under near-uniform routing."""
    from repro.models import moe as moe_lib
    cfg = reduce_for_smoke(get_arch("granite-moe-1b-a400m"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p = jax.tree_util.tree_map(lambda x: x[0], params["moe_blocks"])["moe"]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 64, cfg.d_model)) * 0.02,
                    jnp.float32)
    out, aux = moe_lib.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) == pytest.approx(1.0, rel=0.5)  # balanced ~1.0


def test_long_context_skip_list():
    runnable = [a for a in ARCHS if cell_is_runnable(a, "long_500k")]
    assert sorted(runnable) == ["gemma3-27b", "mamba2-1.3b", "zamba2-2.7b"]
    assert all(cell_is_runnable(a, "train_4k") for a in ARCHS)


def test_shapes_table():
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].global_batch == 1
    assert SHAPES["prefill_32k"].seq_len == 32_768
